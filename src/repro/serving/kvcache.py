"""Batched KV-cache slot manager for continuous batching.

A replica owns a fixed-capacity decode cache (``B_slots`` sequences).  The
manager hands out slots, tracks per-slot sequence positions, and frees slots
on completion — the serving-side "bounded memory" mirror of the paper's
K_max-bounded counter set.
"""

from __future__ import annotations

from typing import Dict, List, Optional

__all__ = ["SlotManager"]


class SlotManager:
    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.free: List[int] = list(range(num_slots))
        self.active: Dict[int, dict] = {}  # slot -> request metadata

    def allocate(self, request_id, session_key, now: float) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop()
        self.active[slot] = {
            "request_id": request_id,
            "session": session_key,
            "start": now,
            "tokens": 0,
        }
        return slot

    def release(self, slot: int) -> dict:
        meta = self.active.pop(slot)
        self.free.append(slot)
        return meta

    def utilization(self) -> float:
        return len(self.active) / self.num_slots

    def __len__(self) -> int:
        return len(self.active)
