"""Continuous-batching serving engine with a FISH request router.

Requests carry *session keys* (user / conversation ids) whose popularity is
time-evolving — exactly the paper's workload.  The router is the paper's
full pipeline:

* hot sessions are spread across several replicas (CHK), cold sessions get
  2 candidates (PKG fallback) — bounding per-session state replication;
* the replica choice among candidates uses *inferred* backlog (Alg. 3 /
  Eq. 1-2), never a queue-depth RPC;
* replica failure / scale-out remaps sessions via consistent hashing (§5),
  so most sessions keep replica affinity (their KV/prefix state survives).

The engine can run pure-simulation (logical per-token service times) or
drive a real reduced model's ``decode_step`` per tick (see
examples/serve_stream.py).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional, Union

import numpy as np

from ..core.fish import FishParams
from ..obs.metrics import MetricsRegistry
from .kvcache import SlotManager

__all__ = ["Request", "ServingEngine", "EngineMetrics"]


@dataclasses.dataclass
class Request:
    request_id: int
    session: object
    arrival: float
    target_tokens: int
    finished: float = -1.0
    replica: int = -1
    #: tick at which the request won a decode slot (-1 while queued) —
    #: ``started - arrival`` is its time-in-queue (ISSUE 8 observability)
    started: float = -1.0


@dataclasses.dataclass
class EngineMetrics:
    latency_avg: float
    latency_p50: float
    latency_p99: float
    throughput_tokens: float
    session_replicas: int          # Σ replicas holding state per session
    session_replicas_norm: float   # normalised to 1 replica/session
    dropped: int
    # ISSUE 8 observability: the autoscaler's input signals
    queue_depth_peak: int = 0      # max Σ_r queued requests seen at any tick
    in_flight_peak: int = 0        # max Σ_r active decode slots at any tick
    shed: int = 0                  # requests rejected by admission control
    time_in_queue_avg: float = 0.0
    time_in_queue_p99: float = 0.0


class ServingEngine:
    def __init__(
        self,
        num_replicas: int,
        slots_per_replica: int = 8,
        tokens_per_tick: Optional[np.ndarray] = None,  # replica speed (hetero)
        grouping: Union[str, "SchemeConfig"] = "fish",
        fish_params: Optional[FishParams] = None,
        step_fn: Optional[Callable[[int, List[dict]], None]] = None,
        max_queue_per_replica: Optional[int] = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        from ..topology.configs import FishConfig, SchemeConfig, config_for

        self.num_replicas = num_replicas
        speeds = (np.ones(num_replicas) if tokens_per_tick is None
                  else np.asarray(tokens_per_tick, dtype=np.float64))
        self.speeds = speeds
        caps = 1.0 / np.maximum(speeds, 1e-9)  # seconds(ticks)/token = P_w
        # grouping: a typed SchemeConfig (ISSUE 3) or a scheme name.  The
        # name "fish" defaults to a 4-tick estimator interval (the engine's
        # historical pacing); an explicit FishConfig keeps its own interval.
        if not isinstance(grouping, SchemeConfig):
            grouping = (FishConfig(interval=4.0) if grouping == "fish"
                        else config_for(grouping))
        if isinstance(grouping, FishConfig) and fish_params is not None:
            grouping = FishConfig.from_params(
                fish_params, interval=grouping.interval,
                virtual_nodes=grouping.virtual_nodes,
                use_consistent_hash=grouping.use_consistent_hash)
        self.router = grouping.build(num_replicas, capacities=caps)
        self.slots = [SlotManager(slots_per_replica) for _ in range(num_replicas)]
        self.queues: List[deque] = [deque() for _ in range(num_replicas)]
        self.step_fn = step_fn
        self.done: List[Request] = []
        self.now = 0.0
        self._alive = set(range(num_replicas))
        self._token_budget = np.zeros(num_replicas)
        self._next_slot = [0] * num_replicas  # round-robin decode cursor
        self.total_tokens = 0
        # ISSUE 8: bounded ingress queue + migration stall + observability.
        # ISSUE 9: shed / queue-depth / in-flight live in registry cells
        # (the session's registry when given, else a private one) and the
        # legacy ``shed``/``queue_depth_peak``/``in_flight_peak`` attributes
        # are properties over them — one source of truth for the report.
        self.max_queue_per_replica = max_queue_per_replica
        self._stall = np.zeros(num_replicas)  # remaining stall ticks
        reg = metrics if metrics is not None else MetricsRegistry()
        self._m_shed = reg.counter("serving.shed")
        self._m_queue_depth_peak = reg.gauge("serving.queue_depth_peak")
        self._m_in_flight_peak = reg.gauge("serving.in_flight_peak")
        self._m_queue_depth_peak._peak_mode = True
        self._m_in_flight_peak._peak_mode = True

    @property
    def shed(self) -> int:
        """Requests rejected by admission control (registry-backed)."""
        return self._m_shed.value

    @shed.setter
    def shed(self, v: int) -> None:
        self._m_shed.set(v)

    @property
    def queue_depth_peak(self) -> int:
        return self._m_queue_depth_peak.value

    @queue_depth_peak.setter
    def queue_depth_peak(self, v: int) -> None:
        self._m_queue_depth_peak.set(v)

    @property
    def in_flight_peak(self) -> int:
        return self._m_in_flight_peak.value

    @in_flight_peak.setter
    def in_flight_peak(self, v: int) -> None:
        self._m_in_flight_peak.set(v)

    @property
    def alive(self) -> List[int]:
        return sorted(self._alive)

    # -- ingress -------------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Route and enqueue one request.  With a bounded ingress queue
        (``max_queue_per_replica``) a request routed to a full replica queue
        is *shed* — counted in ``self.shed``, not enqueued — and -1 is
        returned (ISSUE 8 admission control)."""
        replica = self.router.assign(req.session, self.now)
        if (self.max_queue_per_replica is not None
                and len(self.queues[replica]) >= self.max_queue_per_replica):
            self._m_shed.add(1)
            return -1
        req.replica = replica
        self.queues[replica].append(req)
        self._m_queue_depth_peak.peak(sum(len(q) for q in self.queues))
        return replica

    # -- one scheduling tick ---------------------------------------------------
    def tick(self) -> None:
        self.now += 1.0
        for r in sorted(self._alive):
            if self._stall[r] > 0:
                # migration stall: the replica is ingesting migrated session
                # state this tick — no admission, no decode (ISSUE 8
                # tick-billed migration)
                self._stall[r] -= 1.0
                continue
            sm = self.slots[r]
            q = self.queues[r]
            while q and sm.free:
                req = q.popleft()
                slot = sm.allocate(req.request_id, req.session, self.now)
                sm.active[slot]["req"] = req
                req.started = self.now
            # decode: each replica advances `speed` tokens per tick *total*,
            # spread round-robin over its active slots; a cursor carries the
            # rotation across passes and ticks so no slot is starved when
            # speed < active slots (only the fractional part of the budget
            # carries across ticks)
            self._token_budget[r] += self.speeds[r]
            budget = int(self._token_budget[r])
            self._token_budget[r] -= budget
            while budget > 0 and sm.active:
                if self.step_fn is not None:
                    self.step_fn(r, list(sm.active.values()))
                ptr = self._next_slot[r]
                order = sorted(sm.active)
                order = [s for s in order if s >= ptr] \
                    + [s for s in order if s < ptr]
                for slot in order:
                    if budget <= 0:
                        break
                    meta = sm.active[slot]
                    meta["tokens"] += 1
                    self.total_tokens += 1
                    budget -= 1
                    self._next_slot[r] = slot + 1
                    req = meta["req"]
                    if meta["tokens"] >= req.target_tokens:
                        req.finished = self.now
                        self.done.append(req)
                        sm.release(slot)
        self._m_in_flight_peak.peak(
            sum(len(self.slots[r].active) for r in self._alive))

    def run(self, until_done: int, max_ticks: int = 100_000) -> None:
        """Tick until ``until_done`` submitted requests are accounted for.
        Shed requests count toward completion (ISSUE 8 satellite): they can
        never reach ``done``, so excluding them would spin the loop to
        ``max_ticks`` whenever admission dropped anything, silently
        inflating reported ticks."""
        t = 0
        while len(self.done) + self.shed < until_done and t < max_ticks:
            self.tick()
            t += 1

    def stall_replica(self, r: int, ticks: float) -> None:
        """Bill migrated-state ingest to replica ``r``: it neither admits
        nor decodes for the next ``ticks`` scheduler ticks (ISSUE 8 — scale
        out genuinely competes with serving bandwidth)."""
        self._stall[r] += float(ticks)

    # -- fault tolerance / elasticity -------------------------------------------
    def fail_replica(self, r: int) -> int:
        """Kill a replica: requeue its in-flight + queued requests via the
        router (consistent-hash remap).  Returns # requests rerouted."""
        self._alive.discard(r)
        moved = 0
        orphans = [m["req"] for m in self.slots[r].active.values()]
        orphans += list(self.queues[r])
        self.queues[r].clear()
        self.slots[r] = SlotManager(self.slots[r].num_slots)
        self._next_slot[r] = 0
        self.router.on_membership_change(sorted(self._alive))
        for req in orphans:
            self.submit(req)
            moved += 1
        return moved

    def add_replica(self, speed: float = 1.0, slots: int = 8) -> int:
        r = self.num_replicas
        self.num_replicas += 1
        self.speeds = np.concatenate([self.speeds, [speed]])
        self._token_budget = np.concatenate([self._token_budget, [0.0]])
        self._stall = np.concatenate([self._stall, [0.0]])
        self._next_slot.append(0)
        self.slots.append(SlotManager(slots))
        self.queues.append(deque())
        self._alive.add(r)
        self.router.on_membership_change(sorted(self._alive))
        # propagate the true capacity (P_w = 1/speed) so Alg. 3 routes to the
        # new replica proportionally to its speed instead of the 1.0 pad;
        # full-weight sample — there is no real prior to average against
        self.router.record_capacity_sample(
            r, 1.0 / max(speed, 1e-9), ema=1.0
        )
        return r

    def set_replica_speed(self, r: int, speed: float) -> None:
        """Mid-run speed change (straggler onset / recovery).  The router
        learns the new capacity through a sample, as it would from the
        periodic Alg. 3 sampling loop."""
        self.speeds[r] = speed
        self.router.record_capacity_sample(r, 1.0 / max(speed, 1e-9))

    # -- metrics ------------------------------------------------------------------
    def metrics(self) -> EngineMetrics:
        lats = np.array([r.finished - r.arrival for r in self.done
                         if r.finished >= 0])
        tiq = np.array([r.started - r.arrival for r in self.done
                        if r.finished >= 0 and r.started >= 0])
        sessions = self.router.replicas
        total_rep = sum(len(v) for v in sessions.values())
        return EngineMetrics(
            latency_avg=float(lats.mean()) if len(lats) else 0.0,
            latency_p50=float(np.percentile(lats, 50)) if len(lats) else 0.0,
            latency_p99=float(np.percentile(lats, 99)) if len(lats) else 0.0,
            throughput_tokens=self.total_tokens / max(self.now, 1.0),
            session_replicas=total_rep,
            session_replicas_norm=total_rep / max(len(sessions), 1),
            dropped=0,
            queue_depth_peak=self.queue_depth_peak,
            in_flight_peak=self.in_flight_peak,
            shed=self.shed,
            time_in_queue_avg=float(tiq.mean()) if len(tiq) else 0.0,
            time_in_queue_p99=(float(np.percentile(tiq, 99))
                               if len(tiq) else 0.0),
        )
