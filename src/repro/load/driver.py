"""Open-loop driver: feed sessions at the arrival schedule (ISSUE 8).

The driver closes the loop between the other three layers: per arrival
tick it (1) offers the tick's arrivals to the bounded
:class:`~repro.load.admission.IngressQueue`, (2) drains the queue into
``session.feed`` **unless** the engine's backlog exceeds the backpressure
threshold (that is what makes the queue fill and the admission policy
engage under overload), and (3) hands the returned
:class:`~repro.topology.engine.FeedReceipt` to the optional
:class:`~repro.load.autoscale.P99Autoscaler`, registering whatever
membership events it emits.

Queueing delay is billed honestly: a record popped at tick end ``t_feed``
is fed with timestamp ``t_feed`` (keeping the session's nondecreasing-
timestamp contract), and its ``t_feed - arrival`` is recorded as
time-in-queue — so *total* latency = time-in-queue + the engine's service
latency, and the two components never double count.  The close-time
:class:`~repro.topology.engine.TopologyReport` is stamped with the
admission accounting (``offered == fed + shed + residual``), the driver's
queue-delay stats and the autoscaler's action log.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..obs.telemetry import Telemetry
from ..topology.engine import TopologyReport
from ..topology.graph import RecordBatch
from .admission import IngressQueue
from .arrivals import ArrivalProcess
from .autoscale import P99Autoscaler

__all__ = ["OpenLoopDriver", "LoadReport"]


@dataclasses.dataclass
class LoadReport:
    """One open-loop run: the stamped close-time topology report plus the
    driver-side latency decomposition.  ``total_latency_*`` (queue delay +
    service latency, per fed tuple) is exact on the DSPE simulator, whose
    receipts return per-tuple service latencies aligned with the feed;
    the serving engine's receipts report finished-request latencies
    (unaligned under open loop), so totals are ``None`` there — read the
    queue-delay stats and the report's e2e columns separately."""

    topology: TopologyReport
    offered: int
    fed: int
    #: total loss = ``shed_ingress`` (bounded ingress queue, never fed) +
    #: ``shed_engine`` (the serving engine's bounded replica queues).  The
    #: two-level identity: ``offered == fed + shed_ingress + residual`` and,
    #: once drained, every fed record is either finished or shed_engine.
    shed: int
    shed_ingress: int
    shed_engine: int
    deferred: int
    residual: int
    queue_depth_peak: int
    queue_delay_avg: float
    queue_delay_p99: float
    total_latency_avg: Optional[float]
    total_latency_p99: Optional[float]
    autoscale_events: List[Dict] = dataclasses.field(default_factory=list)
    # ISSUE 9 telemetry: the driver-side metric timeline (queue depth, shed,
    # backpressure engagements) + metrics snapshot — ``None`` (and omitted
    # from ``to_dict``) whenever telemetry is disabled
    timeline: Optional[Dict] = None

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["topology"] = self.topology.to_dict()
        if d.get("timeline") is None:
            d.pop("timeline", None)
        return d


class OpenLoopDriver:
    """Drive one session at an arrival schedule.

    backpressure: engine-backlog threshold (seconds for the simulator,
                  queued requests for the serving engine — the unit of
                  ``FeedReceipt.backlog``) above which the driver stops
                  draining the ingress queue.  ``None`` never pushes back
                  (the queue only fills if ``feed_chunk`` caps drainage).
    backlog_decay: how fast the last receipt's backlog drains per driver
                  second while the driver is *not* feeding (the engine
                  keeps working).  The default 1.0 is exact for the
                  simulator (backlog is seconds and melts one second per
                  second); for the serving engine pass the pool's
                  aggregate service rate in requests/s.  Without decay a
                  stale over-threshold receipt would gate feeding forever.
    feed_chunk:   max records per feed call (``None``: drain everything
                  admitted each tick).
    """

    def __init__(self, session, queue: IngressQueue,
                 backpressure: Optional[float] = None,
                 backlog_decay: float = 1.0,
                 feed_chunk: Optional[int] = None,
                 autoscaler: Optional[P99Autoscaler] = None):
        self.session = session
        self.queue = queue
        self.backpressure = backpressure
        self.backlog_decay = backlog_decay
        self.feed_chunk = feed_chunk
        self.autoscaler = autoscaler
        self._queue_delays: List[np.ndarray] = []
        self._totals: List[np.ndarray] = []
        self._aligned = True
        self._receipt = None
        self._t_last_feed = 0.0
        # ISSUE 9: share the session's bundle so driver points land on the
        # same trace as the engine's spans (private no-op bundle otherwise)
        tel = getattr(session, "telemetry", None)
        self.tel = tel if tel is not None else Telemetry(enabled=False)
        self._c_bp = self.tel.metrics.counter("load.backpressure_engaged")
        if self.autoscaler is not None and not self.autoscaler.tel.enabled:
            # an autoscaler built without an explicit bundle reports into
            # the session's (same cell, adopted into the session registry)
            self.autoscaler.tel = self.tel
            self.tel.metrics.adopt(self.autoscaler._c_actions)

    # -- one run ---------------------------------------------------------------
    def run(self, arrivals: ArrivalProcess, t0: float, t1: float,
            drain: bool = False) -> LoadReport:
        """Offer arrivals on ``[t0, t1)`` tick by tick, then close.  With
        ``drain=True`` the driver keeps ticking past ``t1`` (no new
        arrivals) until the ingress queue empties — otherwise leftover
        records are reported as ``residual``, never silently dropped."""
        t_feed = t0
        for batch in arrivals.batches(t0, t1):
            t_feed += arrivals.tick
            self.queue.offer(batch.keys, batch.timestamps, batch.values)
            self._step(t_feed)
        if drain:
            while len(self.queue):
                t_feed += arrivals.tick
                self._step(t_feed, force=True)
        return self._close()

    def _step(self, t_feed: float, force: bool = False) -> None:
        """Drain the ingress queue into one feed, unless backpressure.
        The backlog read off the last receipt decays at ``backlog_decay``
        per second of driver time since that feed — the engine does not
        stop working just because the driver stopped feeding.  ``force``
        (the post-arrival drain phase) skips the gate entirely: the run is
        over and the residual is pushed through for accounting."""
        if (not force and self.backpressure is not None
                and self._receipt is not None):
            backlog = (self._receipt.backlog - self.backlog_decay
                       * (t_feed - self._t_last_feed))
            if backlog > self.backpressure:
                # backpressure engaged: the queue keeps filling this tick
                self._c_bp.add(1)
                self.tel.tracer.instant("load.backpressure", cat="load",
                                        backlog=float(backlog),
                                        queued=len(self.queue))
                self.tel.timeline.point("load.queue_depth", len(self.queue),
                                        engine_clock=t_feed)
                return
        chunk = self.feed_chunk or len(self.queue)
        keys, arrivals, values = self.queue.pop(chunk)
        n = keys.shape[0]
        if n == 0:
            return
        ts = np.full(n, t_feed)
        receipt = self.session.feed(RecordBatch(keys, ts, values))
        tl = self.tel.timeline
        tl.point("load.queue_depth", len(self.queue), engine_clock=t_feed)
        tl.point("load.shed_total", self.queue.stats.shed,
                 engine_clock=t_feed)
        self._receipt = receipt
        self._t_last_feed = t_feed
        qd = t_feed - arrivals
        self._queue_delays.append(qd)
        lats = receipt.latencies if receipt is not None else None
        if lats is not None and lats.shape == qd.shape:
            self._totals.append(qd + lats)
        else:  # serving open loop: receipts carry finish-order latencies
            self._aligned = False
        if self.autoscaler is not None and receipt is not None:
            events = self.autoscaler.observe(t_feed, receipt)
            if events:
                self.session.advance(events)

    def _close(self) -> LoadReport:
        run_span = self.tel.tracer.span("load.close", cat="load")
        report = self.session.close()
        run_span.done()
        stats = self.queue.stats
        qd = (np.concatenate(self._queue_delays) if self._queue_delays
              else np.empty(0))
        totals = (np.concatenate(self._totals)
                  if self._aligned and self._totals else None)
        # stamp the open-loop accounting onto the shared report schema
        report.offered = stats.offered
        report.shed += stats.shed  # engine-side shed already aggregated
        report.deferred = stats.deferred
        report.residual = self.queue.residual
        report.queue_depth_peak = max(report.queue_depth_peak,
                                      stats.queue_depth_peak)
        report.time_in_queue_avg = float(qd.mean()) if qd.size else 0.0
        report.time_in_queue_p99 = (float(np.percentile(qd, 99))
                                    if qd.size else 0.0)
        if self.autoscaler is not None:
            report.autoscale_events = list(self.autoscaler.events)
        return LoadReport(
            topology=report,
            offered=stats.offered, fed=stats.fed, shed=report.shed,
            shed_ingress=stats.shed, shed_engine=report.shed - stats.shed,
            deferred=stats.deferred, residual=self.queue.residual,
            queue_depth_peak=report.queue_depth_peak,
            queue_delay_avg=report.time_in_queue_avg,
            queue_delay_p99=report.time_in_queue_p99,
            total_latency_avg=(float(totals.mean())
                               if totals is not None and totals.size
                               else None),
            total_latency_p99=(float(np.percentile(totals, 99))
                               if totals is not None and totals.size
                               else None),
            autoscale_events=report.autoscale_events,
            timeline=self.tel.timeline_dict(),
        )
