"""Open-loop load subsystem (ISSUE 8): arrival processes, admission
control + backpressure, an open-loop session driver, and p99-driven
autoscaling.  See DESIGN.md §13 for the semantics and
``benchmarks/bench_slo.py`` for the headline max-sustainable-load sweep.
"""

from .admission import POLICIES, AdmissionStats, IngressQueue
from .arrivals import (ArrivalProcess, ConstantRate, DiurnalRate,
                       FlashCrowd, FlipZipfKeys, MarkovModulatedRate,
                       RateFn, ZipfKeys)
from .autoscale import P99Autoscaler
from .driver import LoadReport, OpenLoopDriver

__all__ = [
    "POLICIES",
    "AdmissionStats",
    "IngressQueue",
    "ArrivalProcess",
    "ConstantRate",
    "DiurnalRate",
    "FlashCrowd",
    "FlipZipfKeys",
    "MarkovModulatedRate",
    "RateFn",
    "ZipfKeys",
    "P99Autoscaler",
    "LoadReport",
    "OpenLoopDriver",
]
