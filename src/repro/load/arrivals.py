"""Arrival processes for open-loop load generation (ISSUE 8).

Closed-loop sessions feed whenever the engine is ready, so the engine is
never *behind* — the regime where the paper's latency reductions actually
matter (sustained overload, flash crowds) is unreachable.  This module
generates timestamped :class:`~repro.topology.graph.RecordBatch`es on a
fixed tick grid **independent of engine progress**:

* a :class:`RateFn` gives the instantaneous offered rate λ(t) in
  tuples/second.  Rate functions compose multiplicatively (``base * mod``):
  :class:`ConstantRate`, :class:`DiurnalRate` (sinusoid modulation),
  :class:`FlashCrowd` (a transient spike multiplier), and
  :class:`MarkovModulatedRate` (MMPP-style regime switching);
* a key process draws the per-record keys: :class:`ZipfKeys` (steady Zipf,
  optional slow hot-key *rotation* drift) and :class:`FlipZipfKeys` (the
  paper's hot-head flip at a fixed time);
* :class:`ArrivalProcess` ties them together: per tick ``[t, t+Δ)`` it
  draws ``Poisson(λ(t+Δ/2)·Δ)`` arrivals (the standard per-tick
  integration of a nonhomogeneous Poisson process), places them uniformly
  inside the tick, sorts, and emits one batch per tick.

Everything is deterministic given the seed, so closed-loop and open-loop
replays of the same process see bit-identical streams (the ``at_time``
agreement test rides on this).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

from ..data.synthetic import zipf_probs
from ..topology.graph import RecordBatch

__all__ = [
    "RateFn",
    "ConstantRate",
    "DiurnalRate",
    "FlashCrowd",
    "MarkovModulatedRate",
    "ZipfKeys",
    "FlipZipfKeys",
    "ArrivalProcess",
]


class RateFn:
    """Instantaneous offered rate λ(t) ≥ 0 in tuples/second.  Subclasses
    implement ``rate(t)``; ``a * b`` composes pointwise (modulators are
    dimensionless multipliers around 1.0 by convention)."""

    def rate(self, t: float) -> float:
        raise NotImplementedError

    def __call__(self, t: float) -> float:
        return max(self.rate(float(t)), 0.0)

    def __mul__(self, other: "RateFn") -> "RateFn":
        return _ProductRate(self, other)

    __rmul__ = __mul__


class _ProductRate(RateFn):
    def __init__(self, a: RateFn, b: RateFn):
        self.a = a
        self.b = b

    def rate(self, t: float) -> float:
        return self.a(t) * self.b(t)


class ConstantRate(RateFn):
    """λ(t) = rate — homogeneous Poisson arrivals."""

    def __init__(self, rate: float):
        self.base = float(rate)

    def rate(self, t: float) -> float:
        return self.base


class DiurnalRate(RateFn):
    """Sinusoid modulation ``1 + amplitude·sin(2π(t - phase)/period)`` —
    the day/night load swing, compressed to whatever ``period`` the
    experiment runs over.  Use as a multiplier: ``ConstantRate(r) *
    DiurnalRate(amplitude=0.5, period=60.0)``."""

    def __init__(self, amplitude: float = 0.5, period: float = 86_400.0,
                 phase: float = 0.0):
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
        self.amplitude = float(amplitude)
        self.period = float(period)
        self.phase = float(phase)

    def rate(self, t: float) -> float:
        return 1.0 + self.amplitude * np.sin(
            2.0 * np.pi * (t - self.phase) / self.period)


class FlashCrowd(RateFn):
    """A transient spike multiplier: 1 everywhere except ``[at, at +
    duration)`` where the rate ramps linearly to ``magnitude`` over
    ``ramp`` seconds, holds, and ramps back down over the last ``ramp``
    seconds — the retweet-storm shape."""

    def __init__(self, at: float, duration: float, magnitude: float,
                 ramp: float = 0.0):
        if magnitude < 1.0:
            raise ValueError(f"magnitude must be >= 1, got {magnitude}")
        if ramp * 2.0 > duration:
            raise ValueError("2*ramp must fit inside duration")
        self.at = float(at)
        self.duration = float(duration)
        self.magnitude = float(magnitude)
        self.ramp = float(ramp)

    def rate(self, t: float) -> float:
        dt = t - self.at
        if dt < 0.0 or dt >= self.duration:
            return 1.0
        boost = self.magnitude - 1.0
        if self.ramp > 0.0:
            if dt < self.ramp:
                return 1.0 + boost * dt / self.ramp
            if dt > self.duration - self.ramp:
                return 1.0 + boost * (self.duration - dt) / self.ramp
        return self.magnitude


class MarkovModulatedRate(RateFn):
    """MMPP-style regime switching: the rate multiplier holds one of
    ``levels`` for an exponentially-distributed dwell time (mean
    ``mean_dwell`` seconds), then jumps to a uniformly-chosen *other*
    level.  The switch path is pre-sampled lazily from ``seed``, so the
    process is deterministic and extending the horizon never perturbs the
    earlier path."""

    def __init__(self, levels: Sequence[float] = (0.5, 1.0, 2.0),
                 mean_dwell: float = 10.0, seed: int = 0):
        if len(levels) < 2:
            raise ValueError("need at least two levels to switch between")
        self.levels = [float(x) for x in levels]
        self.mean_dwell = float(mean_dwell)
        self._rng = np.random.default_rng(seed)
        self._switch_times: List[float] = [0.0]
        self._states: List[int] = [int(self._rng.integers(len(levels)))]

    def _extend_to(self, t: float) -> None:
        while self._switch_times[-1] <= t:
            self._switch_times.append(
                self._switch_times[-1]
                + float(self._rng.exponential(self.mean_dwell)))
            cur = self._states[-1]
            step = int(self._rng.integers(1, len(self.levels)))
            self._states.append((cur + step) % len(self.levels))

    def rate(self, t: float) -> float:
        self._extend_to(t)
        i = int(np.searchsorted(self._switch_times, t, side="right")) - 1
        return self.levels[self._states[i]]


class ZipfKeys:
    """Zipf(z) key popularity over ``num_keys`` interned ids, with optional
    slow hot-key *rotation* drift: every ``drift_period`` seconds the
    rank→id mapping rotates by ``drift_step`` ids, so the hot head wanders
    through the key space (the paper's time-evolving workload, continuous
    flavour)."""

    def __init__(self, num_keys: int, z: float = 1.2,
                 drift_period: Optional[float] = None, drift_step: int = 1):
        self.num_keys = int(num_keys)
        self.probs = zipf_probs(num_keys, z)
        self.drift_period = drift_period
        self.drift_step = int(drift_step)

    def sample(self, n: int, t: float, rng: np.random.Generator
               ) -> np.ndarray:
        ranks = rng.choice(self.num_keys, size=n, p=self.probs)
        if self.drift_period:
            shift = int(t / self.drift_period) * self.drift_step
            ranks = (ranks + shift) % self.num_keys
        return ranks.astype(np.int32)


class FlipZipfKeys(ZipfKeys):
    """Zipf keys whose hot head flips at ``flip_time``: from then on rank
    ``r`` maps to id ``(r + flip_head) % num_keys`` — the cold tail
    becomes the head instantly, the discrete hot-key flip the scenario
    matrix already exercises closed-loop."""

    def __init__(self, num_keys: int, z: float = 1.2,
                 flip_time: float = 0.0, flip_head: Optional[int] = None):
        super().__init__(num_keys, z)
        self.flip_time = float(flip_time)
        self.flip_head = (int(flip_head) if flip_head is not None
                          else num_keys // 2)

    def sample(self, n: int, t: float, rng: np.random.Generator
               ) -> np.ndarray:
        ranks = rng.choice(self.num_keys, size=n, p=self.probs)
        if t >= self.flip_time:
            ranks = (ranks + self.flip_head) % self.num_keys
        return ranks.astype(np.int32)


@dataclasses.dataclass
class ArrivalProcess:
    """Nonhomogeneous Poisson arrivals on a fixed tick grid.

    ``batches(t0, t1)`` yields one :class:`RecordBatch` per tick ``[t,
    t+tick)`` with ``Poisson(λ(t + tick/2)·tick)`` records timestamped
    uniformly inside the tick (sorted; empty ticks yield empty batches so
    the driver's control loop still runs on schedule).  ``payload=True``
    attaches a standard-normal value column."""

    rate_fn: RateFn
    keys: ZipfKeys
    tick: float = 0.1
    seed: int = 0
    payload: bool = False

    def batches(self, t0: float, t1: float) -> Iterator[RecordBatch]:
        if self.tick <= 0.0:
            raise ValueError(f"tick must be positive, got {self.tick}")
        rng = np.random.default_rng(self.seed)
        t = float(t0)
        while t < t1:
            lam = self.rate_fn(t + self.tick / 2.0) * self.tick
            n = int(rng.poisson(lam))
            ts = np.sort(rng.uniform(t, t + self.tick, size=n))
            ks = self.keys.sample(n, t, rng)
            vals = rng.standard_normal(n) if self.payload else None
            yield RecordBatch(ks, ts, vals)
            t += self.tick

    def offered(self, t0: float, t1: float) -> int:
        """Total records the process offers on ``[t0, t1)`` — same draws
        as ``batches`` (deterministic given the seed)."""
        return sum(len(b) for b in self.batches(t0, t1))
