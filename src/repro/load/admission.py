"""Admission control + backpressure for the open-loop driver (ISSUE 8).

The driver never feeds the engine directly: arrivals land in a **bounded
ingress queue** first, and the driver only drains it while the engine's
backlog is below the backpressure threshold.  When arrivals outrun
drainage the queue fills, and the admission policy decides what happens to
the overflow:

======== ==================================================================
policy   overflow behaviour
======== ==================================================================
shed     drop the newest arrivals (never admitted; counted in ``shed``)
defer    hold them source-side (unbounded spill; they enter the queue as
         capacity frees up — queueing delay grows instead of loss)
degrade  thin the *incoming* tick uniformly to the fraction that fits
         (degrade-to-sample: every admitted record is an unbiased sample
         of the offered stream; the thinned-out remainder counts as shed)
======== ==================================================================

Accounting is exact and closed: ``offered == fed + shed + residual`` at
every instant, where ``residual`` is whatever is still waiting (queue +
spill) — the invariant ``tests/test_load.py`` pins.  Time-in-queue is
billed per record as ``feed_time - arrival`` when the driver pops it.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

__all__ = ["AdmissionStats", "IngressQueue", "POLICIES"]

POLICIES = ("shed", "defer", "degrade")


@dataclasses.dataclass
class AdmissionStats:
    """Cumulative admission accounting (``offered == fed + shed +
    residual`` always — residual is read off the live queue)."""

    offered: int = 0
    fed: int = 0
    shed: int = 0
    deferred: int = 0        # records that ever waited in the spill
    queue_depth_peak: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class IngressQueue:
    """Bounded FIFO of (key, arrival_ts, value) records with a pluggable
    overflow policy.  ``offer`` ingests one arrival tick; ``pop`` drains up
    to ``n`` records for feeding and returns their arrival timestamps so
    the caller can bill time-in-queue."""

    def __init__(self, capacity: int, policy: str = "shed", seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown admission policy {policy!r}; one of {POLICIES}")
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.policy = policy
        self.stats = AdmissionStats()
        self._q: Deque[Tuple[int, float, Optional[float]]] = deque()
        self._spill: Deque[Tuple[int, float, Optional[float]]] = deque()
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self._q) + len(self._spill)

    @property
    def residual(self) -> int:
        return len(self)

    def offer(self, keys: np.ndarray, ts: np.ndarray,
              values: Optional[np.ndarray] = None) -> None:
        """Ingest one arrival tick's records under the admission policy."""
        n = int(keys.shape[0])
        self.stats.offered += n
        if n == 0:
            self._note_depth()
            return
        room = self.capacity - len(self._q)
        if self.policy == "degrade" and n > room:
            # uniform thinning to what fits: admitted records are an
            # unbiased sample of the offered tick
            keep = np.zeros(n, dtype=bool)
            if room > 0:
                keep[self._rng.choice(n, size=room, replace=False)] = True
            self.stats.shed += int(n - keep.sum())
            keys, ts = keys[keep], ts[keep]
            values = None if values is None else values[keep]
            n = int(keys.shape[0])
            room = n
        admit = n if self.policy == "defer" else min(n, max(room, 0))
        for i in range(admit):
            rec = (int(keys[i]), float(ts[i]),
                   None if values is None else float(values[i]))
            if self.policy == "defer" and len(self._q) >= self.capacity:
                self._spill.append(rec)
                self.stats.deferred += 1
            else:
                self._q.append(rec)
        if self.policy == "shed":
            self.stats.shed += n - admit
        self._note_depth()

    def pop(self, n: int):
        """Drain up to ``n`` records (FIFO).  Returns ``(keys, arrivals,
        values)`` arrays — arrivals are the records' original offered
        timestamps, so ``feed_time - arrivals`` is their time in queue.
        Spilled (deferred) records refill the bounded queue as it drains."""
        take = min(n, len(self._q))
        out = [self._q.popleft() for _ in range(take)]
        while self._spill and len(self._q) < self.capacity:
            self._q.append(self._spill.popleft())
        self.stats.fed += take
        keys = np.array([r[0] for r in out], dtype=np.int32)
        arrivals = np.array([r[1] for r in out], dtype=np.float64)
        has_vals = any(r[2] is not None for r in out)
        values = (np.array([r[2] if r[2] is not None else 0.0 for r in out])
                  if has_vals else None)
        return keys, arrivals, values

    def _note_depth(self) -> None:
        depth = len(self)
        if depth > self.stats.queue_depth_peak:
            self.stats.queue_depth_peak = depth

    def check_identity(self) -> bool:
        """The admission identity: offered == fed + shed + residual."""
        s = self.stats
        return s.offered == s.fed + s.shed + self.residual
