"""p99-driven autoscaling over the session event channel (ISSUE 8).

The control law is deliberately boring (it is the *harness*, not the
contribution): a sliding window of per-feed source-edge latencies, two
thresholds, a cooldown.

* **scale out** when the windowed p99 exceeds ``slo_p99`` — add exactly one
  worker, with the next never-used id (replica ids are never reused, and
  the serving engine requires new ids to extend the range contiguously);
* **scale in** when the windowed p99 sits below ``scale_in_frac · slo_p99``
  — retire the highest-id worker, never dropping below the initial pool;
* a ``cooldown`` (engine-clock seconds/ticks) between actions lets the
  previous action's effect reach the window before the next decision —
  without it the scaler oscillates on its own transient.

Membership changes are emitted as timestamp-addressed
:class:`~repro.core.stream.MembershipEvent`s (``at_time``) scoped to the
watched stage, so they fire at the next fed tuple — exactly the semantics
a closed-loop replay of the same schedule reproduces.  The worker set is
mirrored into a :class:`~repro.runtime.elastic.ElasticPool` (PR-2 control
plane), whose consistent-hash ring quantifies how many keys each action
remaps; the keyed-state migration that remap implies is billed to the
destination workers' engine clock by the engines themselves
(``migration_cost_per_byte`` / ``migration_ticks_per_byte``).
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.stream import MembershipEvent, at_time
from ..obs.telemetry import Telemetry
from ..runtime.elastic import ElasticPool
from ..topology.graph import ScopedEvent

__all__ = ["P99Autoscaler"]

_NULL_TELEMETRY = Telemetry(enabled=False)


class P99Autoscaler:
    """Watches :class:`~repro.topology.engine.FeedReceipt`s and emits
    membership events for ``stage`` when the sliding-window p99 crosses the
    SLO.  ``observe`` returns the events to register via
    ``session.advance`` (empty list: no action)."""

    def __init__(self, stage: str, slo_p99: float, workers: Sequence[int],
                 max_workers: int, window: float = 5.0,
                 cooldown: float = 5.0, scale_in_frac: float = 0.3,
                 min_samples: int = 64,
                 pool: Optional[ElasticPool] = None,
                 sample_keys: Sequence = (),
                 telemetry: Optional[Telemetry] = None):
        if slo_p99 <= 0.0:
            raise ValueError(f"slo_p99 must be positive, got {slo_p99}")
        self.stage = stage
        self.slo_p99 = float(slo_p99)
        self.workers = sorted(int(w) for w in workers)
        self.min_workers = len(self.workers)
        self.max_workers = int(max_workers)
        self.window = float(window)
        self.cooldown = float(cooldown)
        self.scale_in_frac = float(scale_in_frac)
        self.min_samples = int(min_samples)
        self.pool = pool if pool is not None else ElasticPool(self.workers)
        self.sample_keys = list(sample_keys)
        self._next_id = max(self.workers) + 1
        self._hist: Deque[Tuple[float, np.ndarray]] = deque()
        self._last_action = -np.inf
        self.events: List[Dict] = []
        # ISSUE 9: each action lands as a trace instant + timeline points;
        # the driver passes its session's bundle (no-op when disabled)
        self.tel = telemetry if telemetry is not None else _NULL_TELEMETRY
        self._c_actions = self.tel.metrics.counter("autoscale.actions")

    # -- control loop ---------------------------------------------------------
    def observe(self, t: float, receipt) -> List[ScopedEvent]:
        """Fold one feed's latencies into the window; decide at ``t``."""
        lats = getattr(receipt, "latencies", None)
        if lats is not None and lats.size:
            self._hist.append((float(t), lats))
        while self._hist and self._hist[0][0] < t - self.window:
            self._hist.popleft()
        p99 = self.window_p99()
        if p99 is None or t - self._last_action < self.cooldown:
            return []
        if p99 > self.slo_p99 and len(self.workers) < self.max_workers:
            return [self._scale_out(t, p99)]
        if (p99 < self.scale_in_frac * self.slo_p99
                and len(self.workers) > self.min_workers):
            return [self._scale_in(t, p99)]
        return []

    def window_p99(self) -> Optional[float]:
        """p99 over the sliding window (``None`` until ``min_samples``
        latencies have been seen — don't scale on noise)."""
        if not self._hist:
            return None
        lats = np.concatenate([h[1] for h in self._hist])
        if lats.size < self.min_samples:
            return None
        return float(np.percentile(lats, 99))

    # -- actions --------------------------------------------------------------
    def _scale_out(self, t: float, p99: float) -> ScopedEvent:
        new = self._next_id
        self._next_id += 1
        self.workers = sorted(self.workers + [new])
        moved = self.pool.add_host(new, self.sample_keys)
        return self._emit(t, p99, "scale_out", new, moved)

    def _scale_in(self, t: float, p99: float) -> ScopedEvent:
        gone = self.workers[-1]  # retire the highest id
        self.workers = self.workers[:-1]
        moved = self.pool.remove_host(gone, self.sample_keys)
        return self._emit(t, p99, "scale_in", gone, moved)

    def _emit(self, t: float, p99: float, action: str, worker: int,
              moved: int) -> ScopedEvent:
        self._last_action = t
        self._hist.clear()  # stale latencies predate the new pool
        self.events.append({
            "t": float(t), "action": action, "worker": int(worker),
            "workers": list(self.workers), "p99": float(p99),
            "slo_p99": self.slo_p99,
            "ring_moved": int(moved), "ring_sampled": len(self.sample_keys),
        })
        self._c_actions.add(1)
        self.tel.tracer.instant(
            f"autoscale.{action}", cat="load", worker=int(worker),
            workers=len(self.workers), p99=float(p99), ring_moved=int(moved))
        tl = self.tel.timeline
        tl.point("autoscale.workers", len(self.workers), engine_clock=t)
        tl.point("autoscale.window_p99", p99, engine_clock=t)
        return ScopedEvent(self.stage, at_time(
            MembershipEvent(workers=tuple(self.workers)), t))
