"""Production mesh construction.

Importing this module never touches jax device state —
:func:`make_production_mesh` is a function, called only by launchers (the
dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
any jax import; see launch/dryrun.py).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["make_production_mesh", "mesh_axes", "dp_axes"]


def mesh_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def dp_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = mesh_axes(multi_pod)
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "launch via repro.launch.dryrun (sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512) or on a "
            "real slice"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
