"""Step functions + ShapeDtypeStruct input specs for every (arch × shape).

``input_specs(cfg, shape)`` returns stand-ins for every model input — weak-
type-correct, shardable, no device allocation — and matching PartitionSpecs.
``train_step`` / ``prefill_step`` / ``serve_step`` are the functions the
dry-run lowers and the real launchers run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import transformer as T
from ..models.sharding import ShardingRules, param_specs, set_rules
from ..optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state

__all__ = ["input_specs", "make_train_step", "make_prefill_step",
           "make_serve_step", "abstract_train_state", "abstract_cache",
           "batch_pspecs", "cache_pspecs"]


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.dtype(cfg.dtype)
    sd = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.embeds_input:
            batch["embeds"] = sd((b, s, cfg.d_model), bf16)
        else:
            batch["tokens"] = sd((b, s), i32)
        if shape.kind == "train":
            batch["labels"] = sd((b, s), i32)
        if cfg.rope_kind == "mrope":
            batch["positions"] = sd((3, b, s), i32)
        if cfg.encoder_layers:
            batch["enc_embeds"] = sd((b, cfg.encoder_seq, cfg.d_model), bf16)
        return batch

    # decode: one new token against a seq_len cache
    batch = {"tokens": sd((b, 1), i32)}
    if cfg.embeds_input:
        batch["embeds"] = sd((b, 1, cfg.d_model), bf16)
    return batch


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig,
                 rules: ShardingRules) -> Dict[str, Any]:
    dp = rules.dp
    b = shape.global_batch
    # batch too small to shard (long_500k) -> replicate
    dpb = dp if b >= 32 else None

    specs: Dict[str, Any] = {}
    for k in ("tokens", "labels"):
        specs[k] = P(dpb, None)
    specs["embeds"] = P(dpb, None, None)
    specs["positions"] = P(None, dpb, None)
    specs["enc_embeds"] = P(dpb, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, cache, shape: ShapeConfig,
                 rules: ShardingRules):
    """PartitionSpecs for the decode cache tree.

    Batch shards over dp; long KV seq dims shard over tp when the batch is
    too small to fill dp (long_500k) we replicate (caches there are O(state),
    not O(seq), for the sub-quadratic archs).
    """
    dp, tp = rules.dp, rules.tp
    b = shape.global_batch
    use_dp = b >= 32

    def spec_for(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nd = leaf.ndim
        if name.endswith("pos") or nd == 0:
            return P()
        if not use_dp:
            return P(*([None] * nd))
        # find the batch dim: stacked caches are (L, B, S, ...) or
        # (G, P, B, S, ...) / states (L, B, ...); prefix caches (B, S, ...)
        if nd >= 4 and leaf.shape[-2] == cfg.num_kv_heads:
            # attention kv cache (..., B, S, Hkv, dh)
            axes = [None] * nd
            axes[-4] = dp
            if leaf.shape[-3] >= 4096:
                axes[-3] = tp  # long cache: shard seq over model
            return P(*axes)
        if nd >= 3 and leaf.shape[-1] in (
            getattr(cfg.mla, "kv_lora_rank", -1) if cfg.mla else -1,
            getattr(cfg.mla, "qk_rope_dim", -1) if cfg.mla else -1,
        ):
            # MLA latent cache (..., B, S, R)
            axes = [None] * nd
            axes[-3] = dp
            if leaf.shape[-2] >= 4096:
                axes[-2] = tp
            return P(*axes)
        # state caches (L, B, ...) or (B, ...): shard the batch dim
        axes = [None] * nd
        for i, d in enumerate(leaf.shape):
            if d == shape.global_batch:
                axes[i] = dp
                break
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


# ---------------------------------------------------------------------------
# Abstract state builders (dry-run: eval_shape only)
# ---------------------------------------------------------------------------


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig):
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: T.init_params(cfg, k), key)
    opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
    hot = T.init_hotness_state(cfg)
    hot = jax.eval_shape(lambda: hot) if hot is not None else None
    return params, opt, hot


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def _split_micro(batch, n: int):
    """Reshape every batch leaf to (n, B/n, ...); positions split on axis 1."""
    def split(k, x):
        axis = 1 if k == "positions" else 0
        b = x.shape[axis]
        assert b % n == 0, f"batch {b} not divisible by grad_accum {n}"
        new_shape = x.shape[:axis] + (n, b // n) + x.shape[axis + 1:]
        x = x.reshape(new_shape)
        return jnp.moveaxis(x, axis, 0)

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    rules: Optional[ShardingRules]):
    """train_step with optional gradient accumulation (cfg.grad_accum).

    With accumulation, the FISH hotness epoch becomes the *microbatch*
    (Alg. 1's epoch = a bounded tuple count — the decay cadence follows it).
    """
    n_micro = max(cfg.grad_accum, 1)

    def train_step(params, opt_state: OptState, hotness, batch):
        with set_rules(rules):
            def loss_fn(p, mb, hot):
                loss, out = T.forward_train(p, mb, cfg, hot)
                return loss, out

            def constrain_grads(g):
                # pin grads to the param shardings so ZeRO weight-gather
                # backward lowers to reduce-scatter, not all-reduce (§Perf)
                if rules is None:
                    return g
                gspecs = param_specs(g, rules)
                return jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, g, gspecs)

            if n_micro == 1:
                (loss, out), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch, hotness)
                grads = constrain_grads(grads)
                hot_new = out["new_hotness"]
                ce, aux = out["ce_loss"], out["aux_loss"]
            else:
                micro = _split_micro(batch, n_micro)

                def body(carry, mb):
                    gsum, hot, loss_s, ce_s, aux_s = carry
                    (l, out), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb, hot)
                    g = constrain_grads(g)
                    gsum = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(a.dtype), gsum, g)
                    hot = (out["new_hotness"] if hot is not None else None)
                    return (gsum, hot, loss_s + l, ce_s + out["ce_loss"],
                            aux_s + out["aux_loss"]), None

                gsum0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params)
                z = jnp.float32(0.0)
                (gsum, hot_new, loss, ce, aux), _ = jax.lax.scan(
                    body, (gsum0, hotness, z, z, z), micro)
                grads = jax.tree_util.tree_map(
                    lambda g: g / n_micro, gsum)
                loss, ce, aux = loss / n_micro, ce / n_micro, aux / n_micro

            new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                                   opt_cfg)
        metrics = {"loss": loss, "ce_loss": ce, "aux_loss": aux, **om}
        return new_params, new_opt, hot_new, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: Optional[ShardingRules]):
    def prefill_step(params, batch):
        with set_rules(rules):
            return T.prefill(params, batch, cfg)

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules: Optional[ShardingRules]):
    def serve_step(params, cache, batch):
        with set_rules(rules):
            logits, new_cache = T.decode_step(
                params, cache, batch["tokens"], cfg,
                embeds=batch.get("embeds"),
            )
        return logits, new_cache

    return serve_step
