"""Serving driver: FISH-routed continuous batching over model replicas.

Each replica holds a reduced model + batched KV cache; the engine routes
requests by session key (FISH: CHK replication for hot sessions + Alg. 3
inferred-backlog replica choice + consistent hashing under failures) and
drives real ``decode_step`` calls per tick.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 64 --replicas 2
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs, reduced_config
from ..models import transformer as T
from ..serving.engine import Request, ServingEngine

__all__ = ["ModelReplica", "main"]


class ModelReplica:
    """One replica: params + batched decode cache + jitted decode_step."""

    def __init__(self, cfg, params, num_slots: int, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.cache = T.init_cache(cfg, num_slots, max_seq)
        self.cache["pos"] = jnp.int32(-1)
        self.tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self._step = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
        self.tokens_generated = 0

    def step(self) -> None:
        logits, self.cache = self._step(self.params, self.cache, self.tokens)
        nxt = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
        self.tokens = nxt[:, None].astype(jnp.int32)
        self.tokens_generated += self.tokens.shape[0]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--grouping", default="fish")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if cfg.embeds_input or cfg.encoder_layers:
        raise SystemExit(f"{args.arch}: serving driver supports token-input "
                         "decoders; use the engine simulation for "
                         "frontend-stub archs")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    replicas = [ModelReplica(cfg, params, args.slots, args.max_seq)
                for _ in range(args.replicas)]

    def step_fn(replica_idx: int, active_slots) -> None:
        replicas[replica_idx].step()

    eng = ServingEngine(num_replicas=args.replicas,
                        slots_per_replica=args.slots,
                        grouping=args.grouping, step_fn=step_fn)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        sess = f"hot{rng.integers(0, 3)}" if rng.random() < 0.7 \
            else f"cold{rng.integers(0, 50)}"
        eng.submit(Request(i, sess, arrival=float(i) * 0.25,
                           target_tokens=int(rng.integers(4, 16))))
    eng.run(until_done=args.requests)
    m = eng.metrics()
    total_model_tokens = sum(r.tokens_generated for r in replicas)
    print(f"served {len(eng.done)} requests | p50={m.latency_p50:.1f} "
          f"p99={m.latency_p99:.1f} ticks | {m.throughput_tokens:.2f} "
          f"tok/tick | session replication {m.session_replicas_norm:.2f}x | "
          f"model decode calls produced {total_model_tokens} tokens")


if __name__ == "__main__":
    main()
