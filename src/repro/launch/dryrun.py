import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Two passes per cell:

1. **Compile pass** (the deliverable): jit with the production mesh's
   in/out shardings, ``.lower().compile()`` must succeed.  From the compiled
   artifact we record ``memory_analysis()`` (per-device bytes — proves the
   cell fits a 16 GB v5e chip) and the SPMD-partitioned HLO, from which
   per-device collective bytes are summed with **while-loop expansion**
   (HLO text reports each scanned layer's collectives once; we multiply by
   the loop trip count parsed from the loop condition).

2. **Costing pass** (single-pod cells): the same step is re-lowered with
   ``cfg.cost_exact=True`` — every scan unrolled, attention un-blocked,
   kernels in reference form — so ``lowered.cost_analysis()`` reports exact
   *global* HLO FLOPs / bytes (XLA's HloCostAnalysis counts while bodies
   once, verified empirically; unrolling removes the distortion).

Roofline terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

    compute_s    = HLO_FLOPs_global / (chips × peak)
    memory_s     = HLO_bytes_global / (chips × HBM_bw)   [unfused upper bound]
    collective_s = per_device_collective_bytes / link_bw

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k [--multi-pod] [--no-cost]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config, get_shape, list_archs
from ..models import transformer as T
from ..models.sharding import ShardingRules, param_specs
from ..optim.adamw import AdamWConfig, init_opt_state
from . import steps as S
from .mesh import dp_axes, make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12         # bf16
HBM_BW = 819e9              # bytes/s
ICI_BW = 50e9               # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# HLO computation-graph walk with while-loop trip expansion
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur_name = m.group(1)
            cur_lines = []
        elif line.strip() == "}" and cur_name:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
        elif cur_name:
            cur_lines.append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                return m.group(1)
    return None


def _direct_collectives(block: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for line in block.splitlines():
        low = line.lower()
        for kind in _KINDS:
            # count -start (async) or the plain op; skip -done (same buffer)
            token = f" {kind}(" if f" {kind}(" in low else (
                f" {kind}-start(" if f" {kind}-start(" in low else None)
            if token is None:
                continue
            head = line.split("=", 1)
            if len(head) != 2:
                continue
            result_type = head[1].split(kind)[0]
            out[kind] = out.get(kind, 0) + _shape_bytes(result_type)
            break
    return out


def collective_bytes_expanded(hlo: str) -> Dict[str, int]:
    """Per-device collective bytes with while-loop trip multiplication."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)

    def trip_count(cond_name: str) -> int:
        block = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST_RE.findall(block)]
        return max(consts) if consts else 1

    def walk(name: str, mult: int, seen) -> Dict[str, int]:
        if name not in comps or mult <= 0:
            return {}
        block = comps[name]
        acc = {k: v * mult for k, v in _direct_collectives(block).items()}
        for m in _WHILE_RE.finditer(block):
            cond, body = m.group(1), m.group(2)
            t = trip_count(cond)
            sub = walk(body, mult * t, seen)
            for k, v in sub.items():
                acc[k] = acc.get(k, 0) + v
        # follow calls / conditionals (collectives inside fusions don't exist)
        for cm in re.finditer(r"(?:call|conditional)\(.*?to_apply=%?([\w\.\-]+)",
                              block):
            sub = walk(cm.group(1), mult, seen)
            for k, v in sub.items():
                acc[k] = acc.get(k, 0) + v
        return acc

    if entry is None:
        return {}
    return walk(entry, 1, set())


def _analytic_param_bytes(params) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(params)
    )


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def _build_lowerable(cfg, shape, rules, mesh, opt_cfg):
    """Returns (jitted, args) for the cell's step on the given mesh."""
    params = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    pspecs = param_specs(params, rules)
    ns = lambda spec: NamedSharding(mesh, spec)
    pshard = jax.tree_util.tree_map(ns, pspecs)
    batch = S.input_specs(cfg, shape)
    bspecs = S.batch_pspecs(cfg, shape, rules)
    bshard = {k: ns(bspecs[k]) for k in batch}

    if shape.kind == "train":
        from ..optim.adamw import opt_state_specs

        opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
        m_specs, v_specs = opt_state_specs(params, pspecs, opt_cfg)
        opt_shard = type(opt)(
            step=ns(P()),
            m=jax.tree_util.tree_map(ns, m_specs),
            v=jax.tree_util.tree_map(ns, v_specs),
        )
        hot = T.init_hotness_state(cfg)
        hot_abs = jax.eval_shape(lambda: hot) if hot is not None else None
        hot_shard = ns(P(None, None)) if hot is not None else None
        step_fn = S.make_train_step(cfg, opt_cfg, rules)
        jitted = jax.jit(
            step_fn,
            in_shardings=(pshard, opt_shard, hot_shard, bshard),
            out_shardings=(pshard, opt_shard, hot_shard, ns(P())),
            donate_argnums=(0, 1),
        )
        return jitted, (params, opt, hot_abs, batch)
    if shape.kind == "prefill":
        cache_abs = S.abstract_cache(cfg, shape)
        cspecs = S.cache_pspecs(cfg, cache_abs, shape, rules)
        cshard = jax.tree_util.tree_map(ns, cspecs)
        step_fn = S.make_prefill_step(cfg, rules)
        jitted = jax.jit(step_fn, in_shardings=(pshard, bshard),
                         out_shardings=(cshard, ns(P())))
        return jitted, (params, batch)
    # decode
    cache_abs = S.abstract_cache(cfg, shape)
    cspecs = S.cache_pspecs(cfg, cache_abs, shape, rules)
    cshard = jax.tree_util.tree_map(ns, cspecs)
    step_fn = S.make_serve_step(cfg, rules)
    jitted = jax.jit(step_fn, in_shardings=(pshard, cshard, bshard),
                     out_shardings=(ns(P()), cshard), donate_argnums=(1,))
    return jitted, (params, cache_abs, batch)


def _exact_cost(cfg, shape, opt_cfg) -> Dict[str, float]:
    """Global HLO FLOPs/bytes via an unrolled, unsharded lowering."""
    cfg_x = dataclasses.replace(cfg, cost_exact=True, remat=False,
                                grad_accum=1)
    params = jax.eval_shape(lambda k: T.init_params(cfg_x, k),
                            jax.random.PRNGKey(0))
    batch = S.input_specs(cfg_x, shape)
    if shape.kind == "train":
        opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
        hot = T.init_hotness_state(cfg_x)
        hot_abs = jax.eval_shape(lambda: hot) if hot is not None else None
        step_fn = S.make_train_step(cfg_x, opt_cfg, None)
        lowered = jax.jit(step_fn).lower(params, opt, hot_abs, batch)
    elif shape.kind == "prefill":
        lowered = jax.jit(S.make_prefill_step(cfg_x, None)).lower(params, batch)
    else:
        cache = jax.eval_shape(
            lambda: T.init_cache(cfg_x, shape.global_batch, shape.seq_len))
        lowered = jax.jit(S.make_serve_step(cfg_x, None)).lower(
            params, cache, batch)
    ca = lowered.cost_analysis() or {}
    return {
        "flops_global": float(ca.get("flops", 0.0)),
        "bytes_global": float(ca.get("bytes accessed", 0.0)),
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True, extra_tag: str = "", with_cost: bool = True,
             cfg_override=None) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape):
        result = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "skipped",
            "reason": "long_500k requires sub-quadratic attention "
                      "(DESIGN.md §5)",
        }
        if save:
            _save(result, extra_tag)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(dp=dp_axes(multi_pod), tp="model",
                          zero=cfg.zero_sharding)
    opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype,
                          factored_v=cfg.opt_factored)

    t0 = time.time()
    with mesh:
        jitted, args = _build_lowerable(cfg, shape, rules, mesh, opt_cfg)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()

    n_dev = mesh.devices.size
    colls = collective_bytes_expanded(hlo)
    coll_total = sum(colls.values())

    mem_dict = {}
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes"):
            mem_dict[attr] = getattr(mem, attr, None)

    params_abs = args[0]
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok",
        "devices": int(n_dev),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "param_bytes_global": _analytic_param_bytes(params_abs),
        "collective_bytes_per_device": colls,
        "collective_bytes_total": coll_total,
        "memory_analysis": mem_dict,
    }

    if with_cost and not multi_pod:
        cost = _exact_cost(cfg, shape, opt_cfg)
        result.update(cost)
        flops, bts = cost["flops_global"], cost["bytes_global"]
        result["roofline"] = {
            "compute_s": flops / (n_dev * PEAK_FLOPS) if flops else 0.0,
            "memory_s": bts / (n_dev * HBM_BW) if bts else 0.0,
            "collective_s": coll_total / ICI_BW,
        }
    if save:
        _save(result, extra_tag)
    return result


def _save(result: Dict[str, Any], extra_tag: str = "") -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    tag = "multipod" if result["multi_pod"] else "singlepod"
    if extra_tag:
        tag += f"_{extra_tag}"
    path = os.path.join(
        ARTIFACT_DIR, f"{result['arch']}_{result['shape']}_{tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        try:
            t0 = time.time()
            r = run_cell(arch, shape, multi_pod=args.multi_pod,
                         with_cost=not args.no_cost)
            if r["status"] == "ok":
                rf = r.get("roofline", {})
                ma = r["memory_analysis"] or {}
                tmp = (ma.get("temp_size_in_bytes") or 0) / 2**30
                arg = (ma.get("argument_size_in_bytes") or 0) / 2**30
                print(f"[ok] {arch} {shape} "
                      f"({'2x16x16' if args.multi_pod else '16x16'}) "
                      f"compile={r['compile_s']}s "
                      f"mem: args={arg:.2f}GiB temp={tmp:.2f}GiB "
                      f"coll/dev={r['collective_bytes_total']/2**30:.3f}GiB "
                      + (f"flops={r.get('flops_global', 0):.3e} "
                         f"terms(c/m/n)={rf.get('compute_s', 0):.4f}/"
                         f"{rf.get('memory_s', 0):.4f}/"
                         f"{rf.get('collective_s', 0):.4f}s"
                         if rf else ""),
                      flush=True)
            else:
                print(f"[skip] {arch} {shape}: {r['reason']}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"[FAIL] {arch} {shape}: {type(e).__name__}: {e}",
                  flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
