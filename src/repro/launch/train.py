"""End-to-end training driver.

Runs real steps on the available devices (CPU here; the same code path jits
onto a TPU slice via ``make_production_mesh``), with:

* FISH-grouped streaming data pipeline feeding batches,
* fault-tolerant checkpoint/restore (auto-resume from the latest commit),
* straggler mitigation + heartbeat monitoring wired into the step loop,
* MoE FISH hotness carried through the train state.

Usage (small configs train end-to-end on CPU)::

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..checkpointing import checkpoint as ckpt
from ..configs import get_config, list_archs, reduced_config
from ..core.fish import FishParams
from ..data.pipeline import StreamingPipeline
from ..data.synthetic import token_stream
from ..models import transformer as T
from ..optim.adamw import AdamWConfig, init_opt_state
from ..runtime.stragglers import StragglerMitigator
from . import steps as S

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    def __init__(self, cfg, opt_cfg: AdamWConfig, *, batch: int, seq: int,
                 ckpt_dir: Optional[str] = None, num_hosts: int = 4,
                 grouping: str = "fish", seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.batch, self.seq = batch, seq
        self.ckpt_dir = ckpt_dir
        key = jax.random.PRNGKey(seed)
        self.params = T.init_params(cfg, key)
        self.opt_state = init_opt_state(self.params, opt_cfg)
        self.hotness = T.init_hotness_state(cfg)
        self.step = 0

        assert batch % num_hosts == 0
        self.pipeline = StreamingPipeline(
            num_hosts=num_hosts, seq_len=seq, batch_per_host=batch // num_hosts,
            grouping=grouping, fish_params=FishParams(epoch=1000, k_max=512),
        )
        self.stragglers = StragglerMitigator(num_hosts)
        self._step_fn = jax.jit(S.make_train_step(cfg, opt_cfg, rules=None),
                                donate_argnums=(0, 1))
        self._stream = token_stream(
            10**9, num_keys=20_000, doc_len=seq // 2,
            vocab_size=cfg.vocab_size, z=1.2, phases=6, seed=seed,
        )

    # -- fault tolerance ---------------------------------------------------------
    def maybe_restore(self) -> bool:
        if not self.ckpt_dir:
            return False
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state,
                "hotness": self.hotness}
        restored, step = ckpt.restore(self.ckpt_dir, tree)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.hotness = restored["hotness"]
        self.step = step
        return True

    def save(self) -> None:
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, self.step,
                      {"params": self.params, "opt": self.opt_state,
                       "hotness": self.hotness})

    # -- data --------------------------------------------------------------------
    def next_batch(self):
        b = self.pipeline.next_global_batch()
        while b is None:
            for _ in range(64):  # ingest in chunks, steal fills the rest
                key, toks = next(self._stream)
                self.pipeline.ingest(key, toks)
            b = self.pipeline.next_global_batch()
        return {k: jnp.asarray(v) for k, v in b.items()}

    # -- loop --------------------------------------------------------------------
    def run(self, num_steps: int, *, ckpt_every: int = 50,
            log_every: int = 10) -> list:
        history = []
        for _ in range(num_steps):
            batch = self.next_batch()
            t0 = time.time()
            self.params, self.opt_state, self.hotness, metrics = self._step_fn(
                self.params, self.opt_state, self.hotness, batch)
            dt = time.time() - t0
            self.step += 1
            loss = float(metrics["loss"])
            history.append(loss)
            for h in range(self.stragglers.est.num_workers):
                self.stragglers.record_step_time(h, dt / max(self.batch, 1))
            if self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if ckpt_every and self.step % ckpt_every == 0:
                self.save()
        return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grouping", default="fish")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    cfg = dataclasses.replace(cfg, grad_accum=1)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=max(args.steps, 100),
                          state_dtype=cfg.opt_state_dtype,
                          factored_v=cfg.opt_factored)
    loop = TrainLoop(cfg, opt_cfg, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, grouping=args.grouping)
    if args.resume and loop.maybe_restore():
        print(f"resumed from step {loop.step}")
    hist = loop.run(args.steps)
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f})")
    loop.save()


if __name__ == "__main__":
    main()
