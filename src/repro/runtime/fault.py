"""Fault tolerance: heartbeat failure detection + restart policy.

This is the control plane a multi-pod deployment runs next to the training
loop.  It is exercised in simulation (tests + examples): a
:class:`HeartbeatMonitor` tracks per-host heartbeats on a logical clock,
declares hosts dead after ``timeout`` missed intervals, and the
:class:`RestartPolicy` decides between (a) elastic continue (drop the host,
rescale via consistent hashing) and (b) checkpoint restart (when too many
hosts died or a non-recoverable component failed).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set

__all__ = ["HeartbeatMonitor", "RestartPolicy", "FaultEvent"]


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    time: float
    kind: str              # "host_dead" | "host_joined" | "restart"
    host: Optional[int] = None
    detail: str = ""


class HeartbeatMonitor:
    """Logical-clock heartbeat tracking (paper-style periodic sampling)."""

    def __init__(self, hosts: Sequence[int], timeout: float = 30.0):
        self.timeout = timeout
        self.last_seen: Dict[int, float] = {h: 0.0 for h in hosts}
        self.dead: Set[int] = set()
        self.events: List[FaultEvent] = []

    def heartbeat(self, host: int, now: float) -> None:
        if host in self.dead:
            self.dead.discard(host)
            self.events.append(FaultEvent(now, "host_joined", host))
        self.last_seen[host] = now

    def check(self, now: float) -> List[int]:
        """Returns hosts newly declared dead at ``now``."""
        newly = []
        for h, t in self.last_seen.items():
            if h not in self.dead and now - t > self.timeout:
                self.dead.add(h)
                newly.append(h)
                self.events.append(FaultEvent(now, "host_dead", h))
        return newly

    def alive(self) -> List[int]:
        return sorted(h for h in self.last_seen if h not in self.dead)


class RestartPolicy:
    """Decide elastic-continue vs checkpoint-restart on failures.

    * fewer than ``max_lost_frac`` of hosts lost  -> elastic continue
      (consistent-hash remap keeps most key->host state, paper §5);
    * otherwise -> restore from the last committed checkpoint.
    """

    def __init__(
        self,
        total_hosts: int,
        max_lost_frac: float = 0.25,
        on_rescale: Optional[Callable[[List[int]], None]] = None,
        on_restart: Optional[Callable[[], int]] = None,
    ):
        self.total = total_hosts
        self.max_lost_frac = max_lost_frac
        self.on_rescale = on_rescale
        self.on_restart = on_restart
        self.restarts = 0
        self.rescales = 0

    def handle(self, monitor: HeartbeatMonitor, now: float) -> str:
        alive = monitor.alive()
        lost = self.total - len(alive)
        if lost == 0:
            return "healthy"
        if lost / self.total <= self.max_lost_frac:
            self.rescales += 1
            if self.on_rescale:
                self.on_rescale(alive)
            monitor.events.append(
                FaultEvent(now, "restart", None,
                           f"elastic continue with {len(alive)} hosts")
            )
            return "rescaled"
        self.restarts += 1
        if self.on_restart:
            self.on_restart()
        monitor.events.append(
            FaultEvent(now, "restart", None, "checkpoint restart")
        )
        return "restarted"
