"""Elastic worker membership on a consistent-hash ring (paper §5).

Tracks the active host set for the data pipeline / serving router and
quantifies remap cost when membership changes — the paper's Fig. 17
experiment is the benchmark over this module.
"""

from __future__ import annotations

from typing import Dict, Hashable, Iterable, List, Tuple

from ..core.chash import ConsistentHashRing

__all__ = ["ElasticPool"]


class ElasticPool:
    def __init__(self, hosts: Iterable[int], virtual_nodes: int = 64):
        self.ring = ConsistentHashRing(hosts, virtual_nodes=virtual_nodes)
        self.remap_log: List[Tuple[str, int, int]] = []  # (op, host, moved)

    @property
    def hosts(self) -> List[int]:
        return sorted(self.ring.workers)

    def owner(self, key) -> int:
        return self.ring.lookup(key)

    def add_host(self, host: int, sample_keys: Iterable = ()) -> int:
        """Add a host; returns how many of ``sample_keys`` moved."""
        before = {k: self.ring.lookup(k) for k in sample_keys}
        self.ring.add_worker(host)
        moved = sum(1 for k, o in before.items() if self.ring.lookup(k) != o)
        self.remap_log.append(("add", host, moved))
        return moved

    def remove_host(self, host: int, sample_keys: Iterable = ()) -> int:
        before = {k: self.ring.lookup(k) for k in sample_keys}
        self.ring.remove_worker(host)
        moved = sum(1 for k, o in before.items() if self.ring.lookup(k) != o)
        self.remap_log.append(("remove", host, moved))
        return moved
