"""Straggler mitigation via the paper's Alg. 3 state inference.

Instead of synchronising on the slowest host (or polling host queues), the
coordinator *infers* each host's backlog from what it already knows — how
much work it sent and the host's sampled speed (Eq. 1) — and rebalances the
next step's work shares toward the hosts with the least estimated waiting
time (Eq. 2).  ``shares()`` returns per-host work fractions the data
pipeline / batch assembler applies.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.assignment import WorkerStateEstimator

__all__ = ["StragglerMitigator"]


class StragglerMitigator:
    def __init__(self, num_hosts: int, interval: float = 10.0,
                 min_share: float = 0.25):
        self.est = WorkerStateEstimator(np.ones(num_hosts), interval=interval)
        self.min_share = min_share

    def ensure_hosts(self, num_hosts: int) -> None:
        """Grow the estimator arrays for scale-out (host ids never reused)."""
        self.est.ensure_size(num_hosts)

    def record_step_time(self, host: int, seconds_per_item: float) -> None:
        self.est.record_capacity_sample(host, seconds_per_item)

    def record_assigned(self, host: int, items: int) -> None:
        self.est.assigned[host] += items

    def tick(self, now: float) -> None:
        self.est.maybe_estimate(now)

    def waits(self) -> np.ndarray:
        """Estimated waiting time per host (Eq. 2)."""
        return (self.est.backlog + self.est.assigned) * self.est.capacities

    def shares(self) -> np.ndarray:
        """Work fractions inversely proportional to estimated wait+speed."""
        # effective service rate net of backlog
        rate = 1.0 / np.maximum(self.est.capacities, 1e-9)
        wait = self.waits()
        score = rate / (1.0 + wait)
        share = score / score.sum()
        floor = self.min_share / len(share)
        share = np.maximum(share, floor)
        return share / share.sum()

    def slowest(self) -> int:
        return int(np.argmax(self.waits()))
