"""Checkpoint save/restore with manifest + atomic commit + elastic restore.

Layout::

    <dir>/step_000123/
        manifest.json      # step, leaf index (path -> file, shape, dtype)
        leaf_00000.npy ... # one file per pytree leaf
        COMMITTED          # written last: partial checkpoints are ignored

Restore tolerates a *different* device topology than save (elastic restart):
arrays are saved fully gathered and re-sharded by the caller's in_shardings
on the next step, so scaling from e.g. 512 to 256 devices only changes the
sharding layout, not the checkpoint format.  Fault-tolerance flow:
``latest_step`` + ``restore`` are what runtime.fault's restart policy calls.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "cleanup_old"]

_COMMIT = "COMMITTED"


def _paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomically save a pytree.  Returns the checkpoint path."""
    ckpt = os.path.join(directory, f"step_{step:09d}")
    tmp = ckpt + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if arr.dtype.kind not in "fiub" or dtype == "bfloat16":
            # numpy can't serialise ml_dtypes (bf16/f8): upcast losslessly
            arr = arr.astype(np.float32)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": name, "file": fname, "shape": list(arr.shape),
             "dtype": dtype}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)
    cleanup_old(directory, keep=keep)
    return ckpt


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _COMMIT)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``.  Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    ckpt = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)

    names = [n for n, _ in _paths(tree_like)]
    by_path = {e["path"]: e for e in manifest["leaves"]}
    missing = [n for n in names if n not in by_path]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")

    leaves = []
    for name, like in _paths(tree_like):
        e = by_path[name]
        arr = np.load(os.path.join(ckpt, e["file"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"model {like.shape}"
            )
        leaves.append(arr.astype(like.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def cleanup_old(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, _COMMIT))
    )
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
