"""Metric timelines: every sample carries both clock domains.

A timeline *point* is ``(wall_time, engine_clock, feed_idx, epoch_idx,
value)``:

* ``wall_time`` — monotonic seconds since trace start (host reality:
  what Perfetto plots on its x axis);
* ``engine_clock`` — the engine's own notion of time: *seconds* on the
  DSPE simulator, *scheduler ticks* on the serving engine (DESIGN.md §14
  clock domains).  The two are deliberately not interconvertible;
* ``feed_idx`` — which ``session.feed`` call the sample belongs to
  (-1: outside any feed);
* ``epoch_idx`` — the FISH tracker epoch at sample time (-1: no tracker
  in scope).

Emitters that know their coordinates pass them explicitly; emitters deep
in a layer (the FISH tracker does not know which feed it is in) inherit
the session-maintained :class:`TelemetryContext`.  The disabled path is
the shared :data:`NULL_TIMELINE` singleton — ``point`` is a constant
no-op.

Export downsamples each series to ``max_points`` by stride decimation
that always keeps the first and last point (see §14: peaks inside a
dropped stride are *not* re-aggregated — the full-resolution record is
the Chrome trace, the report timeline is the overview).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

__all__ = ["TelemetryContext", "Timeline", "NullTimeline", "NULL_TIMELINE",
           "TIMELINE_COLUMNS"]

TIMELINE_COLUMNS = ("wall_time", "engine_clock", "feed_idx", "epoch_idx",
                    "value")


class TelemetryContext:
    """Mutable current-position stamp shared by every emitter in a run.
    Sessions advance ``engine_clock``/``feed_idx`` at feed boundaries;
    the FISH epoch observer advances ``epoch_idx``."""

    __slots__ = ("engine_clock", "feed_idx", "epoch_idx")

    def __init__(self) -> None:
        self.engine_clock = 0.0
        self.feed_idx = -1
        self.epoch_idx = -1


class Timeline:
    """Named series of context-stamped samples."""

    def __init__(self, ctx: Optional[TelemetryContext] = None) -> None:
        self.ctx = ctx if ctx is not None else TelemetryContext()
        self.t0 = time.perf_counter()
        self.series: Dict[str, List[tuple]] = {}

    @property
    def enabled(self) -> bool:
        return True

    def point(self, name: str, value: float,
              engine_clock: Optional[float] = None,
              feed_idx: Optional[int] = None,
              epoch_idx: Optional[int] = None) -> None:
        """Append one sample; unspecified coordinates come off the shared
        context."""
        ctx = self.ctx
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = []
        s.append((
            time.perf_counter() - self.t0,
            ctx.engine_clock if engine_clock is None else float(engine_clock),
            ctx.feed_idx if feed_idx is None else int(feed_idx),
            ctx.epoch_idx if epoch_idx is None else int(epoch_idx),
            float(value),
        ))

    def export(self, max_points: int = 512) -> Dict:
        """JSON-serializable dict (the report ``timeline`` section)."""
        out: Dict[str, Dict] = {}
        for name, pts in self.series.items():
            n = len(pts)
            if n > max_points:
                stride = -(-n // max_points)
                kept = pts[::stride]
                if kept[-1] is not pts[-1]:
                    kept.append(pts[-1])
            else:
                kept = list(pts)
            out[name] = {
                "n_points": n,
                "n_kept": len(kept),
                "points": [list(p) for p in kept],
            }
        return {"columns": list(TIMELINE_COLUMNS), "series": out}


class NullTimeline:
    """Disabled timeline: ``point`` is a constant no-op."""

    __slots__ = ("ctx",)
    series: Dict = {}  # shared, always empty: never written to

    def __init__(self, ctx: Optional[TelemetryContext] = None) -> None:
        self.ctx = ctx if ctx is not None else TelemetryContext()

    @property
    def enabled(self) -> bool:
        return False

    def point(self, name: str, value: float,
              engine_clock: Optional[float] = None,
              feed_idx: Optional[int] = None,
              epoch_idx: Optional[int] = None) -> None:
        return None

    def export(self, max_points: int = 512) -> Dict:
        return {"columns": list(TIMELINE_COLUMNS), "series": {}}


NULL_TIMELINE = NullTimeline()
