"""Instruments + registry: the single source of truth for run counters.

Three instrument kinds, all plain-Python cells (zero dependencies, zero
per-tuple work — instrumented code updates them at feed/segment/event
granularity only):

* :class:`Counter` — cumulative count.  Mutate via ``add``/``set``; *read*
  via ``.value``.  Report fields that used to be ad-hoc attributes
  (``FusedEdgeRunner.dispatches``, ``feed_fused.TRACE_COUNT``, the serving
  engine's ``shed``) are properties over a ``Counter`` now, so the registry
  and the report can never disagree.
* :class:`Gauge` — last-value (``set``) or running-peak (``peak``) sample.
* :class:`Histogram` — raw observations with summary percentiles.

A :class:`MetricsRegistry` is an *enumeration surface*, not a lookup table:
``registry.counter(name)`` always mints a fresh instrument and remembers
it, so two runners on two edges can both own a ``fused.dispatches``
without clobbering each other; ``snapshot()`` aggregates by name (counters
sum, gauges keep the max of peaks / last of lasts, histograms merge).
Holding the instrument you minted is the fast path — reads and writes
never hash a name after creation.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "GLOBAL_METRICS"]


class Counter:
    """A cumulative counter cell.  ``value`` is the current total."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = labels or {}
        self.value = 0

    def add(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        """Overwrite the total (session-scoped resets; the
        ``feed_fused.TRACE_COUNT`` write-compat path)."""
        self.value = v

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Last-value / running-peak sample cell."""

    __slots__ = ("name", "labels", "value", "_peak_mode")
    kind = "gauge"

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = labels or {}
        self.value = 0
        self._peak_mode = False

    def set(self, v) -> None:
        self.value = v

    def peak(self, v) -> None:
        """Keep the running max (queue-depth / in-flight peaks)."""
        self._peak_mode = True
        if v > self.value:
            self.value = v

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Raw-observation histogram; summarised (not bucketed) on export."""

    __slots__ = ("name", "labels", "values")
    kind = "histogram"

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = labels or {}
        self.values: List[float] = []

    def record(self, v: float) -> None:
        self.values.append(float(v))

    def summary(self) -> Dict:
        vs = sorted(self.values)
        n = len(vs)
        if not n:
            return {"count": 0}
        return {
            "count": n,
            "min": vs[0],
            "max": vs[-1],
            "mean": sum(vs) / n,
            "p50": vs[n // 2],
            "p99": vs[min(n - 1, (99 * n) // 100)],
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Histogram({self.name}, n={len(self.values)})"


class MetricsRegistry:
    """Mints and enumerates instruments.  Aggregation happens only at
    ``snapshot()`` time — the hot path touches instrument cells directly."""

    def __init__(self) -> None:
        self._instruments: List = []

    def counter(self, name: str, **labels) -> Counter:
        c = Counter(name, labels)
        self._instruments.append(c)
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        g = Gauge(name, labels)
        self._instruments.append(g)
        return g

    def histogram(self, name: str, **labels) -> Histogram:
        h = Histogram(name, labels)
        self._instruments.append(h)
        return h

    def adopt(self, instrument) -> None:
        """Register an instrument minted elsewhere (e.g. the process-wide
        ``feed_fused`` trace counter) so it shows up in snapshots."""
        self._instruments.append(instrument)

    def __iter__(self):
        return iter(self._instruments)

    def __len__(self) -> int:
        return len(self._instruments)

    def snapshot(self) -> Dict[str, Dict]:
        """Aggregate by name: counters sum, peak gauges max / plain gauges
        last-write-wins, histograms merge their observations."""
        out: Dict[str, Dict] = {}
        merged_hists: Dict[str, Histogram] = {}
        for inst in self._instruments:
            if inst.kind == "histogram":
                m = merged_hists.get(inst.name)
                if m is None:
                    m = merged_hists[inst.name] = Histogram(inst.name)
                m.values.extend(inst.values)
                continue
            cur = out.get(inst.name)
            if cur is None:
                out[inst.name] = {"kind": inst.kind, "value": inst.value,
                                  "instruments": 1}
            elif inst.kind == "counter":
                cur["value"] += inst.value
                cur["instruments"] += 1
            else:  # gauge
                if inst._peak_mode:
                    cur["value"] = max(cur["value"], inst.value)
                else:
                    cur["value"] = inst.value
                cur["instruments"] += 1
        for name, h in merged_hists.items():
            out[name] = {"kind": "histogram", **h.summary()}
        return out


#: Process-wide registry for instruments that outlive any one session —
#: e.g. the jit trace counter behind ``feed_fused.TRACE_COUNT`` (retraces
#: are a property of the process-wide jit cache, not of a session).
GLOBAL_METRICS = MetricsRegistry()
