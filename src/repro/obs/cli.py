"""``python -m repro.obs`` — summarize / diff / validate run traces.

Subcommands over the Chrome trace-event files this package writes:

* ``summarize FILE`` — per-span-name duration stats, counter-track
  ranges, and the embedded metrics snapshot;
* ``diff A B`` — side-by-side deltas between two traces of the *same*
  scenario (e.g. FISH vs W-Choices): span totals, counter extremes,
  metric counters;
* ``validate FILE`` — schema-check the trace (exit 1 on problems).

Zero dependencies; everything is stdlib json over the exported file.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from .export import validate_chrome_trace

__all__ = ["main", "summarize_trace", "diff_traces"]


def _load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def _span_stats(trace: Dict) -> Dict[str, Dict]:
    """name -> {count, total_ms, mean_ms, max_ms} over X events."""
    out: Dict[str, Dict] = {}
    for ev in trace.get("traceEvents", ()):
        if ev.get("ph") != "X":
            continue
        s = out.setdefault(ev["name"], {"cat": ev.get("cat", ""),
                                        "count": 0, "total_ms": 0.0,
                                        "max_ms": 0.0})
        d = ev.get("dur", 0.0) / 1e3
        s["count"] += 1
        s["total_ms"] += d
        if d > s["max_ms"]:
            s["max_ms"] = d
    for s in out.values():
        s["mean_ms"] = s["total_ms"] / s["count"]
    return out


def _counter_stats(trace: Dict) -> Dict[str, Dict]:
    """name -> {points, min, max, last} over C events."""
    out: Dict[str, Dict] = {}
    for ev in trace.get("traceEvents", ()):
        if ev.get("ph") != "C":
            continue
        v = ev.get("args", {}).get("value")
        if v is None:
            continue
        s = out.get(ev["name"])
        if s is None:
            out[ev["name"]] = {"points": 1, "min": v, "max": v, "last": v}
        else:
            s["points"] += 1
            s["min"] = min(s["min"], v)
            s["max"] = max(s["max"], v)
            s["last"] = v
    return out


def summarize_trace(trace: Dict) -> Dict:
    other = trace.get("otherData", {})
    return {
        "label": other.get("label", ""),
        "n_events": len(trace.get("traceEvents", ())),
        "spans": _span_stats(trace),
        "counters": _counter_stats(trace),
        "metrics": other.get("metrics", {}),
        "instants": sum(1 for ev in trace.get("traceEvents", ())
                        if ev.get("ph") == "i"),
        "aborted": bool(other.get("aborted", False)),
    }


def _print_summary(s: Dict, out) -> None:
    head = f"trace: {s['label'] or '<unlabeled>'}"
    print(head, file=out)
    print(f"  events: {s['n_events']}  instants: {s['instants']}"
          + ("  [ABORTED RUN]" if s["aborted"] else ""), file=out)
    if s["spans"]:
        print("  spans (name: count, total ms, mean ms, max ms):", file=out)
        for name in sorted(s["spans"], key=lambda n: -s["spans"][n]["total_ms"]):
            sp = s["spans"][name]
            print(f"    {name:32s} {sp['count']:6d} {sp['total_ms']:10.2f} "
                  f"{sp['mean_ms']:9.3f} {sp['max_ms']:9.3f}", file=out)
    if s["counters"]:
        print("  counters (name: points, min, max, last):", file=out)
        for name in sorted(s["counters"]):
            c = s["counters"][name]
            print(f"    {name:32s} {c['points']:6d} {c['min']:10.3f} "
                  f"{c['max']:10.3f} {c['last']:10.3f}", file=out)
    if s["metrics"]:
        print("  metrics:", file=out)
        for name in sorted(s["metrics"]):
            m = s["metrics"][name]
            v = m.get("value", m.get("count"))
            print(f"    {name:40s} {v}", file=out)


def diff_traces(a: Dict, b: Dict) -> Dict:
    sa, sb = summarize_trace(a), summarize_trace(b)
    out: Dict = {"a": sa["label"], "b": sb["label"], "spans": {},
                 "counters": {}, "metrics": {}}
    for name in sorted(set(sa["spans"]) | set(sb["spans"])):
        ta = sa["spans"].get(name, {}).get("total_ms", 0.0)
        tb = sb["spans"].get(name, {}).get("total_ms", 0.0)
        out["spans"][name] = {"a_total_ms": ta, "b_total_ms": tb,
                              "delta_ms": tb - ta}
    for name in sorted(set(sa["counters"]) | set(sb["counters"])):
        ca = sa["counters"].get(name)
        cb = sb["counters"].get(name)
        out["counters"][name] = {
            "a_max": None if ca is None else ca["max"],
            "b_max": None if cb is None else cb["max"],
        }
    for name in sorted(set(sa["metrics"]) | set(sb["metrics"])):
        ma = sa["metrics"].get(name, {})
        mb = sb["metrics"].get(name, {})
        va, vb = ma.get("value"), mb.get("value")
        e = {"a": va, "b": vb}
        if isinstance(va, (int, float)) and isinstance(vb, (int, float)):
            e["delta"] = vb - va
        out["metrics"][name] = e
    return out


def _print_diff(d: Dict, out) -> None:
    print(f"diff: a={d['a'] or '<unlabeled>'}  b={d['b'] or '<unlabeled>'}",
          file=out)
    if d["spans"]:
        print("  span totals (ms):  a, b, b-a", file=out)
        for name, e in d["spans"].items():
            print(f"    {name:32s} {e['a_total_ms']:10.2f} "
                  f"{e['b_total_ms']:10.2f} {e['delta_ms']:+10.2f}", file=out)
    if d["counters"]:
        print("  counter maxima:  a, b", file=out)
        for name, e in d["counters"].items():
            fa = "-" if e["a_max"] is None else f"{e['a_max']:.3f}"
            fb = "-" if e["b_max"] is None else f"{e['b_max']:.3f}"
            print(f"    {name:32s} {fa:>12s} {fb:>12s}", file=out)
    if d["metrics"]:
        print("  metrics:  a, b (delta)", file=out)
        for name, e in d["metrics"].items():
            extra = (f" ({e['delta']:+})" if "delta" in e else "")
            print(f"    {name:40s} {e['a']} -> {e['b']}{extra}", file=out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize / diff / validate repro run traces")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize", help="per-span and counter summary")
    ps.add_argument("file")
    ps.add_argument("--json", action="store_true",
                    help="machine-readable output")
    pd = sub.add_parser("diff", help="delta between two traces")
    pd.add_argument("file_a")
    pd.add_argument("file_b")
    pd.add_argument("--json", action="store_true")
    pv = sub.add_parser("validate", help="trace-event schema check")
    pv.add_argument("file")
    args = ap.parse_args(argv)

    if args.cmd == "summarize":
        trace = _load(args.file)
        problems = validate_chrome_trace(trace)
        if problems:
            for p in problems:
                print(f"invalid trace: {p}", file=sys.stderr)
            return 1
        s = summarize_trace(trace)
        if args.json:
            print(json.dumps(s, indent=2, sort_keys=True))
        else:
            _print_summary(s, sys.stdout)
        return 0
    if args.cmd == "diff":
        d = diff_traces(_load(args.file_a), _load(args.file_b))
        if args.json:
            print(json.dumps(d, indent=2, sort_keys=True))
        else:
            _print_diff(d, sys.stdout)
        return 0
    # validate
    problems = validate_chrome_trace(_load(args.file))
    for p in problems:
        print(p, file=sys.stderr)
    print(f"{args.file}: " + ("INVALID" if problems else "ok"))
    return 1 if problems else 0
