"""Span tracer: wall-clock intervals + instant events, Perfetto-shaped.

Spans are recorded against a monotonic clock (``time.perf_counter``)
anchored to one wall-clock instant at tracer construction, so exported
Chrome-trace timestamps are drift-free within a run and still carry an
absolute ``trace_start_wall`` in metadata.  The disabled path is a pair of
shared singletons (:data:`NULL_TRACER` handing out :data:`NULL_SPAN`):
no allocation, no clock read, no list append — the overhead contract in
DESIGN.md §14.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN"]


class Span:
    """One traced interval.  Used as a context manager; ``set(**kw)``
    attaches args visible in the Perfetto detail pane."""

    __slots__ = ("name", "cat", "t0", "t1", "args", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = time.perf_counter()
        self.t1 = -1.0

    def set(self, **kw) -> "Span":
        if self.args is None:
            self.args = kw
        else:
            self.args.update(kw)
        return self

    def done(self) -> None:
        if self.t1 < 0.0:
            self.t1 = time.perf_counter()
            self._tracer.spans.append(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.done()
        return False


class Tracer:
    """Collects :class:`Span`s and instant events in memory."""

    def __init__(self) -> None:
        self.t0 = time.perf_counter()
        self.wall0 = time.time()
        self.spans: List[Span] = []
        self.instants: List[tuple] = []  # (t, name, cat, args)

    @property
    def enabled(self) -> bool:
        return True

    def span(self, name: str, cat: str = "run", **args) -> Span:
        return Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "run", **args) -> None:
        self.instants.append((time.perf_counter(), name, cat, args or None))

    def rel_us(self, t: float) -> float:
        """Monotonic instant → microseconds since trace start."""
        return (t - self.t0) * 1e6


class NullTracer:
    """Disabled tracer: every call is a constant-return no-op."""

    __slots__ = ()
    spans: List = []      # shared, always empty: never appended to
    instants: List = []
    t0 = 0.0
    wall0 = 0.0

    @property
    def enabled(self) -> bool:
        return False

    def span(self, name: str, cat: str = "run", **args) -> "_NullSpan":
        return NULL_SPAN

    def instant(self, name: str, cat: str = "run", **args) -> None:
        return None

    def rel_us(self, t: float) -> float:
        return 0.0


class _NullSpan:
    """Shared no-op span — ``span()`` on the null tracer allocates nothing."""

    __slots__ = ()

    def set(self, **kw) -> "_NullSpan":
        return self

    def done(self) -> None:
        return None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()
NULL_TRACER = NullTracer()
