"""Telemetry spine (ISSUE 9): engine-clock tracing, per-epoch metric
timelines, and Perfetto-exportable run traces.

Zero-dependency observability for every layer of the repro:

* :class:`MetricsRegistry` / :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` — the single source of truth for run counters
  (always on; plain int cells at feed/segment/event granularity);
* :class:`Tracer` — wall-clock spans and instants;
* :class:`Timeline` — metric series where every sample is stamped
  ``(wall_time, engine_clock, feed_idx, epoch_idx)``;
* :class:`Telemetry` — the bundle engines thread through their layers;
  :func:`enable` / :func:`disable` / :func:`get_telemetry` manage the
  process default (disabled ⇒ strict no-op tracer/timeline singletons);
* Chrome trace-event export (:func:`chrome_trace`, :class:`TraceWriter`)
  viewable in Perfetto, and a CLI (``python -m repro.obs``) that
  summarizes and diffs trace files.

Schema, clock domains, downsampling policy, and the overhead contract
are documented in DESIGN.md §14.
"""

from .export import TraceWriter, chrome_trace, validate_chrome_trace
from .metrics import (GLOBAL_METRICS, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .telemetry import Telemetry, disable, enable, get_telemetry, is_enabled
from .timeline import (NULL_TIMELINE, NullTimeline, TelemetryContext,
                       Timeline)
from .trace import NULL_SPAN, NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "GLOBAL_METRICS",
    "Tracer", "NullTracer", "Span", "NULL_TRACER", "NULL_SPAN",
    "Timeline", "NullTimeline", "TelemetryContext", "NULL_TIMELINE",
    "Telemetry", "enable", "disable", "get_telemetry", "is_enabled",
    "chrome_trace", "validate_chrome_trace", "TraceWriter",
]
