"""Chrome trace-event JSON export (Perfetto-loadable) + streaming writer.

The export target is the `Trace Event Format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
object form: ``{"traceEvents": [...], "otherData": {...}}``.  We emit

* ``M`` metadata events naming the process and one thread lane per span
  category (``fused``, ``session``, ``load``, …);
* ``X`` complete events for spans (``ts``/``dur`` in microseconds since
  trace start);
* ``i`` instant events (autoscaler actions, membership events, FISH
  decay);
* ``C`` counter events for every timeline series — each becomes a
  Perfetto counter track with a single ``value`` series.  The full
  ``(wall_time, engine_clock, feed_idx, epoch_idx)`` coordinates stay in
  the report timeline / ``repro.obs summarize``; counter tracks stay
  clean.

:class:`TraceWriter` is the crash-safe file form: events stream into a
sibling ``.tmp`` and only an explicit ``close()``/``abort()`` renames the
finished, *valid* JSON into place — a benchmark that dies mid-run flushes
what it has instead of leaving a truncated file (ISSUE 9 bugfix
satellite).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

__all__ = ["chrome_trace", "validate_chrome_trace", "TraceWriter"]

PID = 1
_PHASES = frozenset("XBEiCM")


def chrome_trace(tel) -> Dict:
    """Render a :class:`~repro.obs.telemetry.Telemetry` bundle as one
    Chrome trace-event object."""
    tr = tel.tracer
    events: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": PID, "tid": 0,
        "args": {"name": f"repro {tel.label}".strip()},
    }]
    tids: Dict[str, int] = {}

    def tid_for(cat: str) -> int:
        t = tids.get(cat)
        if t is None:
            t = tids[cat] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": PID,
                           "tid": t, "args": {"name": cat}})
        return t

    for sp in tr.spans:
        ev = {"name": sp.name, "cat": sp.cat, "ph": "X",
              "ts": tr.rel_us(sp.t0), "dur": max((sp.t1 - sp.t0) * 1e6, 0.0),
              "pid": PID, "tid": tid_for(sp.cat)}
        if sp.args:
            ev["args"] = sp.args
        events.append(ev)
    for t, name, cat, args in tr.instants:
        ev = {"name": name, "cat": cat, "ph": "i", "ts": tr.rel_us(t),
              "pid": PID, "tid": tid_for(cat), "s": "p"}
        if args:
            ev["args"] = args
        events.append(ev)
    for name, pts in tel.timeline.series.items():
        for wall, _clock, _feed, _epoch, value in pts:
            events.append({"name": name, "cat": "timeline", "ph": "C",
                           "ts": wall * 1e6, "pid": PID,
                           "args": {"value": value}})
    events.sort(key=lambda e: (e.get("ts", -1.0), e["ph"] != "M"))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "label": tel.label,
            "trace_start_wall": getattr(tr, "wall0", 0.0),
            "metrics": tel.metrics.snapshot(),
            "timeline": tel.timeline.export(),
        },
    }


def validate_chrome_trace(obj) -> List[str]:
    """Schema check for the export above (and anything Perfetto would
    choke on).  Returns a list of problems — empty means valid."""
    problems: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing 'traceEvents' list"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int):
            problems.append(f"{where}: missing integer pid")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                problems.append(f"{where}: {ph}-event missing numeric ts")
            elif ts < 0:
                problems.append(f"{where}: negative ts {ts}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X-event needs dur >= 0")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                problems.append(f"{where}: C-event needs non-empty args")
            elif not all(isinstance(v, (int, float))
                         for v in args.values()):
                problems.append(f"{where}: C-event args must be numeric")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args must be an object")
    return problems


class TraceWriter:
    """Streaming trace-event file that is *always* valid JSON once closed.

    Events append to ``<path>.tmp``; ``close()`` seals the array, writes
    ``otherData``, and renames into place.  ``abort()`` is ``close()``
    with an ``aborted`` stamp — the failure path flushes instead of
    truncating.  Idempotent: double close/abort is a no-op.
    """

    def __init__(self, path: str) -> None:
        self.path = path
        self._tmp = f"{path}.tmp"
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self._tmp, "w")
        self._f.write('{"traceEvents": [')
        self._n = 0
        self.closed = False

    def write_event(self, ev: Dict) -> None:
        if self.closed:
            raise ValueError(f"TraceWriter({self.path}) already closed")
        if self._n:
            self._f.write(",\n")
        json.dump(ev, self._f)
        self._n += 1

    def write_telemetry(self, tel) -> None:
        """Append a whole bundle's events (spans, instants, counters)."""
        for ev in chrome_trace(tel)["traceEvents"]:
            self.write_event(ev)

    def close(self, other_data: Optional[Dict] = None,
              aborted: bool = False) -> Optional[str]:
        if self.closed:
            return None
        self.closed = True
        other = dict(other_data or {})
        if aborted:
            other["aborted"] = True
        self._f.write('], "displayTimeUnit": "ms", "otherData": ')
        json.dump(other, self._f)
        self._f.write("}")
        self._f.flush()
        self._f.close()
        os.replace(self._tmp, self.path)
        return self.path

    def abort(self, reason: str = "") -> Optional[str]:
        """Seal whatever was written so far as valid JSON (failure path)."""
        return self.close({"abort_reason": reason} if reason else None,
                          aborted=True)

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, exc_type, *exc) -> bool:
        if exc_type is None:
            self.close()
        else:
            self.abort(reason=str(exc_type.__name__))
        return False
