"""The telemetry bundle and the process default (ISSUE 9 tentpole).

A :class:`Telemetry` carries the three surfaces together:

* ``metrics`` — a :class:`~repro.obs.metrics.MetricsRegistry`.  **Always
  real**, enabled or not: the unified report counters (dispatches, shed,
  remap totals…) live here as their single source of truth, and they are
  plain int cells updated at feed/segment/event granularity — cheap enough
  to never gate.
* ``tracer`` / ``timeline`` — real collectors when enabled, shared no-op
  singletons when not.  This is the strict fast path: with telemetry
  disabled no span object is allocated, no clock is read, no sample list
  grows.

Engines resolve their telemetry as ``telemetry or get_telemetry()``:
pass one explicitly to ``Engine.open`` (or ``enable()`` the process
default) and every layer underneath — fused runner, FISH tracker,
open-loop driver, autoscaler — reports into the same bundle.  When the
process default is *disabled*, each session gets a private disabled
bundle (``for_session()``) so per-session counters never bleed across
runs.
"""

from __future__ import annotations

import os
from typing import Dict, Optional

from .metrics import MetricsRegistry
from .timeline import (NULL_TIMELINE, NullTimeline, TelemetryContext,
                       Timeline)
from .trace import NULL_TRACER, Tracer

__all__ = ["Telemetry", "enable", "disable", "get_telemetry", "is_enabled"]


class Telemetry:
    def __init__(self, enabled: bool = True, label: str = "") -> None:
        self.enabled = bool(enabled)
        self.label = label
        self.metrics = MetricsRegistry()
        self.ctx = TelemetryContext()
        if self.enabled:
            self.tracer = Tracer()
            self.timeline = Timeline(self.ctx)
            # one time base: span ts and timeline ts land on the same axis
            self.timeline.t0 = self.tracer.t0
        else:
            self.tracer = NULL_TRACER
            self.timeline = NullTimeline(self.ctx)
        self.meta: Dict = {"label": label}

    # -- session plumbing ---------------------------------------------------
    def for_session(self) -> "Telemetry":
        """The bundle a new session should use.  Enabled telemetry is
        shared (one trace spans the whole run, sessions and all); disabled
        telemetry hands out a private bundle so session counters don't
        accumulate into a process-lifetime registry."""
        return self if self.enabled else Telemetry(enabled=False)

    # -- export -------------------------------------------------------------
    def timeline_dict(self, max_points: int = 512) -> Optional[Dict]:
        """The report ``timeline`` section (None when disabled, so report
        dicts stay bit-identical to pre-telemetry output)."""
        if not self.enabled:
            return None
        out = self.timeline.export(max_points)
        out["metrics"] = self.metrics.snapshot()
        return out

    def chrome_trace(self) -> Dict:
        from .export import chrome_trace
        return chrome_trace(self)

    def save(self, path: str) -> str:
        """Write the Chrome trace-event JSON atomically (never leaves a
        truncated file: full write to a sibling tmp, then rename)."""
        import json

        payload = self.chrome_trace()
        tmp = f"{path}.tmp"
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
        os.replace(tmp, path)
        return path


_default = Telemetry(enabled=False)


def get_telemetry() -> Telemetry:
    """The process-default bundle (disabled unless ``enable()`` was
    called)."""
    return _default


def enable(label: str = "") -> Telemetry:
    """Turn on process-wide telemetry; returns the new default bundle."""
    global _default
    _default = Telemetry(enabled=True, label=label)
    return _default


def disable() -> None:
    """Back to the no-op default."""
    global _default
    _default = Telemetry(enabled=False)


def is_enabled() -> bool:
    return _default.enabled
