"""Architecture registry: ``--arch <id>`` resolution."""

from typing import Dict, List

from .base import (MLAConfig, ModelConfig, MoEConfig, RGLRUConfig, SHAPES,
                   ShapeConfig, SSMConfig, reduced_config)

_ARCH_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "qwen1.5-0.5b": "qwen15_05b",
    "starcoder2-3b": "starcoder2_3b",
    "olmo-1b": "olmo_1b",
    "gemma2-2b": "gemma2_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "whisper-large-v3": "whisper_large_v3",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    import importlib

    try:
        mod_name = _ARCH_MODULES[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; one of {list_archs()}")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise ValueError(f"unknown shape {name!r}; one of {list(SHAPES)}")


__all__ = [
    "MLAConfig", "ModelConfig", "MoEConfig", "RGLRUConfig", "SSMConfig",
    "ShapeConfig", "SHAPES", "reduced_config", "list_archs", "get_config",
    "get_shape",
]
