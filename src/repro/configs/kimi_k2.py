"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2, paper-table spec].

Per the assignment table: GQA kv=8 (not MLA), d_model=7168, 61 layers,
expert d_ff=2048.  1 shared expert + first layer dense (Kimi-K2/DSv3 style).
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,            # the single dense (first) layer
    vocab_size=163_840,
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=50_000.0,
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_ff_expert=2048,
        shared_experts=1,
        first_dense_layers=1,
        routing="fish",
        capacity_factor=1.25,
        tokens_per_group=512,
        fish_alpha=0.2,
        dispatch_impl="scatter",   # §Perf: -10..-21% HLO FLOPs vs one-hot
        hot_headroom=1.25,         # §Perf: no empty-slot expert compute
    ),
    opt_state_dtype="bfloat16",   # 1T params: fp32 m/v would not fit 16G HBM
    opt_factored=True,            # Adafactor-style v: O(n+m) second moment
    grad_accum=8,                 # microbatching keeps activations in HBM
    zero_sharding=True,
    notes="~1.03T total / ~32B active params. FISH expert routing is the "
          "paper-technique integration point (DESIGN.md §1.2).",
)
