"""qwen1.5-0.5b — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    norm="rmsnorm",
    notes="HF ties embeddings; kept untied here (noted param-count delta "
          "+155M).",
)
