"""recurrentgemma-9b — RG-LRU + local attention, 1 attn per 3 blocks
[arXiv:2402.19427]."""

from .base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,        # MQA in the attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    mlp_kind="geglu",
    norm="rmsnorm_plus_one",
    scale_embeddings=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    rope_theta=10_000.0,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, attention_every=3,
                      local_window=2048, gate_blocks=16),
    sub_quadratic=True,
    notes="(rec, rec, attn) pattern: 38 = 12 groups + 2 trailing rec layers. "
          "Decode attention caches are window-sized ring buffers -> "
          "long_500k eligible.",
)
