"""starcoder2-3b — GQA kv=2, RoPE [arXiv:2402.19173]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="mlp",
    activation="gelu_tanh",
    norm="layernorm",
    norm_eps=1e-5,
    notes="HF uses sliding_window=4096; at the assigned shapes "
          "(train seq 4096) the window covers the sequence, modeled as full "
          "attention (DESIGN.md §4).",
)
