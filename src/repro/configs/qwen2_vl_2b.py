"""qwen2-vl-2b — M-RoPE, dynamic resolution [arXiv:2409.12191].

VLM entry: the ViT frontend is a STUB per the assignment — input_specs()
provides precomputed patch embeddings (B, S, d_model) plus the (3, B, S)
M-RoPE position streams.  Only the transformer backbone is modeled.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    norm="rmsnorm",
    embeds_input=True,
)
