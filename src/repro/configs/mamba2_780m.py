"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L, d_model=1536, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*d_model = 3072, head_dim 64 -> 48 SSD heads.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=48,          # SSD heads (d_inner / head_dim)
    num_kv_heads=48,
    head_dim=64,
    d_ff=0,                # attention-free, no MLP block
    vocab_size=50280,
    norm="rmsnorm",
    rope_kind="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=128),
    sub_quadratic=True,
    notes="Pure Mamba-2: each layer is norm -> SSD mixer -> residual. "
          "long_500k eligible (O(1) decode state).",
)
