"""whisper-large-v3 — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].

The conv1d+mel frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d_model) for the encoder.  Positions
are sinusoidal on both sides (HF uses learned on the decoder — noted
deviation, irrelevant to compile/roofline).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,         # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    qkv_bias=True,
    rope_kind="none",
    mlp_kind="mlp",
    activation="gelu",
    norm="layernorm",
    norm_eps=1e-5,
)
