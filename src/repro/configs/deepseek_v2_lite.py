"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE 64e top-6, 2 shared
[arXiv:2405.04434].
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,       # MLA is effectively MHA over the latent
    head_dim=128,          # v head dim (see MLAConfig for q/k dims)
    d_ff=10944,            # dense first layer
    vocab_size=102_400,
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        shared_experts=2,
        first_dense_layers=1,
        routing="fish",
        capacity_factor=1.25,
        tokens_per_group=1024,
        fish_alpha=0.2,
        dispatch_impl="scatter",   # §Perf: -10..-21% HLO FLOPs vs one-hot
        hot_headroom=1.25,         # §Perf: no empty-slot expert compute
    ),
    notes="Assignment table lists '64e top-6' and '2 shared+160 routed'; "
          "the HF config has 64 routed experts (160 is V2-full) — using 64 "
          "routed + 2 shared, top-6.",
)
