"""The paper's own workload config: FISH stream-grouping defaults (§6.1/§6.3)
for the DSPE simulator, data pipeline and serving router."""

import dataclasses

from ..core.fish import FishParams


@dataclasses.dataclass(frozen=True)
class StreamWorkloadConfig:
    num_workers: int = 128         # paper's largest scale
    num_sources: int = 32          # RQ5 Storm topology: 32 sources
    fish: FishParams = dataclasses.field(default_factory=FishParams)
    arrival_rate: float = 10_000.0  # tuples/s
    estimator_interval: float = 10.0  # paper's T = 10 s
    virtual_nodes: int = 64        # consistent-hash virtual nodes per worker


CONFIG = StreamWorkloadConfig()
