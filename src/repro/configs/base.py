"""Config schema for the model zoo + the assigned input-shape grid."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
           "ModelConfig", "ShapeConfig", "SHAPES", "reduced_config"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_experts: int = 0
    first_dense_layers: int = 1
    routing: str = "fish"          # fg | pkg | fish  (paper-scheme analogs)
    capacity_factor: float = 1.25
    tokens_per_group: int = 2048   # dispatch group size (GShard-style)
    fish_alpha: float = 0.2        # inter-epoch decay (paper §6.3)
    fish_theta_frac: float = 0.25  # θ = frac / num_experts
    router_aux_weight: float = 1e-2
    dispatch_impl: str = "einsum"  # einsum | scatter (§Perf lever)
    hot_headroom: float = 2.0      # C_max multiplier over the uniform slice


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0             # 0 -> d_model
    conv_width: int = 4
    attention_every: int = 3       # 1 attn per 3 blocks (rec, rec, attn)
    local_window: int = 2048
    gate_blocks: int = 16          # block-diagonal i/r gate heads


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention / pos ---
    qkv_bias: bool = False
    rope_kind: str = "rope"        # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("local","global")
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    # --- mlp / norm ---
    mlp_kind: str = "swiglu"       # swiglu | geglu | mlp
    activation: str = "silu"
    norm: str = "rmsnorm"          # rmsnorm | rmsnorm_plus_one | layernorm | nonparametric
    norm_eps: float = 1e-6
    post_norms: bool = False       # gemma2 pre+post sandwich norms
    scale_embeddings: bool = False # gemma: embed * sqrt(d_model)
    tie_embeddings: bool = False
    # --- variants ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder_layers: int = 0        # whisper enc-dec
    encoder_seq: int = 1500
    embeds_input: bool = False     # frontend stub feeds embeddings directly
    # --- training / distribution ---
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    opt_factored: bool = False     # Adafactor-style factored second moment
    grad_accum: int = 1            # microbatches per optimizer step
    zero_sharding: bool = True     # shard non-TP weight dim over (pod, data)
    remat: bool = True
    sub_quadratic: bool = False    # eligible for long_500k
    cost_exact: bool = False       # dry-run costing mode: unroll every scan so
                                   # HloCostAnalysis counts all iterations
    notes: str = ""

    @property
    def attention_free(self) -> bool:
        return self.ssm is not None

    def supports_shape(self, shape: "ShapeConfig") -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    small_heads = min(cfg.num_heads, 4)
    small_kv = max(1, min(cfg.num_kv_heads, small_heads))
    while small_heads % small_kv:
        small_kv -= 1
    updates = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=small_heads,
        num_kv_heads=small_kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        sliding_window=64 if cfg.sliding_window else None,
        encoder_seq=32 if cfg.encoder_layers else cfg.encoder_seq,
        encoder_layers=min(cfg.encoder_layers, 2),
        zero_sharding=False,
    )
    if cfg.moe:
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_ff_expert=64,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            tokens_per_group=64,
        )
    if cfg.mla:
        updates["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=32,
                                   qk_rope_dim=16, v_head_dim=32)
        updates["head_dim"] = 32
    if cfg.ssm:
        updates["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                             chunk=16)
    if cfg.rglru:
        updates["rglru"] = dataclasses.replace(cfg.rglru, lru_width=128,
                                               local_window=32)
    if cfg.local_global_pattern:
        updates["sliding_window"] = 32
    return dataclasses.replace(cfg, **updates)
