"""gemma2-2b — local+global alternating attention, logit softcap
[arXiv:2408.00118]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    mlp_kind="geglu",
    norm="rmsnorm_plus_one",
    post_norms=True,
    scale_embeddings=True,
    tie_embeddings=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sliding_window=4096,
    local_global_pattern=("local", "global"),
    rope_theta=10_000.0,
)
