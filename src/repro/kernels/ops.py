"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends the kernels run in ``interpret=True`` mode (Pallas
executes the kernel body in Python on CPU) so every call site is portable;
on TPU the same BlockSpecs compile to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import fish_count as _fish_count
from . import ssd as _ssd
from . import store_probe as _store_probe
from . import ref as ref  # re-exported for tests/benchmarks

__all__ = ["fish_count", "fish_epoch_count", "ssd_scan", "store_probe", "ref"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fish_count(table_keys: jnp.ndarray, batch_keys: jnp.ndarray, *,
               block_n: int = 1024):
    """Epoch match-and-count; pads the table to lane width (128)."""
    k = table_keys.shape[0]
    k_pad = -k % 128
    padded = jnp.pad(table_keys, (0, k_pad), constant_values=-1)
    counts, matched = _fish_count.fish_count(
        padded, batch_keys, block_n=block_n, interpret=_interpret()
    )
    return counts[:k], matched


def fish_epoch_count(table_keys: jnp.ndarray, table_counts: jnp.ndarray,
                     batch_keys: jnp.ndarray, *, alpha: float,
                     block_n: int = 1024):
    """Fused epoch pass (decay + match-count + candidate histogram).

    Pads the table to lane width (128; empty slots key=-1, count=0) and is
    the ``fused_fn`` plugged into ``repro.core.fish.epoch_update``.
    """
    k = table_keys.shape[0]
    k_pad = -k % 128
    padded_k = jnp.pad(table_keys, (0, k_pad), constant_values=-1)
    padded_c = jnp.pad(table_counts, (0, k_pad))
    counts, matched, cand, first = _fish_count.fish_epoch_count(
        padded_k, padded_c, batch_keys, alpha=float(alpha), block_n=block_n,
        interpret=_interpret(),
    )
    return counts[:k], matched, cand, first


def store_probe(table_keys: jnp.ndarray, batch_keys: jnp.ndarray,
                batch_vals: jnp.ndarray, *, block_n: int = 1024,
                impl: str = None):
    """Keyed-state probe/accumulate (ISSUE 6): per-slot int32 (vsum, csum)
    of one routed chunk against a resident slot table, plus per-token hit
    flags.  Pads the table to lane width (128; empty slots key=-1).

    impl: "pallas" | "sorted" | None.  None = pallas on TPU (or with
    REPRO_FORCE_PALLAS=1), else a ``jnp.searchsorted`` fallback that needs
    ``table_keys`` sorted ascending (which :class:`repro.state.store.
    DeviceStateStore` maintains) — identical results, O(N log K) on CPU
    instead of the O(N·K) compare matrix.
    """
    import os

    if impl is None:
        if jax.default_backend() == "tpu" or os.environ.get("REPRO_FORCE_PALLAS"):
            impl = "pallas"
        else:
            impl = "sorted"
    if impl == "pallas":
        k = table_keys.shape[0]
        k_pad = -k % 128
        padded = jnp.pad(table_keys, (0, k_pad), constant_values=-1)
        vsum, csum, matched = _store_probe.store_probe(
            padded, batch_keys, batch_vals, block_n=block_n,
            interpret=_interpret())
        return vsum[:k], csum[:k], matched
    return _store_probe_sorted(table_keys, batch_keys, batch_vals)


@jax.jit
def _store_probe_sorted(table_keys, batch_keys, batch_vals):
    k = table_keys.shape[0]
    slot = jnp.searchsorted(table_keys, batch_keys)
    slot_c = jnp.clip(slot, 0, max(k - 1, 0))
    matched = (table_keys[slot_c] == batch_keys) if k else jnp.zeros(
        batch_keys.shape, bool)
    tgt = jnp.where(matched, slot_c, k)  # misses land in a scratch slot
    vsum = jnp.zeros(k + 1, jnp.int32).at[tgt].add(batch_vals)
    csum = jnp.zeros(k + 1, jnp.int32).at[tgt].add(1)
    return vsum[:k], csum[:k], matched


def ssd_scan(x, a, b, c, *, chunk: int = 128, initial_state=None,
             impl: str = None):
    """Full SSD layer scan: chunk kernels + tiny cross-chunk lax.scan.

    x: (B, S, H, P); a: (B, S, H) log decay (<= 0); b, c: (B, S, G, N).
    returns y (B, S, H, P) f32, final_state (B, H, N, P) f32.

    impl: "pallas" | "ref" | None.  None = pallas on TPU (the target), the
    pure-jnp chunked reference elsewhere (mathematically identical; Pallas
    tiling is validated in interpret mode by tests/test_kernels.py).  Set
    REPRO_FORCE_PALLAS=1 to run the interpret-mode kernels inside models on
    CPU too.
    """
    import os

    if impl is None:
        if jax.default_backend() == "tpu" or os.environ.get("REPRO_FORCE_PALLAS"):
            impl = "pallas"
        else:
            impl = "ref"

    # pad seq to a chunk multiple: zero x/b/c with zero log-decay leaves the
    # carried state untouched through the padding steps
    s_orig = x.shape[1]
    pad = -s_orig % chunk
    if pad:
        padt = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, a, b, c = padt(x), padt(a), padt(b), padt(c)

    if impl == "ref":
        y, final = ref.ssd_chunked_ref(x, a, b, c, chunk,
                                       initial_state=initial_state)
        return y[:, :s_orig], final

    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = s // chunk
    interp = _interpret()

    xc = x.reshape(bsz * nc, chunk, h, p).astype(jnp.float32)
    ac = a.reshape(bsz * nc, chunk, h).astype(jnp.float32)
    bc_ = b.reshape(bsz * nc, chunk, g, n).astype(jnp.float32)
    cc = c.reshape(bsz * nc, chunk, g, n).astype(jnp.float32)
    a_cum = jnp.cumsum(ac, axis=1)

    states, a_tot = _ssd.ssd_chunk_state(xc, bc_, a_cum, interpret=interp)
    states = states.reshape(bsz, nc, h, n, p)
    a_tot = a_tot.reshape(bsz, nc, h)

    def comb(prev, inp):
        st, at = inp
        return prev * jnp.exp(at)[..., None, None] + st, prev

    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    final, prev_states = jax.lax.scan(
        comb, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1).reshape(bsz * nc, h, n, p)

    y = _ssd.ssd_chunk_output(xc, bc_, cc, a_cum, prev_states, interpret=interp)
    return y.reshape(bsz, s, h, p)[:, :s_orig], final
