"""Pallas TPU kernels for the Mamba-2 SSD (state-space duality) chunk scan.

The SSD layer computes, per head, the 1-semiseparable recurrence

    S_t = exp(a_t) * S_{t-1} + b_t ⊗ x_t          (state  N×P)
    y_t = c_t · S_t

The TPU-native evaluation (arXiv:2405.21060 §6, re-tiled for MXU/VMEM) splits
the sequence into chunks of Q tokens:

  1. ``ssd_chunk_state``  — per-chunk states  S_c = Σ_i exp(A_c - a_i) b_i⊗x_i
     (an (N×Q)@(Q×P) MXU matmul per chunk×head);
  2. a tiny sequential ``lax.scan`` across chunks combines the per-chunk
     states (done by the caller in ops.py — O(S/Q) steps);
  3. ``ssd_chunk_output`` — the chunk-local quadratic part plus the carried
     state contribution:
         y = ((C Bᵀ) ∘ L) X + (C * exp(a_cum)) S_prev
     where L[i,j] = exp(a_cum[i] - a_cum[j]) for i ≥ j (decay mask).

Block shapes are one (chunk × head) tile per grid step: X (Q,P), B/C (Q,N),
states (N,P) — with Q = N = 128 every matmul hits the 128×128 MXU natively
(P = 64 is the mamba2-780m head dim; noted in DESIGN.md).  All tiles live in
VMEM; HBM traffic is one pass over X/B/C per kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_state", "ssd_chunk_output"]


def _chunk_state_kernel(x_ref, b_ref, acum_ref, state_ref, atot_ref):
    x = x_ref[0, :, 0, :]  # (Q, P)
    b = b_ref[0, :, 0, :]  # (Q, N)
    a_cum = acum_ref[0, :, 0]  # (Q,) inclusive cumsum of log-decay
    a_total = a_cum[-1]
    decay = jnp.exp(a_total - a_cum)  # weight of token i into the chunk state
    bw = b * decay[:, None]
    state_ref[0, 0] = jnp.dot(
        bw.T, x, preferred_element_type=jnp.float32
    )  # (N, P)
    atot_ref[0, 0] = a_total


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_state(x, b, a_cum, *, interpret: bool = False):
    """Per-chunk SSD states.

    x:     (BC, Q, H, P)  chunked inputs (batch*chunks leading)
    b:     (BC, Q, G, N)  input projections (G groups, heads share groups)
    a_cum: (BC, Q, H)     inclusive within-chunk cumsum of log decay
    returns states (BC, H, N, P) f32 and a_total (BC, H) f32
    """
    bc, q, h, p = x.shape
    n = b.shape[-1]
    g = b.shape[2]
    hpg = h // g

    states, atot = pl.pallas_call(
        _chunk_state_kernel,
        grid=(bc, h),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j // hpg, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bc, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, a_cum)
    return states, atot


def _chunk_output_kernel(x_ref, b_ref, c_ref, acum_ref, prev_ref, y_ref):
    x = x_ref[0, :, 0, :]  # (Q, P)
    b = b_ref[0, :, 0, :]  # (Q, N)
    c = c_ref[0, :, 0, :]  # (Q, N)
    a_cum = acum_ref[0, :, 0]  # (Q,)
    prev = prev_ref[0, 0]  # (N, P) carried state entering this chunk

    q = x.shape[0]
    # decay mask L[i, j] = exp(a_cum[i] - a_cum[j]) * (i >= j)
    rel = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = row >= col
    l_mat = jnp.where(mask, jnp.exp(rel), 0.0)

    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y_diag = jnp.dot(scores * l_mat, x, preferred_element_type=jnp.float32)
    c_decayed = c * jnp.exp(a_cum)[:, None]
    y_off = jnp.dot(c_decayed, prev, preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y_diag + y_off


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_output(x, b, c, a_cum, prev_states, *, interpret: bool = False):
    """Chunk-local output + carried-state contribution.

    x: (BC, Q, H, P); b, c: (BC, Q, G, N); a_cum: (BC, Q, H);
    prev_states: (BC, H, N, P) — state *entering* each chunk.
    returns y (BC, Q, H, P) f32.
    """
    bc, q, h, p = x.shape
    n = b.shape[-1]
    g = b.shape[2]
    hpg = h // g

    y = pl.pallas_call(
        _chunk_output_kernel,
        grid=(bc, h),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j // hpg, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, j // hpg, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
        interpret=interpret,
    )(x, b, c, a_cum, prev_states)
    return y
