"""Device-resident fused feed hot path (ISSUE 6 tentpole).

One jitted launch per (edge, segment) chains the three layers the batched
engine runs as separate host passes:

a. **routing** — all six schemes as ``jax.numpy`` ops over device state:
   SG is round-robin arithmetic; FG/PKG/DC/WC/FISH look their candidates
   up in a precomputed consistent-hash ring table (``searchsorted`` over
   the ring points — the device mirror of ``chash.lookup_n``); PKG runs
   the exact sequential two-choice ``lax.scan``; DC/WC/FISH classify hot
   keys against a device-resident dense frequency tracker (the decayed
   epoch counting of ``kernels/fish_count.py``, here over the per-key
   table the CHK pass reads) and pick per tuple via a masked-argmin scan
   (FISH: the Eq. 2 wait-time argmin against the Alg. 3 estimator state);
b. **FIFO** — the closed-form per-worker recurrence solved on device,
   either as one ``lax.scan`` (exact, the CPU default) or as
   ``jax.lax.associative_scan`` over a segmented maximum-accumulate
   (``fifo_impl="assoc"``, the depth-log parallel form, default on TPU);
c. **keyed-state update** — per-(key, worker) pane aggregate tables
   updated by scatter-add inside the same launch; panes sync to the host
   :class:`~repro.state.window.KeyedStateManager` only at pane boundaries
   and membership events (``merge_entries`` accumulates, so a pane can be
   synced mid-way and continue on zeroed device tables exactly).  The
   standalone probe/accumulate kernel behind the ``"device"`` store
   backend lives in :mod:`repro.kernels.store_probe`.

A steady-state ``session.feed(batch)`` is therefore **one** device
dispatch (counted in :attr:`FusedEdgeRunner.dispatches`, surfaced as
``EdgeResult.dispatches``): per-key state (tracker, CHK memory, replica
matrix, pane tables) stays device-resident across feeds; only the small
per-worker vectors (busy, counts, estimator) and the per-tuple finish
times cross the boundary as part of the launch round-trip.

Shape discipline: segment lengths pad to power-of-two buckets (min
:data:`MIN_BUCKET`) so varying RecordBatch lengths reuse one trace;
:data:`TRACE_COUNT` increments per trace for the compile-count
regression test.  Everything sized per-key is a dense table of
``key_capacity + 1`` rows (row = key id, last row = phantom absorbing
the padding lanes), everything per-worker has ``busy_len + 1`` lanes
(last = phantom worker).  Worker-universe or key-capacity growth and
ring rebuilds with a different point count change static shapes and
recompile — rare, documented in DESIGN.md §11.

Semantics vs the reference oracle (DESIGN.md §6): SG/FG/PKG routing,
counts, replicas and window aggregates are exact (timing carries an f32
epsilon from the on-device relative clock); DC/WC/FISH read frequencies
at segment granularity from a dense (unbounded) tracker and FISH ticks
its estimator at segment starts — bounded drift, same class as the
batched engine's sub-chunking.
"""

from __future__ import annotations

import sys as _sys
import types as _types
from hashlib import sha1 as _sha1
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..obs.metrics import GLOBAL_METRICS
from ..obs.telemetry import Telemetry


__all__ = ["FusedEdgeRunner", "fused_reject_reason", "TRACE_COUNT",
           "MIN_BUCKET", "KEY_CAP_LIMIT"]

#: The compile-count regression probe, absorbed into the metrics registry
#: (ISSUE 9): ``feed_fused.TRACE_COUNT`` remains readable *and* writable as
#: a module attribute (a property on the module class at the bottom of this
#: file), but the cell itself is this process-wide registry counter —
#: retraces are a property of the jit cache, not of any one session.
_TRACE_COUNTER = GLOBAL_METRICS.counter("fused.trace_count")

#: Shared disabled bundle for runners no session bound telemetry to.
_NULL_TELEMETRY = Telemetry(enabled=False)
MIN_BUCKET = 64  # smallest pow2 padding bucket for segment lengths
KEY_CAP_LIMIT = 1 << 21  # dense per-key tables; larger key ids fall back

_SEG_CACHE: dict = {}  # static signature -> jitted segment function

_SCHEMES = ("sg", "fg", "pkg", "dc", "wc", "fish")
_RING_SCHEMES = ("fg", "pkg", "dc", "wc", "fish")
_BIG_I32 = jnp.int32(2 ** 30)  # device constant: referenced in traced code


def _bucket(n: int) -> int:
    """Smallest power of two >= n that is >= MIN_BUCKET."""
    return max(MIN_BUCKET, 1 << (int(n) - 1).bit_length())


def _pow2_at_least(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def fused_reject_reason(grouper, keys_arr: np.ndarray,
                        values: Optional[np.ndarray],
                        state_sink, tuple_observer) -> Optional[str]:
    """Why this feed cannot run fused (None = it can).  Checked per feed;
    any reason makes the edge fall back to the batched engine for good."""
    scheme = getattr(grouper, "name", None)
    if scheme not in _SCHEMES:
        return f"scheme {scheme!r} has no fused routing"
    if scheme == "fish" and not getattr(grouper, "use_consistent_hash", True):
        return "fused FISH requires the consistent-hash candidate path"
    if tuple_observer is not None:
        return ("fused mode feeds keyed state through state_sink, not "
                "tuple_observer")
    if keys_arr.shape[0]:
        kmin = int(keys_arr.min())
        kmax = int(keys_arr.max())
        if kmin < 0:
            return "fused key tables are dense; negative key ids"
        if kmax >= KEY_CAP_LIMIT:
            return (f"fused key tables are dense; key id {kmax} exceeds "
                    f"capacity limit {KEY_CAP_LIMIT}")
    if state_sink is not None:
        from ..state.window import tuple_values

        op = state_sink.op
        vals = tuple_values(op, keys_arr, payload=values)
        if vals.shape[0]:
            lim = (2 ** 31 - 1) // max(op.stride, 1)
            if int(np.abs(vals).max()) > lim:
                return ("pane aggregates could overflow int32: "
                        f"|value| > {lim} at stride {op.stride}")
    return None


# ---------------------------------------------------------------------------
# ring candidate table — the device mirror of chash.lookup_n
# ---------------------------------------------------------------------------


def _build_ring_table(ring, dmax: int):
    """(sorted ring points uint32, (R, dmax) int32 first-d-distinct-owners).

    ``searchsorted(points, h, side='right') % R`` lands on the same ring
    position as ``bisect_right`` + wrap in ``chash.lookup``; row r holds
    the first ``dmax`` distinct owners walking clockwise from position r —
    exactly ``lookup_n``'s prefix for every d <= dmax.  Rebuilt host-side
    only on membership change (the ring only changes there); rows are
    padded with -1 past the number of distinct live owners.
    """
    pts_l = ring._points
    r_n = len(pts_l)
    pts = np.asarray(pts_l, dtype=np.uint32)
    owners = [ring._owner[p] for p in pts_l]
    d_eff = min(dmax, len(set(owners)))
    cands = np.full((r_n, dmax), -1, dtype=np.int32)
    for r in range(r_n):
        seen = set()
        out = []
        i = r
        while len(out) < d_eff:
            o = owners[i]
            if o not in seen:
                seen.add(o)
                out.append(o)
            i += 1
            if i == r_n:
                i = 0
        cands[r, :d_eff] = out
    return pts, cands


# ---------------------------------------------------------------------------
# traced segment bodies
# ---------------------------------------------------------------------------


def _fifo_scan(busy, caps, workers, t):
    """Exact sequential FIFO: f_i = max(busy[w_i], t_i) + caps[w_i]."""

    def step(b, x):
        w, tt = x
        f = jnp.maximum(b[w], tt) + caps[w]
        return b.at[w].set(f), f

    return jax.lax.scan(step, busy, (workers, t))


def _fifo_assoc(busy, caps, workers, t):
    """Closed-form FIFO via ``associative_scan`` (ISSUE 6 tentpole, part b).

    Sort by worker (stable), then within a worker's run of rank j the
    recurrence unrolls to ``f_j = (j+1)P + max(b0, cummax_j(t_k - kP))``;
    the inner cummax is a segmented maximum-accumulate keyed on the worker
    id, evaluated in O(log n) depth.  Equal to :func:`_fifo_scan` up to
    f32 rounding."""
    n = workers.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    order = jnp.argsort(workers)  # stable in jnp
    ws = workers[order]
    ts = t[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ws[1:] != ws[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(first, iota, 0))
    j = (iota - seg_start).astype(jnp.float32)
    capw = caps[ws]
    g = ts - j * capw

    def comb(a, b):
        aw, ag = a
        bw, bg = b
        return bw, jnp.where(aw == bw, jnp.maximum(ag, bg), bg)

    _, m = jax.lax.associative_scan(comb, (ws, g))
    f = (j + 1.0) * capw + jnp.maximum(busy[ws], m)
    fin = jnp.zeros_like(f).at[order].set(f)
    return busy.at[ws].max(f), fin


def _ring_rows(a, width=None):
    """(n_pad, width or dmax) candidate rows for this segment's hashed
    keys.

    Key→candidates is fixed between membership changes, so when the key
    table is smaller than the segment the ring walk runs once per *key*
    (over the dense hash cache) and tuples gather their row — ~4× fewer
    binary-search probes at 16k-tuple segments.  Phantom-row gathers
    clamp (JAX OOB semantics) and are masked off by ``valid``.  Schemes
    with a fixed fanout (fg: 1, pkg: 2) pass ``width`` so the per-tuple
    gather moves ``width`` candidates instead of the full dmax row."""
    r_n = a["pts"].shape[0]
    cands = a["cands"] if width is None else a["cands"][:, :width]
    if "hash_arr" in a:
        idx = jnp.searchsorted(a["pts"], a["hash_arr"], side="right") % r_n
        return cands[idx][a["keys"]]
    idx = jnp.searchsorted(a["pts"], a["h"], side="right") % r_n
    return cands[idx]


def _route_pkg(a, row):
    """Exact sequential two-choice with cumulative counts (tie -> first)."""
    c0 = row[:, 0]
    c1 = jnp.where(row[:, 1] >= 0, row[:, 1], row[:, 0])

    def step(counts, x):
        a0, a1, v = x
        w = jnp.where(counts[a0] <= counts[a1], a0, a1)
        w = jnp.where(v, w, a["phantom_w"])
        return counts.at[w].add(v.astype(jnp.int32)), w

    return jax.lax.scan(step, a["counts"], (c0, c1, a["valid"]))


def _tracker_update(a, scheme):
    """Dense per-key frequency tracker update (whole segment at once,
    mirroring the batched engine's update-then-classify sub-chunk order).
    Returns (trk, f per tuple, f_top)."""
    one = jnp.where(a["valid"], 1.0, 0.0)
    if scheme == "fish":
        # decay-weighted contributions: a tuple decays once per epoch
        # boundary after it inside the segment, none before it.  cexp_t
        # counts the boundaries at or before tuple t (the boundary decay
        # fires before the tuple is counted); pre_decay covers a segment
        # starting exactly on a boundary.
        cexp = ((a["g0"] + jnp.arange(a["valid"].shape[0], dtype=jnp.int32))
                // a["epoch"]) - (a["g0"] // a["epoch"]) + a["pre_decay"]
        wgt = one * jnp.power(a["alpha"],
                              a["c_total"] - cexp.astype(jnp.float32))
        trk = a["trk"] * jnp.power(a["alpha"], a["c_total"])
        trk = trk.at[a["keys"]].add(wgt)
    else:  # dc/wc: no decay (reference tracker runs alpha=1, epoch=2^62)
        trk = a["trk"].at[a["keys"]].add(one)
    total = jnp.sum(trk)
    f = jnp.where(total > 0.0, trk[a["keys"]] / total, 0.0)
    f_top = jnp.where(total > 0.0, jnp.max(trk) / total, 0.0)
    return trk, f, f_top


def _route_dcwc(a, row, scheme):
    """DC/WC: hot keys spread over d ring candidates (DC) or the whole
    live set (WC); light keys are the exact PKG two-choice.  One masked-
    argmin ``lax.scan`` over cumulative counts mirrors the sequential
    least-loaded selection (argmin tie -> first candidate in ring order,
    matching ``min(cl, key=counts.__getitem__)``; WC's full-set argmin
    tie -> smallest worker id, matching the (count, id) heap)."""
    trk, f, _ = _tracker_update(a, scheme)
    hot = f > a["theta"]
    wnum = a["wnum"]  # live worker-universe size (traced; can grow mid-run)
    d_heavy = jnp.clip(jnp.ceil(f * wnum / jnp.sqrt(a["theta"])),
                       2.0, wnum).astype(jnp.int32)
    d = jnp.where(hot, d_heavy, 2)
    dmax = row.shape[1]
    iota_d = jnp.arange(dmax, dtype=jnp.int32)

    def step(counts, x):
        r, dd, h, v = x
        waits = jnp.where((iota_d < dd) & (r >= 0), counts[r], _BIG_I32)
        w = r[jnp.argmin(waits)]
        if scheme == "wc":
            full = jnp.where(a["act_mask"], counts, _BIG_I32)
            w = jnp.where(h, jnp.argmin(full).astype(w.dtype), w)
        w = jnp.where(v, w, a["phantom_w"])
        return counts.at[w].add(v.astype(jnp.int32)), w

    counts, workers = jax.lax.scan(
        step, a["counts"], (row, d, hot, a["valid"]))
    return counts, workers, trk


def _route_fish(a, row):
    """FISH: Alg. 1 (dense decayed tracker) + Alg. 2 (CHK with monotone
    memory M_k) + Alg. 3 (per-tuple Eq. 2 wait-time argmin against the
    estimator state) — the per-tuple oracle's selection with frequencies
    read at segment granularity."""
    trk, f, f_top = _tracker_update(a, "fish")
    hot = (f > a["theta"]) & (f > 0.0) & (f_top > 0.0)
    ratio = jnp.maximum(f_top / jnp.maximum(f, 1e-30), 1.0)
    index = jnp.clip(jnp.floor(jnp.log2(ratio)), 0.0, 30.0)
    wnum = a["wnum"]
    d0 = jnp.clip(jnp.floor(wnum / jnp.exp2(index)),
                  a["d_min"].astype(jnp.float32), wnum).astype(jnp.int32)
    m_prev = a["m_k"][a["keys"]]
    d = jnp.where(hot, jnp.maximum(d0, m_prev), 2)
    m_k = a["m_k"].at[a["keys"]].max(
        jnp.where(hot & a["valid"], jnp.maximum(m_prev, d0), 0))

    # estimator tick (Alg. 3 Eq. 1), applied once at segment start when due
    backlog, assigned = a["ebl"], a["eas"]
    work = (backlog + assigned) * a["ecaps"]
    ticked = jnp.where(work > a["elapsed"],
                       (work - a["elapsed"]) / a["ecaps"], 0.0)
    backlog = jnp.where(a["do_tick"] > 0, ticked, backlog)
    assigned = jnp.where(a["do_tick"] > 0, 0.0, assigned)

    dmax = row.shape[1]
    iota_d = jnp.arange(dmax, dtype=jnp.int32)
    # the scan reads only `asn`; counts never feed the argmin, so they
    # accumulate in one dense pass after the loop instead of a scatter
    # per step.
    def step(asn, x):
        r, dd, v = x
        waits = jnp.where((iota_d < dd) & (r >= 0),
                          (backlog[r] + asn[r]) * a["ecaps"][r], jnp.inf)
        w = r[jnp.argmin(waits)]
        w = jnp.where(v, w, a["phantom_w"])
        return asn.at[w].add(jnp.where(v, 1.0, 0.0)), w

    assigned, workers = jax.lax.scan(
        step, assigned, (row, d, a["valid"]))
    lanes = jnp.arange(a["counts"].shape[0], dtype=workers.dtype)
    counts = a["counts"] + jnp.sum(
        (workers[None, :] == lanes[:, None]) & a["valid"][None, :],
        axis=1).astype(jnp.int32)
    return counts, workers, trk, m_k, backlog, assigned


def _get_seg_fn(sig):
    """Build (or fetch) the jitted segment function for one static shape
    signature — (scheme, padded length, worker lanes, key rows, ring
    points, candidate width, pane?, fresh pane?, fifo impl) is the
    recompile boundary."""
    fn = _SEG_CACHE.get(sig)
    if fn is not None:
        return fn
    scheme, n_pad, w1, kcap1, r_n, dmax, has_pane, reset, fifo_impl = sig
    phantom_w = w1 - 1
    fifo = _fifo_scan if fifo_impl == "scan" else _fifo_assoc

    def seg(dev, a):
        # `dev` holds the per-key device tables (replica matrix, tracker,
        # pane planes) — donated, so XLA updates them in place instead of
        # copying the ~MB accumulators every launch
        _TRACE_COUNTER.add(1)  # runs at trace time only
        a = dict(a)
        a.update(dev)
        a["phantom_w"] = jnp.int32(phantom_w)
        # padding is always the array tail, so validity is derived from
        # the live count instead of shipping a bool lane per tuple
        a["valid"] = jnp.arange(n_pad, dtype=jnp.int32) < a["m"]
        out = {}
        trk = None
        def _count(workers):
            # dense broadcast-sum: ~3x cheaper than a 1-lane scatter on
            # the CPU backend at these worker counts
            lanes = jnp.arange(w1, dtype=jnp.int32)
            seg = ((workers[None, :] == lanes[:, None])
                   & a["valid"][None, :]).sum(axis=1, dtype=jnp.int32)
            return a["counts"] + seg

        if scheme == "sg":
            iota = jnp.arange(n_pad, dtype=jnp.int32)
            workers = a["act"][(a["rr"] + iota) % a["a_live"]]
            workers = jnp.where(a["valid"], workers, phantom_w)
            counts = _count(workers)
        else:
            row = _ring_rows(a, {"fg": 1, "pkg": 2}.get(scheme))
            if scheme == "fg":
                workers = jnp.where(a["valid"], row[:, 0], phantom_w)
                counts = _count(workers)
            elif scheme == "pkg":
                counts, workers = _route_pkg(a, row)
            elif scheme in ("dc", "wc"):
                counts, workers, trk = _route_dcwc(a, row, scheme)
            else:  # fish
                (counts, workers, trk, m_k, backlog,
                 assigned) = _route_fish(a, row)
                out["m_k"] = m_k
                out["ebl"] = backlog
                out["eas"] = assigned
        if trk is not None:
            out["trk"] = trk

        busy, fin = fifo(a["busy"], a["caps"], workers, a["t"])
        out["fin"] = fin
        out["busy"] = busy
        out["counts"] = counts
        if has_pane:
            # one stacked scatter updates value and count planes together,
            # through a flat row index (1-D indexed scatters lower to a
            # cheaper XLA scatter than 2-D ones on CPU); its count plane
            # then gives the replica update as a dense OR — both measurably
            # cheaper than separate 2-D scatters
            vc = jnp.stack([jnp.where(a["valid"], a["vals"], 0),
                            a["valid"].astype(jnp.int32)], axis=-1)
            # worker-major flat index: the host flush's flatnonzero then
            # yields entries already grouped per worker with keys
            # ascending, so it needs no sort at all
            flat = workers * kcap1 + a["keys"]
            # `reset` marks the first segment of a pane: the tables start
            # from in-jit zeros (a fused memset) instead of round-tripping
            # an eagerly allocated zero buffer through the launch
            base = (jnp.zeros((w1 * kcap1, 2), jnp.int32) if reset
                    else a["pane_tab"].reshape(w1 * kcap1, 2))
            # indices are in-bounds by construction (the phantom worker
            # lane and phantom key row absorb padding), so skipping the
            # per-element bounds check measurably speeds the CPU scatter
            pane = base.at[flat].add(
                vc, mode="promise_in_bounds").reshape(w1, kcap1, 2)
            out["pane_tab"] = pane
            # contiguous count-plane copy: the host flush scans this with
            # one flatnonzero instead of a strided nonzero over the table
            out["pane_cnt"] = pane[:, :, 1]
            out["repl"] = a["repl"] | (pane[:, :, 1] > 0).T
            gidx = a["seg_base"] + jnp.arange(n_pad, dtype=jnp.int32)
            gidx = jnp.where(a["valid"], gidx, -1)
            lanes = jnp.arange(w1, dtype=jnp.int32)
            seg_last = jnp.max(
                jnp.where(workers[None, :] == lanes[:, None],
                          gidx[None, :], -1), axis=1)
            out["pane_last"] = (seg_last if reset else
                                jnp.maximum(a["pane_last"], seg_last))
        else:
            out["repl"] = a["repl"].at[a["keys"], workers].set(True)
        return out

    fn = _SEG_CACHE[sig] = jax.jit(seg, donate_argnums=0)
    return fn


# ---------------------------------------------------------------------------
# the per-edge runner (device state residency across feeds)
# ---------------------------------------------------------------------------


class FusedEdgeRunner:
    """Device-resident execution state of one fused edge.

    Lives on ``EdgeState.device`` across feeds.  Per-key state —
    frequency tracker, CHK memory, replica matrix, open pane tables —
    stays on device between launches; per-worker vectors (busy, counts,
    estimator) round-trip with each launch as arguments/outputs, keeping
    the host copies authoritative so event handling and metrics never
    need a separate sync.  ``host_sync`` folds the replica matrix back
    into the grouper — called before metrics/close and membership events.
    """

    def __init__(self, grouper, state, sink, telemetry=None):
        self.scheme = grouper.name
        self.has_pane = sink is not None
        self.fifo_impl = ("assoc" if jax.default_backend() == "tpu"
                          else "scan")
        # ISSUE 9: launch/pane counters live in the metrics registry; the
        # legacy ``dispatches`` attribute is a property over the counter
        # (per-feed window on a cumulative cell — see ``begin_feed``)
        self.tel = telemetry if telemetry is not None else _NULL_TELEMETRY
        self._c_dispatches = self.tel.metrics.counter(
            "fused.dispatches", scheme=self.scheme)
        self._c_pane_flushes = self.tel.metrics.counter(
            "fused.pane_flushes", scheme=self.scheme)
        self._c_host_syncs = self.tel.metrics.counter(
            "fused.host_syncs", scheme=self.scheme)
        self._feed_base_dispatches = 0
        self._prev_hot: set = set()   # fish hot set at the last epoch point
        self._fish_epoch_idx = -1
        self._fish_epochs_crossed = 0
        self.pane_fed = 0         # tuples in the device pane, unsynced
        self._kcap = 0
        self._w1 = 0
        self._dmax = 1 if self.scheme == "fg" else (
            2 if self.scheme == "pkg" else 0)  # 0 = worker-universe width
        self._pts = None          # ring points (np uint32)
        self._cands = None        # ring candidate rows (np int32)
        self._pts_dev = None
        self._cands_dev = None
        self._hash_arr = None     # dense key -> hash32 cache (np uint32)
        self._hash_ok = None
        self._repl_dirty = False
        # device-resident per-key state
        self.trk = None
        self.m_k = None
        self.repl = None
        self.pane_tab = None      # (w1, kcap1, 2): value / count planes
        self.pane_cnt = None      # contiguous count plane for the flush scan
        self.pane_last = None
        self._repl_synced = None  # host mirror of already-synced pairs

    @property
    def dispatches(self) -> int:
        """Launches in the current feed (the ``EdgeResult.dispatches``
        source) — a per-feed window on the registry's cumulative
        ``fused.dispatches`` counter, so the registry and the report can
        never disagree."""
        return self._c_dispatches.value - self._feed_base_dispatches

    # -- shape management (the recompile boundary; rare) --------------------
    def _ensure_shapes(self, grouper, state, kmax: int) -> None:
        w1 = state.busy_until.shape[0] + 1
        new_kcap = self._kcap
        if kmax >= new_kcap:
            new_kcap = _pow2_at_least(max(kmax + 1, MIN_BUCKET))
        if w1 == self._w1 and new_kcap == self._kcap:
            return
        old_k, old_w = self._kcap, self._w1
        kcap1 = new_kcap + 1
        self._hash_arr = _grow1(self._hash_arr, old_k, new_kcap, np.uint32)
        self._hash_ok = _grow1(self._hash_ok, old_k, new_kcap, np.bool_)
        if self.scheme in _RING_SCHEMES and new_kcap <= (1 << 14):
            # prefill the whole ring-hash cache at the (rare) resize so
            # steady-state feeds never touch SHA-1; for sparse key spaces
            # past 16k ids stay lazy per feed
            self._fill_hashes(np.flatnonzero(~self._hash_ok))
        # the old phantom key row (index old_k) is dropped by the [:old_k]
        # copy — it only ever holds the padding lanes' sink entries
        self.trk = _grow_dev1(self.trk, old_k, kcap1, jnp.float32)
        self.m_k = _grow_dev1(self.m_k, old_k, kcap1, jnp.int32)
        self.repl = _grow_dev2(self.repl, old_k, old_w, kcap1, w1, jnp.bool_)
        self._repl_synced = _grow_host2(self._repl_synced, old_k, old_w,
                                        kcap1, w1)
        if self.has_pane and self.pane_tab is not None:
            # an empty (flushed) pane stays None — the next launch's
            # `reset` variant rebuilds it at the new shape from zeros
            self.pane_tab = _grow_dev3(self.pane_tab, old_k, old_w,
                                       kcap1, w1)
            self.pane_cnt = _grow_dev2(self.pane_cnt, old_w, old_k,
                                       w1, kcap1, jnp.int32)
            self.pane_last = _grow_last(self.pane_last, old_w, w1)
        grew_w = w1 != self._w1
        self._kcap = new_kcap
        self._w1 = w1
        if grew_w:
            self.refresh_membership(grouper, state)

    def refresh_membership(self, grouper, state) -> None:
        """Rebuild the device ring table + live-set arrays after a
        membership change (or worker-universe growth)."""
        ring_span = self.tel.tracer.span("fused.refresh_membership",
                                         cat="fused")
        if self.scheme in _RING_SCHEMES:
            dmax = self._dmax or max(state.busy_until.shape[0], 2)
            self._pts, self._cands = _build_ring_table(grouper.ring, dmax)
            self._pts_dev = jnp.asarray(self._pts)
            self._cands_dev = jnp.asarray(self._cands)
        act = np.asarray(sorted(state.active), dtype=np.int32)
        self._act = act
        self._act_pad = np.full(self._w1, self._w1 - 1, np.int32)
        self._act_pad[:act.shape[0]] = act
        self._act_mask = np.zeros(self._w1, bool)
        self._act_mask[act] = True
        ring_span.set(live=int(act.shape[0])).done()

    # -- per-feed lifecycle -------------------------------------------------
    def begin_feed(self, grouper, state, keys_arr, values, times,
                   sink) -> None:
        self._feed_base_dispatches = self._c_dispatches.value
        with self.tel.tracer.span("fused.begin_feed", cat="fused",
                                  n=int(keys_arr.shape[0])):
            self._base = float(times[0]) if times.shape[0] else 0.0
            kmax = int(keys_arr.max()) if keys_arr.shape[0] else 0
            self._ensure_shapes(grouper, state, kmax)
            self._feed_keys = keys_arr.astype(np.int32)
            self._feed_times = times
            if self.scheme in _RING_SCHEMES:
                self._feed_hash = self._hashes(keys_arr)
            if self.has_pane:
                from ..state.window import tuple_values

                self._feed_vals = tuple_values(
                    sink.op, keys_arr, payload=values).astype(np.int32)

    def _fill_hashes(self, miss: np.ndarray) -> None:
        if miss.shape[0]:
            # inlined hash32 for plain int keys (same SHA-1 bucket as
            # chash.hash32): skips the per-key canonicalise/dispatch
            sha1, fb = _sha1, int.from_bytes
            self._hash_arr[miss] = np.fromiter(
                (fb(sha1(repr(k).encode("utf-8")).digest()[:4], "big")
                 for k in miss.tolist()),
                dtype=np.uint32, count=miss.shape[0])
            self._hash_ok[miss] = True

    def _hashes(self, keys_arr: np.ndarray) -> np.ndarray:
        ok = self._hash_ok[keys_arr]
        if not ok.all():
            self._fill_hashes(np.unique(keys_arr[~ok]))
        return self._hash_arr[keys_arr]

    def run_segment(self, grouper, state, lo: int, hi: int) -> np.ndarray:
        """One fused launch for tuples [lo, hi) of the current feed.
        Returns their absolute finish times (float64, host)."""
        tracer = self.tel.tracer
        seg_span = tracer.span("fused.segment", cat="fused",
                               scheme=self.scheme, lo=lo, hi=hi)
        prep_span = tracer.span("fused.segment.prep", cat="fused")
        m = hi - lo
        n_pad = _bucket(m)
        w1 = self._w1
        kcap1 = self._kcap + 1
        scheme = self.scheme

        keys_i = np.full(n_pad, self._kcap, np.int32)  # pad -> phantom row
        keys_i[:m] = self._feed_keys[lo:hi]
        t = np.zeros(n_pad, np.float32)
        t[:m] = self._feed_times[lo:hi] - self._base

        busy = np.zeros(w1, np.float32)
        busy[:w1 - 1] = state.busy_until - self._base
        caps = np.ones(w1, np.float32)
        caps[:w1 - 1] = state.capacities
        counts = np.zeros(w1, np.int32)
        cn = grouper.assigned_counts.shape[0]
        # the device kernel compares counts pairwise (PKG/DC argmin), never
        # absolutely — shifting all workers by the running minimum keeps
        # every comparison identical while the int64 lifetime totals stay
        # host-side, so 10⁸-tuple runs (contracts.SCALE_TARGET) never push
        # the int32 device domain past 2³¹ (ISSUE 10)
        counts_base = int(grouper.assigned_counts.min()) if cn else 0
        rebased = grouper.assigned_counts - counts_base
        if rebased.max(initial=0) + m > 2 ** 31 - 1:
            raise ValueError(
                "fused feed: per-worker count spread exceeds int32 "
                f"(max-min = {int(rebased.max(initial=0))}, feed m = {m})")
        counts[:cn] = rebased

        # host-side inputs go in as plain numpy — jit transfers them at
        # dispatch for a fraction of the cost of an eager jnp conversion
        # per array (the dominant host overhead at 16k-tuple feeds).
        # Per-key tables ride in `dev`, the donated arg: each is replaced
        # by its updated output, never read again through the old handle.
        dev = {"repl": self.repl}
        a = {"keys": keys_i, "m": np.int32(m), "t": t, "busy": busy,
             "caps": caps, "counts": counts}
        r_n = 0
        dmax = 0
        if scheme == "sg":
            a["act"] = self._act_pad
            a["a_live"] = np.int32(self._act.shape[0])
            a["rr"] = np.int32(grouper._rr)
        else:
            a["pts"] = self._pts_dev
            a["cands"] = self._cands_dev
            r_n = self._pts.shape[0]
            dmax = self._cands.shape[1]
            if kcap1 <= n_pad:  # static per sig: route keys, gather tuples
                a["hash_arr"] = self._hash_arr
            else:
                h = np.zeros(n_pad, np.uint32)
                h[:m] = self._feed_hash[lo:hi]
                a["h"] = h
        if scheme in ("dc", "wc", "fish"):
            dev["trk"] = self.trk
            a["theta"] = np.float32(self._theta(grouper))
            a["wnum"] = np.float32(grouper.num_workers)
            if scheme == "wc":
                a["act_mask"] = self._act_mask
        if scheme == "fish":
            fa = self._fish_args(grouper, lo, hi, state.offset)
            dev["m_k"] = fa.pop("m_k")
            a.update(fa)
        reset = False
        if self.has_pane:
            vals = np.zeros(n_pad, np.int32)
            vals[:m] = self._feed_vals[lo:hi]
            a["vals"] = vals
            reset = self.pane_tab is None  # first segment of a fresh pane
            if not reset:
                dev["pane_tab"] = self.pane_tab
                dev["pane_last"] = self.pane_last
            a["seg_base"] = np.int32(state.offset + lo)

        sig = (scheme, n_pad, w1, kcap1, r_n, dmax, self.has_pane, reset,
               self.fifo_impl)
        prep_span.done()
        # the one device dispatch: routing, FIFO and state-scatter run as
        # a single fused launch, so the phases share this span (the
        # ``phases`` arg names them for the Perfetto detail pane — see
        # DESIGN.md §14 on why they cannot be timed separately)
        with tracer.span("fused.segment.launch", cat="fused", n_pad=n_pad,
                         phases="route|fifo|state-scatter"):
            out = _get_seg_fn(sig)(dev, a)
        self._c_dispatches.add(1)

        # device-resident state stays device-side
        self.repl = out["repl"]
        if "trk" in out:
            self.trk = out["trk"]
        if "m_k" in out:
            self.m_k = out["m_k"]
        if self.has_pane:
            self.pane_tab = out["pane_tab"]
            self.pane_cnt = out["pane_cnt"]
            self.pane_last = out["pane_last"]
            self.pane_fed += m
        self._repl_dirty = True

        # small per-worker vectors ride back with the launch's output fetch
        with tracer.span("fused.segment.readback", cat="fused"):
            state.busy_until[:] = self._base + np.asarray(
                out["busy"], dtype=np.float64)[:w1 - 1]
            grouper.assigned_counts[:] = counts_base + np.asarray(
                out["counts"], dtype=np.int64)[:cn]
            if scheme == "sg":
                grouper._rr = int((grouper._rr + m) % self._act.shape[0])
            elif scheme == "fish":
                est = grouper.estimator
                nw = est.backlog.shape[0]
                est.backlog[:] = np.asarray(out["ebl"],
                                            dtype=np.float64)[:nw]
                est.assigned[:] = np.asarray(out["eas"],
                                             dtype=np.float64)[:nw]
            fin = self._base + np.asarray(out["fin"], dtype=np.float64)[:m]
        if (scheme == "fish" and self.tel.enabled
                and self._fish_epochs_crossed):
            self._fish_epoch_points(grouper, state, lo, hi)
        seg_span.done()
        return fin

    def _theta(self, grouper) -> float:
        if self.scheme == "fish":
            return grouper.params.theta(grouper.num_workers)
        return grouper.theta  # dc/wc property (theta_frac / num_workers)

    def _fish_args(self, grouper, lo: int, hi: int, offset: int) -> dict:
        p = grouper.params
        est = grouper.estimator
        g0 = offset + lo
        g1 = offset + hi
        # epoch-boundary decay fires *before* the boundary tuple is
        # counted, so a segment starting exactly on a boundary decays once
        # up front
        pre = 1 if (g0 > 0 and g0 % p.epoch == 0) else 0
        c_total = (g1 - 1) // p.epoch - g0 // p.epoch + pre
        self._fish_epochs_crossed = c_total
        self._fish_epoch_idx = g1 // p.epoch
        now0 = float(self._feed_times[lo])
        do_tick = 0
        elapsed = 0.0
        if now0 - est._t_prior > est.interval:
            do_tick = 1
            elapsed = now0 - est._t_prior
            est._t_prior = now0
        w1 = self._w1
        ebl = np.zeros(w1, np.float32)
        eas = np.zeros(w1, np.float32)
        ecaps = np.ones(w1, np.float32)
        nw = est.backlog.shape[0]
        ebl[:nw] = est.backlog
        eas[:nw] = est.assigned
        ecaps[:nw] = est.capacities
        return {"m_k": self.m_k, "alpha": np.float32(p.alpha),
                "epoch": np.int32(p.epoch), "g0": np.int32(g0),
                "pre_decay": np.int32(pre),
                "c_total": np.float32(c_total),
                "d_min": np.int32(p.d_min),
                "ebl": ebl, "eas": eas, "ecaps": ecaps,
                "do_tick": np.int32(do_tick),
                "elapsed": np.float32(elapsed)}

    def _fish_epoch_points(self, grouper, state, lo: int, hi: int) -> None:
        """Per-epoch FISH timeline (telemetry-enabled only): hot-set size
        and churn read off the *device* tracker after a segment that
        crossed one or more epoch boundaries, plus the per-worker
        imbalance at that instant.  ``np.asarray`` of a CPU jax buffer is
        a zero-copy view, so this costs one small reduction per crossed
        epoch batch, never per tuple."""
        epoch_idx = self._fish_epoch_idx
        self.tel.ctx.epoch_idx = epoch_idx
        trk = np.asarray(self.trk)[:-1]  # drop the phantom padding row
        total = float(trk.sum())
        theta = grouper.params.theta(grouper.num_workers)
        hot = (set(np.flatnonzero(trk > theta * total).tolist())
               if total > 0.0 else set())
        churn = len(hot ^ self._prev_hot)
        self._prev_hot = hot
        tl = self.tel.timeline
        tl.point("fish.hot_set_size", len(hot), epoch_idx=epoch_idx)
        tl.point("fish.hot_set_churn", churn, epoch_idx=epoch_idx)
        counts = grouper.assigned_counts
        act = self._act
        if act.shape[0] and counts[act].sum() > 0:
            share = counts[act]
            tl.point("fish.worker_imbalance",
                     float(share.max() / max(share.mean(), 1e-12)),
                     epoch_idx=epoch_idx)
        self.tel.tracer.instant(
            "fish.epoch_decay", cat="fish", epoch=epoch_idx,
            crossed=int(self._fish_epochs_crossed), hot_set=len(hot))

    # -- host sync points ---------------------------------------------------
    def flush_pane(self, sink) -> None:
        """Sync the open device pane into the host KeyedStateManager and
        drop the device tables (``merge_entries`` accumulates, so the pane
        can keep filling on device afterwards)."""
        if not self.has_pane or self.pane_fed == 0:
            return
        self._c_pane_flushes.add(1)
        flush_span = self.tel.tracer.span("fused.pane_flush", cat="fused",
                                          pane_fed=self.pane_fed)
        cnt = np.asarray(self.pane_cnt)
        tab = np.asarray(self.pane_tab).reshape(-1, 2)
        last = np.asarray(self.pane_last)
        # phantom row/lane never accumulate (padding lanes scatter zeros),
        # so one flatnonzero over the contiguous count plane finds every
        # live entry — already per-worker grouped with keys ascending,
        # because the device table is worker-major
        flat = np.flatnonzero(cnt)
        entries = []
        if flat.shape[0]:
            ws, ks0 = np.divmod(flat, cnt.shape[1])
            ks = ks0.astype(np.int64)
            vs = tab[flat, 0].astype(np.int64)
            cs = tab[flat, 1].astype(np.int64)
            starts = np.concatenate(
                [[0], np.flatnonzero(ws[1:] != ws[:-1]) + 1, [ws.shape[0]]])
            for s, e in zip(starts[:-1].tolist(), starts[1:].tolist()):
                w = int(ws[s])
                entries.append((w, ks[s:e], vs[s:e], cs[s:e], int(last[w])))
        sink.feed_aggregated(self.pane_fed, entries)
        # None marks the pane empty — the next segment's launch starts
        # from in-jit zeros (its `reset` variant), so no buffer is
        # allocated or transferred here
        self.pane_tab = None
        self.pane_cnt = None
        self.pane_last = None
        self.pane_fed = 0
        flush_span.done()

    def host_sync(self, grouper) -> None:
        """Fold device-resident per-key state back into the grouper: new
        (key, worker) replica pairs since the last sync.  Called before
        metrics/close and before membership events."""
        if not self._repl_dirty:
            return
        self._c_host_syncs.add(1)
        with self.tel.tracer.span("fused.host_sync", cat="fused"):
            dev = np.asarray(self.repl)
            new = dev[:-1, :-1] & ~self._repl_synced[:-1, :-1]
            for k, w in zip(*np.nonzero(new)):
                grouper.replicas.setdefault(int(k), set()).add(int(w))
            # asarray of a CPU device buffer is a view, and self.repl is
            # donated to the next launch — copy before the buffer is
            # reused
            self._repl_synced = dev.copy()
            self._repl_dirty = False


# -- growth helpers (rare: each growth is a recompile boundary) -------------


def _grow1(arr, old, new, dtype):
    out = np.zeros(new, dtype)
    if arr is not None:
        out[:old] = arr[:old]
    return out


def _grow_dev1(arr, old, new1, dtype):
    out = jnp.zeros((new1,), dtype)
    return out if arr is None else out.at[:old].set(arr[:old])


def _grow_dev2(arr, old_k, old_w, kcap1, w1, dtype):
    out = jnp.zeros((kcap1, w1), dtype)
    if arr is None:
        return out
    # the old phantom column (old_w - 1) may only hold phantom-row entries,
    # which the [:old_k] row slice already drops — safe to copy columns
    return out.at[:old_k, :old_w].set(arr[:old_k, :old_w])


def _grow_dev3(arr, old_k, old_w, kcap1, w1):
    # pane tables are worker-major: (w1, kcap1, 2)
    out = jnp.zeros((w1, kcap1, 2), jnp.int32)
    if arr is None:
        return out
    return out.at[:old_w, :old_k, :].set(arr[:old_w, :old_k, :])


def _grow_host2(arr, old_k, old_w, kcap1, w1):
    out = np.zeros((kcap1, w1), bool)
    if arr is not None:
        out[:old_k, :old_w] = arr[:old_k, :old_w]
    return out


def _grow_last(arr, old_w, w1):
    out = jnp.full((w1,), -1, jnp.int32)
    return out if arr is None else out.at[:old_w].set(arr[:old_w])


# ---------------------------------------------------------------------------
# TRACE_COUNT module-attribute compatibility (ISSUE 9 counter unification)
# ---------------------------------------------------------------------------


class _FeedFusedModule(_types.ModuleType):
    """Routes ``feed_fused.TRACE_COUNT`` reads *and* writes through the
    registry counter.  A plain module ``__getattr__`` cannot do this: the
    first ``feed_fused.TRACE_COUNT += 1`` (the ``TraceBudget`` test does
    exactly that) would create a module-dict shadow and fork the count.  A
    data descriptor on the module class intercepts both directions."""

    @property
    def TRACE_COUNT(self) -> int:
        return _TRACE_COUNTER.value

    @TRACE_COUNT.setter
    def TRACE_COUNT(self, v: int) -> None:
        _TRACE_COUNTER.set(v)


_sys.modules[__name__].__class__ = _FeedFusedModule
