"""Pallas kernel: keyed-state probe/accumulate (ISSUE 6 tentpole, part c).

The per-worker keyed state store is a table of key slots; folding a routed
chunk into it is "for each tuple, find its key's slot and accumulate
(value, count)".  The sequential form probes per tuple; here the whole
chunk is batched with the same slot discipline as
:mod:`repro.kernels.fish_count`: the O(N_chunk × K_slots) key-vs-slot
comparison matrix is evaluated block-by-block on the VPU with the token
axis tiled through VMEM, producing per-slot accumulated sums

* ``vsum``    — Σ value over the chunk's tuples landing in each slot,
* ``csum``    — tuple count per slot, and
* ``matched`` — per-token hit flags (misses are new keys the caller
  inserts host-side before re-probing — the open-addressing slow path).

The slot table stays resident in VMEM across the grid (the bounded-scope
insight again: a pane's live key set is small); only token blocks stream
HBM→VMEM.  Accumulation is int32 so merged aggregates stay exact — the
state-store contract (order-independent int sums) must survive the device
round-trip bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["store_probe"]

_BLOCK_N = 1024  # tokens per grid step (VMEM tile)


def _store_probe_kernel(table_ref, keys_ref, vals_ref, vsum_ref, csum_ref,
                        matched_ref):
    step = pl.program_id(0)
    tbl = table_ref[...]  # (1, K) int32, resident
    ks = keys_ref[...]  # (block_n, 1) int32
    vs = vals_ref[...]  # (block_n, 1) int32

    eq = (ks == tbl) & (tbl >= 0)  # (block_n, K) — the probe matrix

    @pl.when(step == 0)
    def _init():
        vsum_ref[...] = jnp.zeros_like(vsum_ref)
        csum_ref[...] = jnp.zeros_like(csum_ref)

    vsum_ref[...] += jnp.sum(jnp.where(eq, vs, 0), axis=0, keepdims=True)
    csum_ref[...] += jnp.sum(eq.astype(jnp.int32), axis=0, keepdims=True)
    matched_ref[...] = jnp.any(eq, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def store_probe(
    table_keys: jnp.ndarray,
    batch_keys: jnp.ndarray,
    batch_vals: jnp.ndarray,
    *,
    block_n: int = _BLOCK_N,
    interpret: bool = False,
):
    """Blocked probe/accumulate of one routed chunk against a slot table.

    table_keys: (K,) int32 slot keys, -1 marks empty slots.  K should be a
                multiple of 128 for TPU lane alignment (ops.py pads).
    batch_keys: (N,) int32 tuple key ids (>= 0).
    batch_vals: (N,) int32 per-tuple values (``repro.state.window.
                tuple_values`` folded to int32 — the caller guards range).
    returns:    vsum (K,) int32, csum (K,) int32, matched (N,) bool.
    """
    k = table_keys.shape[0]
    n = batch_keys.shape[0]
    n_pad = -n % block_n
    keys2d = jnp.pad(batch_keys, (0, n_pad), constant_values=-2).reshape(-1, 1)
    vals2d = jnp.pad(batch_vals, (0, n_pad)).reshape(-1, 1)
    table2d = table_keys.reshape(1, k)
    grid = (keys2d.shape[0] // block_n,)

    vsum, csum, matched = pl.pallas_call(
        _store_probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # table resident in VMEM
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),  # token tile
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),  # value tile
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # accumulated across grid
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((keys2d.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(table2d, keys2d, vals2d)
    return vsum[0], csum[0], matched[:n, 0].astype(bool)
