"""Pallas TPU kernel: FISH intra-epoch match-and-count (the Alg. 1 hotspot).

Every tuple of an epoch must be compared against the bounded counter table
``K`` (paper Alg. 1 line 8: ``if k in K``).  Sequential SpaceSaving does this
tuple-by-tuple; on TPU we batch the whole epoch: the O(N_epoch × K_max)
comparison matrix is evaluated block-by-block on the VPU with the token axis
tiled through VMEM, producing

* ``counts``  — per-table-slot occurrence counts for this epoch
  (Alg. 1 line 9, batched), and
* ``matched`` — per-token membership flags (drives the batched ReplaceMin
  merge done by the caller — see ``repro.core.fish.epoch_update``).

The table (K_max ≤ a few thousand ids) stays resident in VMEM across the
whole grid; only token blocks stream HBM→VMEM.  Arithmetic intensity is
~K_max compares per 4-byte token load, so the kernel is firmly compute-bound
on the VPU — exactly the term the paper's epoch batching is designed to
shrink (one decay pass per epoch instead of per tuple).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fish_count"]

_BLOCK_N = 1024  # tokens per grid step (VMEM tile)


def _fish_count_kernel(table_ref, keys_ref, counts_ref, matched_ref):
    step = pl.program_id(0)
    tbl = table_ref[...]  # (1, K) int32, resident
    ks = keys_ref[...]  # (block_n, 1) int32

    eq = (ks == tbl) & (tbl >= 0)  # (block_n, K) — the O(N·K) hotspot

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    counts_ref[...] += jnp.sum(eq.astype(jnp.float32), axis=0, keepdims=True)
    matched_ref[...] = jnp.any(eq, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fish_count(
    table_keys: jnp.ndarray,
    batch_keys: jnp.ndarray,
    *,
    block_n: int = _BLOCK_N,
    interpret: bool = False,
):
    """Blocked epoch match-and-count.

    table_keys: (K,) int32, -1 marks empty slots.  K should be a multiple of
                128 for TPU lane alignment (the wrapper in ops.py pads).
    batch_keys: (N,) int32 tuple/key ids (>= 0).
    returns:    counts (K,) float32, matched (N,) bool.
    """
    k = table_keys.shape[0]
    n = batch_keys.shape[0]
    n_pad = -n % block_n
    keys2d = jnp.pad(batch_keys, (0, n_pad), constant_values=-2).reshape(-1, 1)
    table2d = table_keys.reshape(1, k)
    grid = (keys2d.shape[0] // block_n,)

    counts, matched = pl.pallas_call(
        _fish_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # table resident in VMEM
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),  # token tile
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # accumulated across grid
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((keys2d.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(table2d, keys2d)
    return counts[0], matched[:n, 0].astype(bool)
