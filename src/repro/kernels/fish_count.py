"""Pallas TPU kernel: FISH intra-epoch match-and-count (the Alg. 1 hotspot).

Every tuple of an epoch must be compared against the bounded counter table
``K`` (paper Alg. 1 line 8: ``if k in K``).  Sequential SpaceSaving does this
tuple-by-tuple; on TPU we batch the whole epoch: the O(N_epoch × K_max)
comparison matrix is evaluated block-by-block on the VPU with the token axis
tiled through VMEM, producing

* ``counts``  — per-table-slot occurrence counts for this epoch
  (Alg. 1 line 9, batched), and
* ``matched`` — per-token membership flags (drives the batched ReplaceMin
  merge done by the caller — see ``repro.core.fish.epoch_update``).

The table (K_max ≤ a few thousand ids) stays resident in VMEM across the
whole grid; only token blocks stream HBM→VMEM.  Arithmetic intensity is
~K_max compares per 4-byte token load, so the kernel is firmly compute-bound
on the VPU — exactly the term the paper's epoch batching is designed to
shrink (one decay pass per epoch instead of per tuple).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fish_count", "fish_epoch_count"]

_BLOCK_N = 1024  # tokens per grid step (VMEM tile)


def _fish_count_kernel(table_ref, keys_ref, counts_ref, matched_ref):
    step = pl.program_id(0)
    tbl = table_ref[...]  # (1, K) int32, resident
    ks = keys_ref[...]  # (block_n, 1) int32

    eq = (ks == tbl) & (tbl >= 0)  # (block_n, K) — the O(N·K) hotspot

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    counts_ref[...] += jnp.sum(eq.astype(jnp.float32), axis=0, keepdims=True)
    matched_ref[...] = jnp.any(eq, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fish_count(
    table_keys: jnp.ndarray,
    batch_keys: jnp.ndarray,
    *,
    block_n: int = _BLOCK_N,
    interpret: bool = False,
):
    """Blocked epoch match-and-count.

    table_keys: (K,) int32, -1 marks empty slots.  K should be a multiple of
                128 for TPU lane alignment (the wrapper in ops.py pads).
    batch_keys: (N,) int32 tuple/key ids (>= 0).
    returns:    counts (K,) float32, matched (N,) bool.
    """
    k = table_keys.shape[0]
    n = batch_keys.shape[0]
    n_pad = -n % block_n
    keys2d = jnp.pad(batch_keys, (0, n_pad), constant_values=-2).reshape(-1, 1)
    table2d = table_keys.reshape(1, k)
    grid = (keys2d.shape[0] // block_n,)

    counts, matched = pl.pallas_call(
        _fish_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # table resident in VMEM
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),  # token tile
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # accumulated across grid
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((keys2d.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(table2d, keys2d)
    return counts[0], matched[:n, 0].astype(bool)


# ---------------------------------------------------------------------------
# Fused epoch kernel (ISSUE 1): decay + match-count + candidate histogram
# ---------------------------------------------------------------------------


def _fish_epoch_kernel(alpha, block_n, table_ref, counts_ref, keys_ref,
                       all_keys_ref, new_counts_ref, matched_ref, cand_ref,
                       first_ref):
    step = pl.program_id(0)
    tbl = table_ref[...]  # (1, K) int32, resident
    ks = keys_ref[...]  # (block_n, 1) int32
    all_k = all_keys_ref[...]  # (1, N_pad) int32, resident

    eq = (ks == tbl) & (tbl >= 0)  # (block_n, K) — the O(N·K) hotspot

    @pl.when(step == 0)
    def _init():
        # inter-epoch TimeDecayingUpdate fused into the same launch
        new_counts_ref[...] = counts_ref[...] * jnp.float32(alpha)

    new_counts_ref[...] += jnp.sum(eq.astype(jnp.float32), axis=0,
                                   keepdims=True)
    matched_ref[...] = jnp.any(eq, axis=1, keepdims=True).astype(jnp.int32)

    # candidate epoch histogram: occurrences of each token's key within the
    # whole epoch batch (O(N_epoch) per token on the VPU), plus a
    # first-occurrence flag so the caller can dedupe without a host sort
    eq_all = (ks == all_k) & (all_k >= 0)  # (block_n, N_pad)
    cand_ref[...] = jnp.sum(eq_all.astype(jnp.float32), axis=1, keepdims=True)
    gid = step * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (ks.shape[0], 1), 0
    )
    col = jax.lax.broadcasted_iota(jnp.int32, eq_all.shape, 1)
    earlier = eq_all & (col < gid)
    first_ref[...] = (
        jnp.sum(earlier.astype(jnp.int32), axis=1, keepdims=True) == 0
    ).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("alpha", "block_n", "interpret")
)
def fish_epoch_count(
    table_keys: jnp.ndarray,
    table_counts: jnp.ndarray,
    batch_keys: jnp.ndarray,
    *,
    alpha: float,
    block_n: int = _BLOCK_N,
    interpret: bool = False,
):
    """One fused launch for a whole epoch (ISSUE 1 tentpole):

    1. inter-epoch decay      — ``counts * alpha`` (Alg. 1 lines 23-26),
    2. intra-epoch counting   — per-slot occurrence counts + match flags
       (Alg. 1 lines 8-9, the O(N_epoch × K_max) hotspot), and
    3. candidate histogram    — per-token epoch frequency of *its own* key
       plus a first-occurrence flag, which is exactly the unmatched-new-key
       histogram the batched ReplaceMin needs (replaces the host-side
       sort + segment-count pass in ``epoch_update``).

    The candidate histogram costs O(N_epoch²) compares and keeps the whole
    padded epoch resident in VMEM, so this kernel is sized for the paper's
    epoch regime (N_epoch ≈ 1e3-1e4: ≤ ~1e8 VPU compares, tens of KB
    resident).  For much larger epochs, split the batch into several
    epoch-sized calls or fall back to the unfused `epoch_update` path,
    whose candidate pass is O(N log N) on host.

    table_keys:  (K,) int32, -1 marks empty slots (K: multiple of 128 for
                 lane alignment — ops.py pads).
    table_counts:(K,) float32 decayed counters.
    batch_keys:  (N,) int32 key ids (>= 0).
    returns:     new_counts (K,) f32 = alpha*counts + epoch delta,
                 matched (N,) bool, cand_count (N,) f32, is_first (N,) bool.
    """
    k = table_keys.shape[0]
    n = batch_keys.shape[0]
    n_pad = -n % block_n
    keys2d = jnp.pad(batch_keys, (0, n_pad), constant_values=-2).reshape(-1, 1)
    all2d = keys2d.reshape(1, -1)
    table2d = table_keys.reshape(1, k)
    counts2d = table_counts.astype(jnp.float32).reshape(1, k)
    n_tot = keys2d.shape[0]
    grid = (n_tot // block_n,)

    kern = functools.partial(_fish_epoch_kernel, alpha, block_n)
    new_counts, matched, cand, first = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # table resident
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # counters resident
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),  # token tile
            pl.BlockSpec((1, n_tot), lambda i: (0, 0)),  # whole epoch resident
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),  # accumulated over grid
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((n_tot, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tot, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_tot, 1), jnp.int32),
        ],
        interpret=interpret,
    )(table2d, counts2d, keys2d, all2d)
    return (
        new_counts[0],
        matched[:n, 0].astype(bool),
        cand[:n, 0],
        first[:n, 0].astype(bool),
    )
