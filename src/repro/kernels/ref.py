"""Pure-jnp oracles for every Pallas kernel (exact, unblocked)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fish_count_ref", "fish_epoch_count_ref", "ssd_ref",
           "ssd_chunked_ref"]


def fish_count_ref(table_keys: jnp.ndarray, batch_keys: jnp.ndarray):
    """Oracle for kernels.fish_count: full equality matrix."""
    eq = (batch_keys[:, None] == table_keys[None, :]) & (table_keys[None, :] >= 0)
    counts = jnp.sum(eq, axis=0).astype(jnp.float32)
    matched = jnp.any(eq, axis=1)
    return counts, matched


def fish_epoch_count_ref(table_keys: jnp.ndarray, table_counts: jnp.ndarray,
                         batch_keys: jnp.ndarray, *, alpha: float):
    """Oracle for kernels.fish_epoch_count: decay + match + histogram,
    all as full equality matrices."""
    delta, matched = fish_count_ref(table_keys, batch_keys)
    new_counts = table_counts.astype(jnp.float32) * jnp.float32(alpha) + delta
    self_eq = batch_keys[:, None] == batch_keys[None, :]
    cand = jnp.sum(self_eq, axis=1).astype(jnp.float32)
    n = batch_keys.shape[0]
    col = jnp.arange(n)[None, :]
    first = jnp.sum(self_eq & (col < jnp.arange(n)[:, None]), axis=1) == 0
    return new_counts, matched, cand, first


def ssd_ref(x, a, b, c, initial_state=None):
    """Exact sequential SSD recurrence (oracle for the chunked kernels).

    x: (B, S, H, P); a: (B, S, H) log decay; b, c: (B, S, G, N).
    returns y (B, S, H, P), final_state (B, H, N, P), all float32.
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hpg = h // g
    bh = jnp.repeat(b, hpg, axis=2)  # (B, S, H, N)
    ch = jnp.repeat(c, hpg, axis=2)

    def step(state, inp):
        xt, at, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        state = state * jnp.exp(at)[..., None, None] + (
            bt[..., :, None] * xt[..., None, :]
        )  # (B,H,N,P)
        yt = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, yt

    state0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(a, 1, 0).astype(jnp.float32),
        jnp.moveaxis(bh, 1, 0).astype(jnp.float32),
        jnp.moveaxis(ch, 1, 0).astype(jnp.float32),
    )
    final, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), final


def ssd_chunked_ref(x, a, b, c, chunk: int, initial_state=None):
    """Chunked-math oracle (same algorithm as the kernels, pure jnp).

    Used to separate "chunking math correct" from "Pallas tiling correct".
    Shapes as in ssd_ref.
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    hpg = h // g

    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    ac = a.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc_ = b.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    bh = jnp.repeat(bc_, hpg, axis=3)  # (B,NC,Q,H,N)
    ch = jnp.repeat(cc, hpg, axis=3)

    a_cum = jnp.cumsum(ac, axis=2)  # inclusive, (B,NC,Q,H)
    a_tot = a_cum[:, :, -1, :]  # (B,NC,H)

    # per-chunk states
    decay = jnp.exp(a_tot[:, :, None, :] - a_cum)  # (B,NC,Q,H)
    states = jnp.einsum("bnqh,bnqhk,bnqhp->bnhkp", decay, bh, xc)  # k=N

    # scan across chunks
    def comb(prev, inp):
        st, at = inp
        new = prev * jnp.exp(at)[..., None, None] + st
        return new, prev  # emit state *entering* the chunk

    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    final, prev_states = jax.lax.scan(
        comb, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,NC,H,N,P)

    # chunk-local quadratic part + carried contribution
    rel = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,NC,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bnqhk,bnshk->bnqsh", ch, bh)  # (B,NC,Q,Q,H)
    y_diag = jnp.einsum("bnqsh,bnshp->bnqhp", scores * l_mat, xc)
    y_off = jnp.einsum(
        "bnqhk,bnqh,bnhkp->bnqhp", ch, jnp.exp(a_cum), prev_states
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final
