"""FISH core: the paper's contribution (Algs. 1-3, CHK, consistent hashing,
baseline groupings, DSPE simulator)."""

from .assignment import WorkerStateEstimator, select_min_wait
from .baselines import (
    DChoices,
    FieldGrouping,
    FishGrouper,
    Grouper,
    PartialKeyGrouping,
    ShuffleGrouping,
    WChoices,
    make_grouper,
)
from .chash import ConsistentHashRing, hash32
from .fish import (
    EpochFrequencyTracker,
    FishParams,
    FishState,
    chk_num_workers,
    classify_hot_keys,
    epoch_update,
    init_fish_state,
)
from .stream import (
    CapacityEvent,
    EdgeResult,
    EdgeState,
    MembershipEvent,
    StreamMetrics,
    at_time,
    edge_metrics,
    simulate_edge,
    simulate_stream,
    simulate_stream_reference,
)

__all__ = [
    "WorkerStateEstimator",
    "select_min_wait",
    "DChoices",
    "FieldGrouping",
    "FishGrouper",
    "Grouper",
    "PartialKeyGrouping",
    "ShuffleGrouping",
    "WChoices",
    "make_grouper",
    "ConsistentHashRing",
    "hash32",
    "EpochFrequencyTracker",
    "FishParams",
    "FishState",
    "chk_num_workers",
    "classify_hot_keys",
    "epoch_update",
    "init_fish_state",
    "CapacityEvent",
    "EdgeResult",
    "EdgeState",
    "MembershipEvent",
    "StreamMetrics",
    "at_time",
    "edge_metrics",
    "simulate_edge",
    "simulate_stream",
    "simulate_stream_reference",
]
