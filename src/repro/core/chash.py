"""Consistent hashing with virtual nodes (paper §5, Fig. 8).

Maps keys onto a 2^32 ring; workers are placed via ``v`` virtual nodes each
(paper Fig. 8(d)) so that small deployments stay balanced.  Worker addition /
removal only remaps the keys between the affected ring arcs (monotonicity —
property-tested in tests/test_chash.py).

The hash is SHA-1 truncated to 32 bits, per the paper's footnote 3 ([35] =
RFC 3174 SHA-1).
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Dict, Hashable, Iterable, List, Optional

import numpy as np

__all__ = ["hash32", "ConsistentHashRing"]

_RING = 1 << 32


def _canon(value):
    """Canonicalise numpy scalars so ``np.int32(5)`` and ``5`` hash alike.

    The batched grouping engine interns keys to int32 ids while the sequential
    reference iterates numpy scalars out of the same array; both must land on
    the same ring position.
    """
    if isinstance(value, np.generic):
        return value.item()
    if isinstance(value, tuple):
        return tuple(_canon(v) for v in value)
    return value


def hash32(value) -> int:
    """SHA-1 based 32-bit bucket id (paper footnote 3)."""
    if not isinstance(value, bytes):
        value = repr(_canon(value)).encode("utf-8")
    return int.from_bytes(hashlib.sha1(value).digest()[:4], "big")


class ConsistentHashRing:
    """Clockwise consistent-hash ring with virtual nodes."""

    def __init__(self, workers: Iterable[Hashable] = (), virtual_nodes: int = 64):
        if virtual_nodes < 1:
            raise ValueError("virtual_nodes must be >= 1")
        self.virtual_nodes = virtual_nodes
        self._points: List[int] = []  # sorted ring positions
        self._owner: Dict[int, Hashable] = {}  # position -> worker
        self._workers: Dict[Hashable, List[int]] = {}
        for w in workers:
            self.add_worker(w)

    # -- membership --------------------------------------------------------------
    def add_worker(self, worker: Hashable) -> None:
        if worker in self._workers:
            raise KeyError(f"worker {worker!r} already on ring")
        points = []
        for i in range(self.virtual_nodes):
            pos = hash32((worker, i))
            while pos in self._owner:  # extremely unlikely collision
                pos = (pos + 1) % _RING
            self._owner[pos] = worker
            bisect.insort(self._points, pos)
            points.append(pos)
        self._workers[worker] = points

    def remove_worker(self, worker: Hashable) -> None:
        points = self._workers.pop(worker)
        for pos in points:
            del self._owner[pos]
            idx = bisect.bisect_left(self._points, pos)
            del self._points[idx]

    def clone(self) -> "ConsistentHashRing":
        """Structural copy without re-hashing any virtual node.

        Building a W=128 ring costs W×v SHA-1 calls; cloning is a few dict
        copies.  Used by the grouper factory to amortise ring construction
        across benchmark runs.
        """
        ring = ConsistentHashRing((), virtual_nodes=self.virtual_nodes)
        ring._points = list(self._points)
        ring._owner = dict(self._owner)
        ring._workers = {w: list(ps) for w, ps in self._workers.items()}
        return ring

    @property
    def workers(self) -> List[Hashable]:
        return list(self._workers)

    def __len__(self) -> int:
        return len(self._workers)

    def __contains__(self, worker) -> bool:
        return worker in self._workers

    # -- lookup -------------------------------------------------------------------
    def lookup(self, key) -> Hashable:
        """Nearest worker clockwise from hash(key) (paper Fig. 8(a))."""
        if not self._points:
            raise LookupError("ring is empty")
        pos = hash32(key)
        idx = bisect.bisect_right(self._points, pos)
        if idx == len(self._points):
            idx = 0  # wrap around the ring
        return self._owner[self._points[idx]]

    def lookup_n(self, key, n: int) -> List[Hashable]:
        """First ``n`` *distinct* workers clockwise — candidate set for a hot
        key that CHK assigned d workers (Alg. 2 'through a consistent hash')."""
        if not self._points:
            raise LookupError("ring is empty")
        n = min(n, len(self._workers))
        pos = hash32(key)
        idx = bisect.bisect_right(self._points, pos)
        out: List[Hashable] = []
        seen = set()
        total = len(self._points)
        for step in range(total):
            owner = self._owner[self._points[(idx + step) % total]]
            if owner not in seen:
                seen.add(owner)
                out.append(owner)
                if len(out) == n:
                    break
        return out
