"""FISH epoch-based recent hot-key identification (paper Alg. 1 + Alg. 2).

Two implementations live here:

* :class:`EpochFrequencyTracker` — the paper-faithful *sequential* host-side
  implementation: per-tuple SpaceSaving with replace-min (count inherited from
  the evicted minimum, Alg. 1 lines 19-22) and per-epoch time decay
  (``TimeDecayingUpdate``, lines 23-26).  This is what the reproduction
  benchmarks use.
* :func:`epoch_update` / :func:`classify_hot_keys` — branch-free ``jax.lax``
  versions for the device-side fast path (MoE routing).  The match-and-count
  hotspot is the Pallas kernel in :mod:`repro.kernels.fish_count`; here we keep
  a pure-jnp fallback with the same semantics (epoch-batched ReplaceMin — see
  DESIGN.md §4 for the fidelity note and tests for the Jaccard bound vs. the
  sequential oracle).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FishParams",
    "EpochFrequencyTracker",
    "FishState",
    "init_fish_state",
    "epoch_update",
    "classify_hot_keys",
    "chk_num_workers",
]


# ---------------------------------------------------------------------------
# Parameters (defaults follow the paper's §6.3 recommendations)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FishParams:
    """Tunables of FISH (paper Table 1 + §6.3).

    alpha:   inter-epoch time decaying factor (paper default 0.2).
    epoch:   number of sequential tuples per epoch, ``N_epoch`` (default 1000).
    k_max:   capacity of the bounded counter set ``K`` (default 1000).
    theta_frac: hot-key threshold as a fraction of ``2/n``; the paper settles
        on θ = 1/(4n) for n workers, i.e. ``theta = theta_frac / num_workers``
        with ``theta_frac = 0.25``.
    d_min:   minimal number of workers for a hot key (Alg. 2).
    """

    alpha: float = 0.2
    epoch: int = 1000
    k_max: int = 1000
    theta_frac: float = 0.25
    d_min: int = 2

    def theta(self, num_workers: int) -> float:
        return self.theta_frac / float(num_workers)


# ---------------------------------------------------------------------------
# Host-side, paper-faithful sequential tracker (Alg. 1)
# ---------------------------------------------------------------------------


class EpochFrequencyTracker:
    """Sequential SpaceSaving-with-decay tracker — exact Alg. 1.

    ``update(key)`` processes one tuple; every ``epoch`` tuples all counters
    are multiplied by ``alpha`` *before* the tuple is counted (Alg. 1 lines
    4-7 run at the top of the loop body).
    """

    def __init__(self, params: FishParams):
        self.params = params
        self.counts: Dict[object, float] = {}
        self._tuples_in_epoch = 0
        self.total_seen = 0
        self.epochs_completed = 0

    # -- Alg. 1 main loop body -------------------------------------------------
    def update(self, key) -> None:
        p = self.params
        if self._tuples_in_epoch == p.epoch:
            self._time_decaying_update()
            self._tuples_in_epoch = 0
            self.epochs_completed += 1
        counts = self.counts
        if key in counts:
            counts[key] += 1.0
        elif len(counts) < p.k_max:
            counts[key] = 1.0
        else:
            self._replace_min(key)
        self._tuples_in_epoch += 1
        self.total_seen += 1

    def update_many(self, keys: Sequence) -> None:
        for k in keys:
            self.update(k)

    # -- Alg. 1 ReplaceMin -----------------------------------------------------
    def _replace_min(self, key) -> None:
        k_min = min(self.counts, key=self.counts.get)
        c_min = self.counts.pop(k_min)
        # "its occurrence number is set to that of replaced ones plus 1"
        self.counts[key] = c_min + 1.0

    # -- Alg. 1 TimeDecayingUpdate ----------------------------------------------
    def _time_decaying_update(self) -> None:
        a = self.params.alpha
        if a == 0.0:
            self.counts.clear()
            return
        for k in self.counts:
            self.counts[k] *= a

    # -- queries ----------------------------------------------------------------
    def frequency(self, key) -> float:
        """Relative frequency estimate f_k (counter / Σ counters)."""
        total = sum(self.counts.values())
        if total <= 0.0:
            return 0.0
        return self.counts.get(key, 0.0) / total

    def frequencies(self) -> Dict[object, float]:
        total = sum(self.counts.values())
        if total <= 0.0:
            return {k: 0.0 for k in self.counts}
        return {k: c / total for k, c in self.counts.items()}

    def top_frequency(self) -> float:
        total = sum(self.counts.values())
        if total <= 0.0:
            return 0.0
        return max(self.counts.values()) / total

    def hot_keys(self, num_workers: int) -> Dict[object, float]:
        theta = self.params.theta(num_workers)
        return {k: f for k, f in self.frequencies().items() if f > theta}


# ---------------------------------------------------------------------------
# CHK — Classification of Hot Key (Alg. 2), scalar host form
# ---------------------------------------------------------------------------


def chk_num_workers(
    f_k: float,
    f_top: float,
    theta: float,
    num_workers: int,
    d_min: int = 2,
    m_k: int = 0,
) -> Tuple[int, int]:
    """Alg. 2: number of candidate workers ``d`` for a key with frequency f_k.

    Returns ``(d, new_m_k)``; ``m_k`` is the per-key monotone memory ``M_k``.
    Non-hot keys (f_k <= theta) get d = 2 (PKG fallback) and M_k unchanged.
    """
    if f_k <= theta or f_k <= 0.0 or f_top <= 0.0:
        return 2, m_k
    # index = floor(log2(f_top / f_k)); d = W / 2^index
    index = int(math.floor(math.log2(max(f_top / f_k, 1.0))))
    d = num_workers // (2**index) if index < 63 else 0
    d = max(d, d_min)
    d = min(d, num_workers)
    if m_k < d:
        m_k = d
    else:
        d = m_k
    return d, m_k


# ---------------------------------------------------------------------------
# Device-side state + epoch-batched update (jax.lax, jit-able)
# ---------------------------------------------------------------------------


class FishState(dict):
    """Pytree: bounded counter table on device.

    keys:   (k_max,) int32   — key ids, -1 for empty slots
    counts: (k_max,) float32 — decayed occurrence counters
    """

    def __init__(self, keys, counts):
        super().__init__(keys=keys, counts=counts)

    @property
    def keys_arr(self):
        return self["keys"]

    @property
    def counts_arr(self):
        return self["counts"]


def init_fish_state(k_max: int) -> FishState:
    return FishState(
        keys=jnp.full((k_max,), -1, dtype=jnp.int32),
        counts=jnp.zeros((k_max,), dtype=jnp.float32),
    )


def _match_counts(table_keys: jnp.ndarray, batch_keys: jnp.ndarray):
    """Pure-jnp fallback of the fish_count kernel: one-hot match & count.

    Returns (counts_delta (k_max,), matched (n,) bool).
    """
    eq = (batch_keys[:, None] == table_keys[None, :]) & (table_keys[None, :] >= 0)
    counts_delta = jnp.sum(eq, axis=0).astype(jnp.float32)
    matched = jnp.any(eq, axis=1)
    return counts_delta, matched


def epoch_update(
    state: FishState,
    batch_keys: jnp.ndarray,
    *,
    alpha: float,
    max_new: int = 64,
    match_fn=None,
) -> FishState:
    """Process one epoch of keys through the bounded counter table.

    Device-side analog of Alg. 1 with epoch-batched ReplaceMin:

    1. inter-epoch decay:   counts *= alpha
    2. intra-epoch counting: counts[k] += #occurrences for keys already in K
       (the O(N·K_max) hotspot — ``match_fn`` defaults to the pure-jnp oracle;
       the Pallas kernel from kernels/ops.py can be passed instead)
    3. batched ReplaceMin: the ``max_new`` most frequent *unmatched* keys of
       this epoch are merged, each evicting the current minimum and inheriting
       ``c_min + its epoch frequency`` (Alg. 1 line 22 generalised to a batch).

    ``batch_keys``: (n,) int32 key ids (>= 0).  Static shapes throughout.
    """
    if match_fn is None:
        match_fn = _match_counts
    table_keys = state["keys"]
    counts = state["counts"] * jnp.float32(alpha)  # TimeDecayingUpdate

    counts_delta, matched = match_fn(table_keys, batch_keys)
    counts = counts + counts_delta

    # --- candidate new keys: frequency of unmatched keys within this epoch ---
    # Sort unmatched keys so identical ids are adjacent, then segment-count.
    n = batch_keys.shape[0]
    cand_keys = jnp.where(matched, jnp.int32(-1), batch_keys)
    sorted_keys = jnp.sort(cand_keys)
    new_run = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    run_len = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), run_id, num_segments=n)
    run_key = jax.ops.segment_max(sorted_keys, run_id, num_segments=n)
    run_len = jnp.where(run_key >= 0, run_len, 0.0)  # drop the matched/-1 run

    # top `max_new` candidate keys by epoch frequency
    top_len, top_idx = jax.lax.top_k(run_len, max_new)
    top_key = run_key[top_idx]

    # --- batched ReplaceMin merge -------------------------------------------
    def merge_one(carry, kv):
        tk, tc = carry
        key, freq = kv
        empty = tk < 0
        # empty slots count as min with counter 0 (insert path, Alg.1 l.12-14)
        eff = jnp.where(empty, 0.0, tc)
        slot = jnp.argmin(eff)
        c_min = eff[slot]
        do = freq > 0.0
        new_count = jnp.where(tk[slot] < 0, freq, c_min + freq)
        tk = jnp.where(do, tk.at[slot].set(key), tk)
        tc = jnp.where(do, tc.at[slot].set(new_count), tc)
        return (tk, tc), None

    (table_keys, counts), _ = jax.lax.scan(
        merge_one, (table_keys, counts), (top_key, top_len)
    )
    return FishState(keys=table_keys, counts=counts)


def classify_hot_keys(
    state: FishState,
    *,
    num_workers: int,
    theta: float,
    d_min: int = 2,
    m_k: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Vectorised CHK (Alg. 2) over the whole table.

    Returns ``(d, is_hot, new_m_k)`` — per-slot candidate-worker counts
    (non-hot slots get 2), hotness mask, and the updated monotone memory.
    """
    counts = state["counts"]
    total = jnp.maximum(jnp.sum(counts), 1e-30)
    f = counts / total
    f_top = jnp.max(f)
    is_hot = f > theta
    ratio = jnp.maximum(f_top / jnp.maximum(f, 1e-30), 1.0)
    index = jnp.floor(jnp.log2(ratio)).astype(jnp.int32)
    index = jnp.clip(index, 0, 30)
    d = (num_workers // (2**index)).astype(jnp.int32)
    d = jnp.maximum(d, d_min)
    d = jnp.minimum(d, num_workers)
    if m_k is None:
        m_k = jnp.zeros_like(d)
    new_m_k = jnp.where(is_hot, jnp.maximum(m_k, d), m_k)
    d = jnp.where(is_hot, jnp.maximum(d, m_k), 2)
    return d, is_hot, new_m_k
