"""FISH epoch-based recent hot-key identification (paper Alg. 1 + Alg. 2).

Two implementations live here:

* :class:`EpochFrequencyTracker` — the paper-faithful *sequential* host-side
  implementation: per-tuple SpaceSaving with replace-min (count inherited from
  the evicted minimum, Alg. 1 lines 19-22) and per-epoch time decay
  (``TimeDecayingUpdate``, lines 23-26).  This is what the reproduction
  benchmarks use.
* :func:`epoch_update` / :func:`classify_hot_keys` — branch-free ``jax.lax``
  versions for the device-side fast path (MoE routing).  The match-and-count
  hotspot is the Pallas kernel in :mod:`repro.kernels.fish_count`; here we keep
  a pure-jnp fallback with the same semantics (epoch-batched ReplaceMin — see
  DESIGN.md §4 for the fidelity note and tests for the Jaccard bound vs. the
  sequential oracle).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FishParams",
    "EpochFrequencyTracker",
    "FishState",
    "init_fish_state",
    "epoch_update",
    "classify_hot_keys",
    "chk_num_workers",
    "chk_num_workers_batch",
]


# ---------------------------------------------------------------------------
# Parameters (defaults follow the paper's §6.3 recommendations)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FishParams:
    """Tunables of FISH (paper Table 1 + §6.3).

    alpha:   inter-epoch time decaying factor (paper default 0.2).
    epoch:   number of sequential tuples per epoch, ``N_epoch`` (default 1000).
    k_max:   capacity of the bounded counter set ``K`` (default 1000).
    theta_frac: hot-key threshold as a fraction of ``2/n``; the paper settles
        on θ = 1/(4n) for n workers, i.e. ``theta = theta_frac / num_workers``
        with ``theta_frac = 0.25``.
    d_min:   minimal number of workers for a hot key (Alg. 2).
    """

    alpha: float = 0.2
    epoch: int = 1000
    k_max: int = 1000
    theta_frac: float = 0.25
    d_min: int = 2

    def theta(self, num_workers: int) -> float:
        return self.theta_frac / float(num_workers)


# ---------------------------------------------------------------------------
# Host-side, paper-faithful sequential tracker (Alg. 1)
# ---------------------------------------------------------------------------


class EpochFrequencyTracker:
    """Sequential SpaceSaving-with-decay tracker — exact Alg. 1.

    ``update(key)`` processes one tuple; every ``epoch`` tuples all counters
    are multiplied by ``alpha`` *before* the tuple is counted (Alg. 1 lines
    4-7 run at the top of the loop body).

    ``epoch_observer`` (ISSUE 9): an optional ``f(tracker)`` fired right
    after each TimeDecayingUpdate (``epochs_completed`` already advanced) —
    the telemetry hook for per-epoch hot-set/churn timelines.  Decay is a
    uniform scaling, so the relative frequencies the observer reads are
    those the epoch ended with.
    """

    def __init__(self, params: FishParams):
        self.params = params
        self.counts: Dict[object, float] = {}
        self._tuples_in_epoch = 0
        self.total_seen = 0
        self.epochs_completed = 0
        self.epoch_observer = None

    # -- Alg. 1 main loop body -------------------------------------------------
    def update(self, key) -> None:
        p = self.params
        if self._tuples_in_epoch == p.epoch:
            self._time_decaying_update()
            self._tuples_in_epoch = 0
            self.epochs_completed += 1
            if self.epoch_observer is not None:
                self.epoch_observer(self)
        counts = self.counts
        if key in counts:
            counts[key] += 1.0
        elif len(counts) < p.k_max:
            counts[key] = 1.0
        else:
            self._replace_min(key)
        self._tuples_in_epoch += 1
        self.total_seen += 1

    def update_many(self, keys: Sequence) -> None:
        """Bulk Alg. 1 over epoch-aligned chunks (ISSUE 1 tentpole).

        Instead of one Python call per tuple, each epoch-sized chunk is one
        ``np.unique`` count plus a single batched ReplaceMin — the host mirror
        of :func:`epoch_update`.  Exact while the table is under capacity;
        at capacity it is the same epoch-batched approximation the device
        path uses (bounded divergence, see DESIGN.md §4/§6).
        """
        arr = np.asarray(keys)
        if arr.ndim != 1 or arr.dtype.kind not in "iu":
            for k in keys:  # non-integer keys: exact sequential path
                self.update(k)
            return
        p = self.params
        n = arr.shape[0]
        i = 0
        while i < n:
            if self._tuples_in_epoch == p.epoch:
                self._time_decaying_update()
                self._tuples_in_epoch = 0
                self.epochs_completed += 1
                if self.epoch_observer is not None:
                    self.epoch_observer(self)
            take = min(n - i, p.epoch - self._tuples_in_epoch)
            self._update_chunk(arr[i : i + take])
            self._tuples_in_epoch += take
            self.total_seen += take
            i += take

    def _update_chunk(self, chunk: np.ndarray) -> None:
        """One intra-epoch bulk count + batched ReplaceMin."""
        uniq, cnt = np.unique(chunk, return_counts=True)
        counts = self.counts
        new_keys: List[int] = []
        new_cnts: List[int] = []
        for k, c in zip(uniq.tolist(), cnt.tolist()):
            if k in counts:
                counts[k] += float(c)
            else:
                new_keys.append(k)
                new_cnts.append(c)
        if not new_keys:
            return
        order = np.argsort(-np.asarray(new_cnts), kind="stable")
        free = self.params.k_max - len(counts)
        for j in order[:free].tolist():  # fill empty slots, hottest first
            counts[new_keys[j]] = float(new_cnts[j])
        rest = order[free:]
        if rest.size == 0:
            return
        # batched ReplaceMin: the m hottest remaining candidates evict the m
        # smallest counters, each inheriting c_min + its epoch frequency
        # (Alg. 1 line 22 generalised to a batch).
        m = min(rest.size, self.params.k_max)
        victims = heapq.nsmallest(m, counts.items(), key=lambda kv: kv[1])
        for (k_old, c_old), j in zip(victims, rest[:m].tolist()):
            del counts[k_old]
            counts[new_keys[j]] = c_old + float(new_cnts[j])

    # -- Alg. 1 ReplaceMin -----------------------------------------------------
    def _replace_min(self, key) -> None:
        k_min = min(self.counts, key=self.counts.get)
        c_min = self.counts.pop(k_min)
        # "its occurrence number is set to that of replaced ones plus 1"
        self.counts[key] = c_min + 1.0

    # -- Alg. 1 TimeDecayingUpdate ----------------------------------------------
    def _time_decaying_update(self) -> None:
        a = self.params.alpha
        if a == 0.0:
            self.counts.clear()
            return
        for k in self.counts:
            self.counts[k] *= a

    # -- queries ----------------------------------------------------------------
    def frequency(self, key) -> float:
        """Relative frequency estimate f_k (counter / Σ counters)."""
        total = sum(self.counts.values())
        if total <= 0.0:
            return 0.0
        return self.counts.get(key, 0.0) / total

    def frequencies(self) -> Dict[object, float]:
        total = sum(self.counts.values())
        if total <= 0.0:
            return {k: 0.0 for k in self.counts}
        return {k: c / total for k, c in self.counts.items()}

    def top_frequency(self) -> float:
        total = sum(self.counts.values())
        if total <= 0.0:
            return 0.0
        return max(self.counts.values()) / total

    def hot_keys(self, num_workers: int) -> Dict[object, float]:
        theta = self.params.theta(num_workers)
        return {k: f for k, f in self.frequencies().items() if f > theta}


# ---------------------------------------------------------------------------
# CHK — Classification of Hot Key (Alg. 2), scalar host form
# ---------------------------------------------------------------------------


def chk_num_workers(
    f_k: float,
    f_top: float,
    theta: float,
    num_workers: int,
    d_min: int = 2,
    m_k: int = 0,
) -> Tuple[int, int]:
    """Alg. 2: number of candidate workers ``d`` for a key with frequency f_k.

    Returns ``(d, new_m_k)``; ``m_k`` is the per-key monotone memory ``M_k``.
    Non-hot keys (f_k <= theta) get d = 2 (PKG fallback) and M_k unchanged.
    """
    if f_k <= theta or f_k <= 0.0 or f_top <= 0.0:
        return 2, m_k
    # index = floor(log2(f_top / f_k)); d = W / 2^index
    index = int(math.floor(math.log2(max(f_top / f_k, 1.0))))
    d = num_workers // (2**index) if index < 63 else 0
    d = max(d, d_min)
    d = min(d, num_workers)
    if m_k < d:
        m_k = d
    else:
        d = m_k
    return d, m_k


def chk_num_workers_batch(
    f_k: np.ndarray,
    f_top: float,
    theta: float,
    num_workers: int,
    d_min: int = 2,
    m_k: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorised :func:`chk_num_workers` over an array of frequencies.

    Element-for-element identical to the scalar form (property-tested);
    the batched grouping engine runs it once per sub-chunk over the chunk's
    unique keys.  Returns ``(d, new_m_k)`` as int64 arrays.
    """
    f_k = np.asarray(f_k, dtype=np.float64)
    if m_k is None:
        m_k = np.zeros(f_k.shape[0], dtype=np.int64)
    hot = (f_k > theta) & (f_k > 0.0) & (f_top > 0.0)
    ratio = np.maximum(f_top / np.maximum(f_k, 1e-300), 1.0)
    index = np.floor(np.log2(ratio))
    # W // 2**index via exact power-of-two float division; index >= 63 -> 0
    d = np.where(index < 63,
                 np.floor(num_workers / np.exp2(np.minimum(index, 63))), 0.0)
    d = np.clip(d, d_min, num_workers).astype(np.int64)
    new_m_k = np.where(hot, np.maximum(m_k, d), m_k)
    d = np.where(hot, np.maximum(d, m_k), 2)
    return d, new_m_k


# ---------------------------------------------------------------------------
# Device-side state + epoch-batched update (jax.lax, jit-able)
# ---------------------------------------------------------------------------


class FishState(dict):
    """Pytree: bounded counter table on device.

    keys:   (k_max,) int32   — key ids, -1 for empty slots
    counts: (k_max,) float32 — decayed occurrence counters
    """

    def __init__(self, keys, counts):
        super().__init__(keys=keys, counts=counts)

    @property
    def keys_arr(self):
        return self["keys"]

    @property
    def counts_arr(self):
        return self["counts"]


def init_fish_state(k_max: int) -> FishState:
    return FishState(
        keys=jnp.full((k_max,), -1, dtype=jnp.int32),
        counts=jnp.zeros((k_max,), dtype=jnp.float32),
    )


def _match_counts(table_keys: jnp.ndarray, batch_keys: jnp.ndarray):
    """Pure-jnp fallback of the fish_count kernel: one-hot match & count.

    Returns (counts_delta (k_max,), matched (n,) bool).
    """
    eq = (batch_keys[:, None] == table_keys[None, :]) & (table_keys[None, :] >= 0)
    counts_delta = jnp.sum(eq, axis=0).astype(jnp.float32)
    matched = jnp.any(eq, axis=1)
    return counts_delta, matched


def epoch_update(
    state: FishState,
    batch_keys: jnp.ndarray,
    *,
    alpha: float,
    max_new: int = 64,
    match_fn=None,
    fused_fn=None,
) -> FishState:
    """Process one epoch of keys through the bounded counter table.

    Device-side analog of Alg. 1 with epoch-batched ReplaceMin:

    1. inter-epoch decay:   counts *= alpha
    2. intra-epoch counting: counts[k] += #occurrences for keys already in K
       (the O(N·K_max) hotspot — ``match_fn`` defaults to the pure-jnp oracle;
       the Pallas kernel from kernels/ops.py can be passed instead)
    3. batched ReplaceMin: the ``max_new`` most frequent *unmatched* keys of
       this epoch are merged via a vectorised sort-based merge — the bottom
       ``max_new`` counters (ascending) are paired against the top ``max_new``
       candidates (descending); each inserted key inherits ``c_min + its
       epoch frequency`` (Alg. 1 line 22 generalised to a batch).

    ``fused_fn``, when given, is the single-launch Pallas path
    (``repro.kernels.ops.fish_epoch_count``): one kernel yields the decayed
    counts + epoch delta, the match flags, and the unmatched-candidate epoch
    histogram, replacing steps 1-2 *and* the sort/segment candidate pass.

    ``batch_keys``: (n,) int32 key ids (>= 0).  Static shapes throughout.
    """
    table_keys = state["keys"]
    n = batch_keys.shape[0]
    # top_k cannot take k larger than its operand; a partial final epoch may
    # carry fewer tuples than max_new, and more than k_max inserts per epoch
    # can never land anyway
    max_new = min(max_new, int(table_keys.shape[0]), n)

    if fused_fn is not None:
        # fused: decay + match-count + candidate histogram in one launch
        counts, matched, cand_count, is_first = fused_fn(
            table_keys, state["counts"], batch_keys, alpha=alpha
        )
        scores = jnp.where(is_first & ~matched, cand_count, 0.0)
        top_len, top_idx = jax.lax.top_k(scores, max_new)
        top_key = batch_keys[top_idx]
    else:
        if match_fn is None:
            match_fn = _match_counts
        counts = state["counts"] * jnp.float32(alpha)  # TimeDecayingUpdate
        counts_delta, matched = match_fn(table_keys, batch_keys)
        counts = counts + counts_delta

        # --- candidate new keys: epoch frequency of unmatched keys ----------
        # Sort unmatched keys so identical ids are adjacent, then
        # segment-count.
        cand_keys = jnp.where(matched, jnp.int32(-1), batch_keys)
        sorted_keys = jnp.sort(cand_keys)
        new_run = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
        )
        run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
        run_len = jax.ops.segment_sum(
            jnp.ones((n,), jnp.float32), run_id, num_segments=n
        )
        run_key = jax.ops.segment_max(sorted_keys, run_id, num_segments=n)
        run_len = jnp.where(run_key >= 0, run_len, 0.0)  # drop matched/-1 run

        # top `max_new` candidate keys by epoch frequency
        top_len, top_idx = jax.lax.top_k(run_len, max_new)
        top_key = run_key[top_idx]

    # --- batched ReplaceMin: vectorised sort-based merge ---------------------
    # (replaces the former O(max_new · k_max) lax.scan — ISSUE 1 tentpole)
    empty = table_keys < 0
    eff = jnp.where(empty, 0.0, counts)  # empty slots are free minima
    bottom = jnp.argsort(eff)[:max_new]  # slots ascending by counter
    do = top_len > 0.0
    merged_counts = eff[bottom] + top_len
    table_keys = table_keys.at[bottom].set(
        jnp.where(do, top_key, table_keys[bottom])
    )
    counts = counts.at[bottom].set(
        jnp.where(do, merged_counts, counts[bottom])
    )
    return FishState(keys=table_keys, counts=counts)


def classify_hot_keys(
    state: FishState,
    *,
    num_workers: int,
    theta: float,
    d_min: int = 2,
    m_k: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Vectorised CHK (Alg. 2) over the whole table.

    Returns ``(d, is_hot, new_m_k)`` — per-slot candidate-worker counts
    (non-hot slots get 2), hotness mask, and the updated monotone memory.
    """
    counts = state["counts"]
    total = jnp.maximum(jnp.sum(counts), 1e-30)
    f = counts / total
    f_top = jnp.max(f)
    is_hot = f > theta
    ratio = jnp.maximum(f_top / jnp.maximum(f, 1e-30), 1.0)
    index = jnp.floor(jnp.log2(ratio)).astype(jnp.int32)
    index = jnp.clip(index, 0, 30)
    d = (num_workers // (2**index)).astype(jnp.int32)
    d = jnp.maximum(d, d_min)
    d = jnp.minimum(d, num_workers)
    if m_k is None:
        m_k = jnp.zeros_like(d)
    new_m_k = jnp.where(is_hot, jnp.maximum(m_k, d), m_k)
    d = jnp.where(is_hot, jnp.maximum(d, m_k), 2)
    return d, is_hot, new_m_k
