"""Heuristic worker assignment (paper Alg. 3, Eq. 1 & Eq. 2).

The source never polls workers.  It keeps, per worker:

* ``P_w`` — processing capacity = seconds per tuple (periodically sampled),
* ``C_w`` — *inferred* number of unprocessed tuples,
* ``N_w`` — tuples assigned since the last estimation tick.

Every interval ``T`` (paper: 10 s; here a configurable logical interval) the
backlog is advanced with Eq. 1::

    C_w <- ((C_w + N_w) * P_w - T) / P_w        (clamped at 0)

and a tuple is routed to the candidate with the least estimated waiting time
(Eq. 2):  ``T_w = C_w * P_w``.

The jax variant (:func:`select_min_wait`) is used on device (MoE overflow
routing / straggler-aware replica choice); :class:`WorkerStateEstimator` is
the host-side runtime piece shared by the data pipeline, the serving router
and the stream simulator.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["WorkerStateEstimator", "select_min_wait", "greedy_allocate"]


def greedy_allocate(waits: np.ndarray, caps: np.ndarray, count: int) -> np.ndarray:
    """Exact batched replay of the Alg. 3 Eq. 2 greedy.

    Applying :meth:`WorkerStateEstimator.select` ``count`` times is: pick the
    candidate with the least estimated wait, bump its wait by ``P_w``,
    repeat.  Replayed here over a (wait, index) heap — O(count log k) with
    ``count`` bounded by the engine's sub-chunk size, and bit-identical to
    the sequential trajectory (heap ties break on the smaller index, exactly
    like ``np.argmin``).  Returns integer allocations aligned with
    ``waits``/``caps``.
    """
    k = waits.shape[0]
    alloc = np.zeros(k, dtype=np.int64)
    if count <= 0:
        return alloc
    if k == 1:
        alloc[0] = count
        return alloc
    heap = [(w, i) for i, w in enumerate(waits.tolist())]
    heapq.heapify(heap)
    caps_l = caps.tolist()
    alloc_l = [0] * k
    for _ in range(count):
        w, i = heapq.heappop(heap)
        alloc_l[i] += 1
        heapq.heappush(heap, (w + caps_l[i], i))
    alloc[:] = alloc_l
    return alloc


@dataclasses.dataclass
class WorkerStateEstimator:
    """Host-side Alg. 3 state.  All times are logical seconds."""

    capacities: np.ndarray  # P_w, seconds/tuple, shape (W,)
    interval: float = 10.0  # T
    time_fn: Optional[callable] = None  # logical clock; required (no wall time)

    def __post_init__(self):
        self.capacities = np.asarray(self.capacities, dtype=np.float64)
        w = self.capacities.shape[0]
        self.backlog = np.zeros(w, dtype=np.float64)  # C_w
        self.assigned = np.zeros(w, dtype=np.float64)  # N_w
        self._t_prior = 0.0

    @property
    def num_workers(self) -> int:
        return self.capacities.shape[0]

    def ensure_size(self, num_workers: int) -> None:
        """Grow the per-worker arrays for scale-out (ids are never reused).
        New workers start at capacity 1.0 with empty backlog until a real
        sample arrives."""
        grow = num_workers - self.capacities.shape[0]
        if grow > 0:
            self.capacities = np.concatenate([self.capacities, np.ones(grow)])
            self.backlog = np.concatenate([self.backlog, np.zeros(grow)])
            self.assigned = np.concatenate([self.assigned, np.zeros(grow)])

    # -- Alg. 3 lines 3-10: periodic state estimation --------------------------
    def maybe_estimate(self, now: float) -> None:
        if now - self._t_prior > self.interval:
            work = (self.backlog + self.assigned) * self.capacities
            elapsed = now - self._t_prior
            self.backlog = np.where(
                work > elapsed, (work - elapsed) / self.capacities, 0.0
            )
            self.assigned[:] = 0.0
            self._t_prior = now

    # -- Alg. 3 lines 12-18: candidate selection -------------------------------
    def select(self, candidates: Sequence[int], now: Optional[float] = None) -> int:
        if now is not None:
            self.maybe_estimate(now)
        cand = np.asarray(list(candidates), dtype=np.int64)
        waits = (self.backlog[cand] + self.assigned[cand]) * self.capacities[cand]
        appro = int(cand[int(np.argmin(waits))])
        # line 18: C_appro <- C_appro + 1 (we track it in N_w until next tick)
        self.assigned[appro] += 1.0
        return appro

    # -- bookkeeping hooks ------------------------------------------------------
    def record_capacity_sample(self, worker: int, seconds_per_tuple: float,
                               ema: float = 0.5) -> None:
        """Periodic sampling of P_w (paper §4.2.1)."""
        self.capacities[worker] = (
            ema * seconds_per_tuple + (1.0 - ema) * self.capacities[worker]
        )

    def estimated_wait(self, worker: int) -> float:
        return float(
            (self.backlog[worker] + self.assigned[worker]) * self.capacities[worker]
        )


def select_min_wait(backlog: jnp.ndarray, capacity: jnp.ndarray,
                    candidate_mask: jnp.ndarray) -> jnp.ndarray:
    """Device-side Eq. 2 argmin over a candidate set.

    backlog:        (W,) inferred unprocessed work C_w
    capacity:       (W,) seconds/tuple P_w
    candidate_mask: (..., W) bool — True where the worker is a candidate
    returns:        (...,) int32 selected worker per row
    """
    wait = backlog * capacity  # T_w, (W,)
    wait = jnp.where(candidate_mask, wait[..., :], jnp.inf)
    return jnp.argmin(wait, axis=-1).astype(jnp.int32)
