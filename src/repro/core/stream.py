"""Discrete-event DSPE simulator (paper §6.1 "Simulation Settings").

Models the paper's Fig. 1 DAG: sources emit a keyed tuple stream, a grouping
scheme assigns each tuple to a worker, each worker is a FIFO queue with a
processing capacity ``P_w`` (seconds per tuple — heterogeneous per paper
§4.2.3 / Fig. 7).  Reported metrics mirror the paper:

* ``execution_time``  — makespan = max_w(busy-until); the paper's simulated
  load-balance metric (Figs. 9/10: "execution time ... normalised to SG").
* ``latency_*``       — per-tuple queueing latency average / p50 / p95 / p99
  (Fig. 18's deployment metric).
* ``throughput``      — tuples / makespan (Fig. 19).
* ``memory_overhead`` — Σ_w distinct keys on w (Fig. 3/11/20), plus the
  FG-normalised form.
* ``imbalance``       — (max_w load − mean_w load) / mean_w load.

Two engines share the metric plumbing (ISSUE 1 tentpole):

* :func:`simulate_stream` — the **batched** engine: the stream is cut into
  event-free segments (membership/capacity events + capacity-sample points
  are the only cut sites), each segment is routed with one ``grouper.assign_batch``
  call, and the per-worker FIFO recurrence ``f_j = max(f_{j-1}, t_j) + P_w``
  is solved in closed form with ``np.maximum.accumulate`` — zero Python work
  per tuple.
* :func:`simulate_stream_reference` — the original per-tuple loop, kept as
  the oracle for the batched-vs-reference equivalence tests (exact for
  SG/FG/PKG, bounded drift for DC/WC/FISH — see DESIGN.md §6).

Dynamic membership events (paper §5 / RQ4) are supported via
:class:`MembershipEvent`; mid-stream capacity changes (straggler onset /
recovery, heterogeneity shifts — Fig. 7) via :class:`CapacityEvent`.  Both
kinds are segment cut sites in the batched engine and may be mixed freely in
the ``events`` sequence.  Capacity sampling for FISH's estimator (Alg. 3) is
emulated with a periodic noisy sample of the true ``P_w`` — a straggler is
therefore *discovered* at the next sample point, not instantaneously.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .baselines import Grouper

__all__ = [
    "CapacityEvent",
    "MembershipEvent",
    "StreamMetrics",
    "simulate_stream",
    "simulate_stream_reference",
]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """At tuple index ``at``, switch the active worker set to ``workers``."""

    at: int
    workers: Sequence[int]


@dataclasses.dataclass(frozen=True)
class CapacityEvent:
    """At tuple index ``at``, set the *true* seconds-per-tuple of the listed
    workers (straggler onset when slower, recovery when restored)."""

    at: int
    capacities: Mapping[int, float]


@dataclasses.dataclass
class StreamMetrics:
    execution_time: float
    latency_avg: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    throughput: float
    memory_overhead: int
    memory_overhead_norm: float
    imbalance: float
    per_worker_busy: np.ndarray

    def row(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("per_worker_busy")
        return d


def _split_events(events, n: int):
    """Partition a mixed event sequence into (membership, capacity) lists
    sorted by tuple index.  Events outside [0, n) can never fire (there is
    no tuple at their index) and are dropped here — keeping them would
    stall the in-order event cursor and silently suppress later events."""
    for e in events:
        if not isinstance(e, (MembershipEvent, CapacityEvent)):
            raise TypeError(
                f"unknown event type {type(e).__name__!r}; expected "
                "MembershipEvent or CapacityEvent"
            )
    mem = sorted((e for e in events
                  if isinstance(e, MembershipEvent) and 0 <= e.at < n),
                 key=lambda e: e.at)
    cap = sorted((e for e in events
                  if isinstance(e, CapacityEvent) and 0 <= e.at < n),
                 key=lambda e: e.at)
    return mem, cap


def _apply_events(i, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
                  active, event_observer):
    """Fire every event scheduled at tuple index ``i`` (shared by both
    engines).  Returns the advanced cursors and active set."""
    while ev_idx < len(mem_ev) and mem_ev[ev_idx].at == i:
        e = mem_ev[ev_idx]
        if event_observer is not None:
            event_observer("pre_membership", grouper, e)
        active = set(e.workers)
        grouper.on_membership_change(sorted(active))
        if event_observer is not None:
            event_observer("post_membership", grouper, e)
        ev_idx += 1
    while cap_idx < len(cap_ev) and cap_ev[cap_idx].at == i:
        e = cap_ev[cap_idx]
        for wk, cap in e.capacities.items():
            capacities[wk] = cap
        if event_observer is not None:
            event_observer("capacity", grouper, e)
        cap_idx += 1
    return ev_idx, cap_idx, active


def _setup(grouper, capacities, arrival_rate, mem_ev, cap_ev):
    """Shared preamble: capacities, initial samples, busy array sizing."""
    w = grouper.num_workers
    if capacities is None:
        # feasible utilisation ~0.9 across the initial worker set
        capacities = np.full(w, 0.9 * w / arrival_rate)
    capacities = np.asarray(capacities, dtype=np.float64).copy()

    # give capacity-aware groupers their initial (noisy) samples
    for wk in range(w):
        grouper.record_capacity_sample(wk, float(capacities[wk]))

    hi_w = w - 1
    for e in mem_ev:
        if e.workers:
            hi_w = max(hi_w, max(e.workers))
    for e in cap_ev:
        if e.capacities:
            hi_w = max(hi_w, max(e.capacities))
    busy_until = np.zeros(hi_w + 1, dtype=np.float64)
    if capacities.shape[0] < busy_until.shape[0]:
        pad = np.full(busy_until.shape[0] - capacities.shape[0],
                      capacities.mean())
        capacities = np.concatenate([capacities, pad])
    return capacities, busy_until


def _metrics(grouper, busy_until, latencies, n) -> StreamMetrics:
    makespan = float(busy_until.max()) if n else 0.0
    counts = grouper.assigned_counts[: len(busy_until)].astype(np.float64)
    imbalance = float((counts.max() - counts.mean()) / max(counts.mean(), 1e-12))
    return StreamMetrics(
        execution_time=makespan,
        latency_avg=float(latencies.mean()) if n else 0.0,
        latency_p50=float(np.percentile(latencies, 50)) if n else 0.0,
        latency_p95=float(np.percentile(latencies, 95)) if n else 0.0,
        latency_p99=float(np.percentile(latencies, 99)) if n else 0.0,
        throughput=n / makespan if makespan > 0 else 0.0,
        memory_overhead=grouper.memory_overhead(),
        memory_overhead_norm=grouper.memory_overhead_normalized(),
        imbalance=imbalance,
        per_worker_busy=busy_until.copy(),
    )


def _advance_fifo(busy_until: np.ndarray, workers: np.ndarray,
                  times: np.ndarray, capacities: np.ndarray,
                  latencies_out: np.ndarray) -> None:
    """Vectorised per-worker FIFO advance for one segment.

    For a worker with service time P and tuples at times t_0 <= t_1 <= ...,
    the FIFO recurrence ``f_j = max(f_{j-1}, t_j) + P`` (with ``f_{-1}`` the
    carried busy-until b0) unrolls to::

        f_j = (j + 1) P + max(b0, max_{k<=j}(t_k - k P))

    i.e. a single ``np.maximum.accumulate`` per worker.  Writes per-tuple
    latencies (finish - arrival) into ``latencies_out`` and updates
    ``busy_until`` in place.
    """
    order = np.argsort(workers, kind="stable")
    ws = workers[order]
    ts = times[order]
    finishes = np.empty_like(ts)
    seg_starts = np.concatenate(
        [[0], np.flatnonzero(ws[1:] != ws[:-1]) + 1]
    ) if ws.shape[0] else np.empty(0, dtype=np.int64)
    seg_ends = np.concatenate([seg_starts[1:], [ws.shape[0]]])
    for s, e in zip(seg_starts.tolist(), seg_ends.tolist()):
        wk = int(ws[s])
        cap = capacities[wk]
        tt = ts[s:e]
        j = np.arange(e - s, dtype=np.float64)
        m = np.maximum.accumulate(tt - j * cap)
        f = (j + 1.0) * cap + np.maximum(busy_until[wk], m)
        finishes[s:e] = f
        busy_until[wk] = f[-1]
    latencies_out[order] = finishes - ts


def simulate_stream(
    grouper: Grouper,
    keys: Sequence,
    *,
    capacities: Optional[np.ndarray] = None,
    arrival_rate: float = 10_000.0,
    sample_every: int = 5_000,
    sample_noise: float = 0.02,
    events: Sequence[object] = (),
    seed: int = 0,
    event_observer: Optional[Callable[[str, Grouper, object], None]] = None,
) -> StreamMetrics:
    """Run ``keys`` through ``grouper`` with the batched engine.

    capacities:   true seconds/tuple per worker (default: all 1/arrival_rate
                  scaled so ~W tuples are in flight — i.e. balanced feasible).
    arrival_rate: tuples per second entering the source.
    sample_every: period (in tuples) of the Alg.-3 capacity sampling hook.
    events:       mixed :class:`MembershipEvent` / :class:`CapacityEvent`
                  sequence; each event index is a segment cut site.
    event_observer: optional ``f(kind, grouper, event)`` callback fired with
                  kind "pre_membership"/"post_membership" around membership
                  changes and "capacity" after a capacity change — the
                  scenario subsystem's remap-accounting hook.

    ``keys`` must be a 1-D integer array of interned key ids for the batched
    path (``repro.data.synthetic`` generators emit int32); anything else
    falls back to :func:`simulate_stream_reference`.
    """
    keys_arr = np.asarray(keys)
    if keys_arr.ndim != 1 or keys_arr.dtype.kind not in "iu":
        return simulate_stream_reference(
            grouper, keys, capacities=capacities, arrival_rate=arrival_rate,
            sample_every=sample_every, sample_noise=sample_noise,
            events=events, seed=seed, event_observer=event_observer,
        )
    rng = np.random.default_rng(seed)
    w = grouper.num_workers
    n = keys_arr.shape[0]
    mem_ev, cap_ev = _split_events(events, n)
    capacities, busy_until = _setup(grouper, capacities, arrival_rate,
                                    mem_ev, cap_ev)

    dt = 1.0 / arrival_rate
    latencies = np.empty(n, dtype=np.float64)
    active = set(range(w))

    # segment cut sites: membership/capacity events + capacity-sample points
    cuts = {0, n}
    cuts.update(e.at for e in mem_ev)
    cuts.update(e.at for e in cap_ev)
    if sample_every:
        cuts.update(range(sample_every, n, sample_every))
    bounds = sorted(cuts)
    ev_idx = 0
    cap_idx = 0

    for lo, hi in zip(bounds[:-1], bounds[1:]):
        ev_idx, cap_idx, active = _apply_events(
            lo, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
            active, event_observer)
        seg_workers = grouper.assign_batch(keys_arr[lo:hi], lo * dt, dt)
        seg_times = np.arange(lo, hi, dtype=np.float64) * dt
        _advance_fifo(busy_until, seg_workers, seg_times, capacities,
                      latencies[lo:hi])
        if sample_every and hi % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    return _metrics(grouper, busy_until, latencies, n)


def simulate_stream_reference(
    grouper: Grouper,
    keys: Sequence,
    *,
    capacities: Optional[np.ndarray] = None,
    arrival_rate: float = 10_000.0,
    sample_every: int = 5_000,
    sample_noise: float = 0.02,
    events: Sequence[object] = (),
    seed: int = 0,
    event_observer: Optional[Callable[[str, Grouper, object], None]] = None,
) -> StreamMetrics:
    """Per-tuple oracle engine (the original sequential simulator).

    Semantically authoritative: the batched engine is tested against this
    (exact for stateless-per-tuple schemes, bounded drift for the
    frequency-tracking ones).
    """
    rng = np.random.default_rng(seed)
    w = grouper.num_workers
    mem_ev, cap_ev = _split_events(events, len(keys))
    capacities, busy_until = _setup(grouper, capacities, arrival_rate,
                                    mem_ev, cap_ev)

    dt = 1.0 / arrival_rate
    latencies = np.empty(len(keys), dtype=np.float64)
    ev_idx = 0
    cap_idx = 0
    active = set(range(w))

    for i, key in enumerate(keys):
        ev_idx, cap_idx, active = _apply_events(
            i, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
            active, event_observer)
        now = i * dt
        worker = grouper.assign(key, now)
        start = max(busy_until[worker], now)
        finish = start + capacities[worker]
        busy_until[worker] = finish
        latencies[i] = finish - now
        if sample_every and (i + 1) % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    return _metrics(grouper, busy_until, latencies, len(keys))
