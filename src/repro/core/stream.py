"""Discrete-event DSPE simulator (paper §6.1 "Simulation Settings").

Models the paper's Fig. 1 DAG: sources emit a keyed tuple stream, a grouping
scheme assigns each tuple to a worker, each worker is a FIFO queue with a
processing capacity ``P_w`` (seconds per tuple — heterogeneous per paper
§4.2.3 / Fig. 7).  Reported metrics mirror the paper:

* ``execution_time``  — makespan = max_w(busy-until); the paper's simulated
  load-balance metric (Figs. 9/10: "execution time ... normalised to SG").
* ``latency_*``       — per-tuple queueing latency average / p50 / p95 / p99
  (Fig. 18's deployment metric).
* ``throughput``      — tuples / makespan (Fig. 19).
* ``memory_overhead`` — Σ_w distinct keys on w (Fig. 3/11/20), plus the
  FG-normalised form.
* ``imbalance``       — (max_w load − mean_w load) / mean_w load.

Two engines share the metric plumbing (ISSUE 1 tentpole), unified behind
:func:`simulate_edge` (ISSUE 3): one grouped *edge* of a dataflow topology,
taking an optional explicit per-tuple arrival-time array (so successive
edges can feed the finish times of one stage into the FIFO queues of the
next) and returning per-tuple finish times alongside the metrics.

* ``mode="batched"`` — the stream is cut into event-free segments
  (membership/capacity events + capacity-sample points are the only cut
  sites), each segment is routed with one ``grouper.assign_batch`` call, and
  the per-worker FIFO recurrence ``f_j = max(f_{j-1}, t_j) + P_w`` is solved
  in closed form with ``np.maximum.accumulate`` — zero Python work per tuple.
* ``mode="reference"`` — the original per-tuple loop, kept as the oracle for
  the batched-vs-reference equivalence tests (exact for SG/FG/PKG, bounded
  drift for DC/WC/FISH — see DESIGN.md §6).

:func:`simulate_stream` / :func:`simulate_stream_reference` remain as
deprecated single-hop shims over :func:`simulate_edge`; new code goes
through :mod:`repro.topology` (ISSUE 3 — one engine protocol).

Dynamic membership events (paper §5 / RQ4) are supported via
:class:`MembershipEvent`; mid-stream capacity changes (straggler onset /
recovery, heterogeneity shifts — Fig. 7) via :class:`CapacityEvent`.  Both
kinds are segment cut sites in the batched engine and may be mixed freely in
the ``events`` sequence.  Capacity sampling for FISH's estimator (Alg. 3) is
emulated with a periodic noisy sample of the true ``P_w`` — a straggler is
therefore *discovered* at the next sample point, not instantaneously.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from .baselines import Grouper

__all__ = [
    "CapacityEvent",
    "EdgeResult",
    "MembershipEvent",
    "StreamMetrics",
    "simulate_edge",
    "simulate_stream",
    "simulate_stream_reference",
]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """At tuple index ``at``, switch the active worker set to ``workers``."""

    at: int
    workers: Sequence[int]


@dataclasses.dataclass(frozen=True)
class CapacityEvent:
    """At tuple index ``at``, set the *true* seconds-per-tuple of the listed
    workers (straggler onset when slower, recovery when restored)."""

    at: int
    capacities: Mapping[int, float]


@dataclasses.dataclass
class StreamMetrics:
    execution_time: float
    latency_avg: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    throughput: float
    memory_overhead: int
    memory_overhead_norm: float
    imbalance: float
    per_worker_busy: np.ndarray

    def row(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("per_worker_busy")
        return d


@dataclasses.dataclass
class EdgeResult:
    """One grouped edge's outcome: paper metrics + per-tuple finish times
    (the arrival times of the downstream stage's input stream)."""

    metrics: StreamMetrics
    finishes: np.ndarray


def _split_events(events, n: int):
    """Partition a mixed event sequence into (membership, capacity) lists
    sorted by tuple index.  Events outside [0, n) can never fire (there is
    no tuple at their index) and are dropped here — keeping them would
    stall the in-order event cursor and silently suppress later events."""
    for e in events:
        if not isinstance(e, (MembershipEvent, CapacityEvent)):
            raise TypeError(
                f"unknown event type {type(e).__name__!r}; expected "
                "MembershipEvent or CapacityEvent"
            )
    mem = sorted((e for e in events
                  if isinstance(e, MembershipEvent) and 0 <= e.at < n),
                 key=lambda e: e.at)
    cap = sorted((e for e in events
                  if isinstance(e, CapacityEvent) and 0 <= e.at < n),
                 key=lambda e: e.at)
    return mem, cap


def _apply_events(i, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
                  active, event_observer):
    """Fire every event scheduled at tuple index ``i`` (shared by both
    engines).  Returns the advanced cursors and active set."""
    while ev_idx < len(mem_ev) and mem_ev[ev_idx].at == i:
        e = mem_ev[ev_idx]
        if event_observer is not None:
            event_observer("pre_membership", grouper, e)
        active = set(e.workers)
        grouper.on_membership_change(sorted(active))
        if event_observer is not None:
            event_observer("post_membership", grouper, e)
        ev_idx += 1
    while cap_idx < len(cap_ev) and cap_ev[cap_idx].at == i:
        e = cap_ev[cap_idx]
        for wk, cap in e.capacities.items():
            capacities[wk] = cap
        if event_observer is not None:
            event_observer("capacity", grouper, e)
        cap_idx += 1
    return ev_idx, cap_idx, active


def _setup(grouper, capacities, arrival_rate, mem_ev, cap_ev):
    """Shared preamble: capacities, initial samples, busy array sizing."""
    w = grouper.num_workers
    if capacities is None:
        # feasible utilisation ~0.9 across the initial worker set
        capacities = np.full(w, 0.9 * w / arrival_rate)
    capacities = np.asarray(capacities, dtype=np.float64).copy()

    # give capacity-aware groupers their initial (noisy) samples
    for wk in range(w):
        grouper.record_capacity_sample(wk, float(capacities[wk]))

    hi_w = w - 1
    for e in mem_ev:
        if e.workers:
            hi_w = max(hi_w, max(e.workers))
    for e in cap_ev:
        if e.capacities:
            hi_w = max(hi_w, max(e.capacities))
    busy_until = np.zeros(hi_w + 1, dtype=np.float64)
    if capacities.shape[0] < busy_until.shape[0]:
        pad = np.full(busy_until.shape[0] - capacities.shape[0],
                      capacities.mean())
        capacities = np.concatenate([capacities, pad])
    return capacities, busy_until


def _metrics(grouper, busy_until, latencies, n) -> StreamMetrics:
    makespan = float(busy_until.max()) if n else 0.0
    counts = grouper.assigned_counts[: len(busy_until)].astype(np.float64)
    imbalance = float((counts.max() - counts.mean()) / max(counts.mean(), 1e-12))
    return StreamMetrics(
        execution_time=makespan,
        latency_avg=float(latencies.mean()) if n else 0.0,
        latency_p50=float(np.percentile(latencies, 50)) if n else 0.0,
        latency_p95=float(np.percentile(latencies, 95)) if n else 0.0,
        latency_p99=float(np.percentile(latencies, 99)) if n else 0.0,
        throughput=n / makespan if makespan > 0 else 0.0,
        memory_overhead=grouper.memory_overhead(),
        memory_overhead_norm=grouper.memory_overhead_normalized(),
        imbalance=imbalance,
        per_worker_busy=busy_until.copy(),
    )


def _advance_fifo(busy_until: np.ndarray, workers: np.ndarray,
                  times: np.ndarray, capacities: np.ndarray,
                  latencies_out: np.ndarray) -> None:
    """Vectorised per-worker FIFO advance for one segment.

    For a worker with service time P and tuples at times t_0 <= t_1 <= ...,
    the FIFO recurrence ``f_j = max(f_{j-1}, t_j) + P`` (with ``f_{-1}`` the
    carried busy-until b0) unrolls to::

        f_j = (j + 1) P + max(b0, max_{k<=j}(t_k - k P))

    i.e. a single ``np.maximum.accumulate`` per worker.  Writes per-tuple
    latencies (finish - arrival) into ``latencies_out`` and updates
    ``busy_until`` in place.
    """
    order = np.argsort(workers, kind="stable")
    ws = workers[order]
    ts = times[order]
    finishes = np.empty_like(ts)
    seg_starts = np.concatenate(
        [[0], np.flatnonzero(ws[1:] != ws[:-1]) + 1]
    ) if ws.shape[0] else np.empty(0, dtype=np.int64)
    seg_ends = np.concatenate([seg_starts[1:], [ws.shape[0]]])
    for s, e in zip(seg_starts.tolist(), seg_ends.tolist()):
        wk = int(ws[s])
        cap = capacities[wk]
        tt = ts[s:e]
        j = np.arange(e - s, dtype=np.float64)
        m = np.maximum.accumulate(tt - j * cap)
        f = (j + 1.0) * cap + np.maximum(busy_until[wk], m)
        finishes[s:e] = f
        busy_until[wk] = f[-1]
    latencies_out[order] = finishes - ts


def simulate_edge(
    grouper: Grouper,
    keys: Sequence,
    *,
    times: Optional[np.ndarray] = None,
    mode: str = "batched",
    capacities: Optional[np.ndarray] = None,
    arrival_rate: float = 10_000.0,
    sample_every: int = 5_000,
    sample_noise: float = 0.02,
    events: Sequence[object] = (),
    seed: int = 0,
    event_observer: Optional[Callable[[str, Grouper, object], None]] = None,
    tuple_observer: Optional[Callable[[np.ndarray, np.ndarray], None]] = None,
) -> EdgeResult:
    """Run one grouped edge: route ``keys`` through ``grouper`` and advance
    the destination stage's per-worker FIFO queues.

    times:        optional per-tuple arrival times (nondecreasing).  ``None``
                  means a uniform source at ``arrival_rate`` (tuple ``i``
                  arrives at ``i / arrival_rate``).  A topology engine passes
                  the *finish* times of the upstream stage here, which is how
                  a stream propagates through successive grouped edges.
    mode:         "batched" (segment-wise closed-form FIFO — ISSUE 1) or
                  "reference" (the per-tuple oracle interpreter).
    capacities:   true seconds/tuple per worker (default: all 1/arrival_rate
                  scaled so ~W tuples are in flight — i.e. balanced feasible).
    sample_every: period (in tuples) of the Alg.-3 capacity sampling hook.
    events:       mixed :class:`MembershipEvent` / :class:`CapacityEvent`
                  sequence; ``at`` indexes this edge's input stream and is a
                  segment cut site in the batched mode.
    event_observer: optional ``f(kind, grouper, event)`` callback fired with
                  kind "pre_membership"/"post_membership" around membership
                  changes and "capacity" after a capacity change — the
                  remap-accounting hook.
    tuple_observer: optional ``f(keys, workers)`` callback fed the routed
                  chunks of the stream in order (each tuple exactly once,
                  interleaved correctly with the event hooks) — the keyed
                  operator-state hook (:mod:`repro.state`).  In batched
                  mode it fires once per segment; in reference mode the
                  per-tuple assignments are buffered and flushed before
                  each event and at stream end.

    ``keys`` must be a 1-D integer array of interned key ids for the batched
    mode (``repro.data.synthetic`` generators emit int32); anything else
    silently takes the reference interpreter.
    """
    if mode not in ("batched", "reference"):
        raise ValueError(f"unknown mode {mode!r}; 'batched' or 'reference'")
    if times is not None:
        times = np.asarray(times, dtype=np.float64)
        if times.shape[0] != len(keys):
            raise ValueError(
                f"times has {times.shape[0]} entries for {len(keys)} keys")
    if mode == "batched":
        keys_arr = np.asarray(keys)
        if keys_arr.ndim == 1 and keys_arr.dtype.kind in "iu":
            return _edge_batched(
                grouper, keys_arr, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                tuple_observer)
    return _edge_reference(
        grouper, keys, times, capacities, arrival_rate,
        sample_every, sample_noise, events, seed, event_observer,
        tuple_observer)


def _edge_batched(grouper, keys_arr, times, capacities, arrival_rate,
                  sample_every, sample_noise, events, seed,
                  event_observer, tuple_observer=None) -> EdgeResult:
    rng = np.random.default_rng(seed)
    w = grouper.num_workers
    n = keys_arr.shape[0]
    mem_ev, cap_ev = _split_events(events, n)
    capacities, busy_until = _setup(grouper, capacities, arrival_rate,
                                    mem_ev, cap_ev)

    dt = 1.0 / arrival_rate
    if times is not None and n > 1:
        # mean spacing of the explicit stream — FISH's estimator-tick pacing
        dt = float((times[-1] - times[0]) / (n - 1)) or dt
    latencies = np.empty(n, dtype=np.float64)
    active = set(range(w))

    # segment cut sites: membership/capacity events + capacity-sample points
    cuts = {0, n}
    cuts.update(e.at for e in mem_ev)
    cuts.update(e.at for e in cap_ev)
    if sample_every:
        cuts.update(range(sample_every, n, sample_every))
    bounds = sorted(cuts)
    ev_idx = 0
    cap_idx = 0

    for lo, hi in zip(bounds[:-1], bounds[1:]):
        ev_idx, cap_idx, active = _apply_events(
            lo, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
            active, event_observer)
        if times is None:
            seg_times = np.arange(lo, hi, dtype=np.float64) * dt
            now0 = lo * dt
        else:
            seg_times = times[lo:hi]
            now0 = float(seg_times[0])
        seg_workers = grouper.assign_batch(keys_arr[lo:hi], now0, dt)
        if tuple_observer is not None:
            tuple_observer(keys_arr[lo:hi], seg_workers)
        _advance_fifo(busy_until, seg_workers, seg_times, capacities,
                      latencies[lo:hi])
        if sample_every and hi % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    all_times = (np.arange(n, dtype=np.float64) * dt if times is None
                 else times)
    return EdgeResult(_metrics(grouper, busy_until, latencies, n),
                      all_times + latencies)


def _edge_reference(grouper, keys, times, capacities, arrival_rate,
                    sample_every, sample_noise, events, seed,
                    event_observer, tuple_observer=None) -> EdgeResult:
    rng = np.random.default_rng(seed)
    w = grouper.num_workers
    n = len(keys)
    mem_ev, cap_ev = _split_events(events, n)
    capacities, busy_until = _setup(grouper, capacities, arrival_rate,
                                    mem_ev, cap_ev)

    dt = 1.0 / arrival_rate
    latencies = np.empty(n, dtype=np.float64)
    finishes = np.empty(n, dtype=np.float64)
    ev_idx = 0
    cap_idx = 0
    active = set(range(w))

    # per-tuple assignments are buffered and flushed to the tuple observer
    # before any event fires, preserving the batched mode's interleaving
    buf_k: list = []
    buf_w: list = []

    def _flush_tuples() -> None:
        if buf_k and tuple_observer is not None:
            tuple_observer(np.asarray(buf_k),
                           np.asarray(buf_w, dtype=np.int64))
            buf_k.clear()
            buf_w.clear()

    for i, key in enumerate(keys):
        if tuple_observer is not None and (
                (ev_idx < len(mem_ev) and mem_ev[ev_idx].at == i)
                or (cap_idx < len(cap_ev) and cap_ev[cap_idx].at == i)):
            _flush_tuples()
        ev_idx, cap_idx, active = _apply_events(
            i, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
            active, event_observer)
        now = i * dt if times is None else float(times[i])
        worker = grouper.assign(key, now)
        if tuple_observer is not None:
            buf_k.append(key)
            buf_w.append(worker)
        start = max(busy_until[worker], now)
        finish = start + capacities[worker]
        busy_until[worker] = finish
        latencies[i] = finish - now
        finishes[i] = finish
        if sample_every and (i + 1) % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    _flush_tuples()
    return EdgeResult(_metrics(grouper, busy_until, latencies, n), finishes)


def _warn_legacy(name: str) -> None:
    warnings.warn(
        f"{name} is deprecated; build a Topology and run it through "
        "repro.topology (SimulatorEngine / ServingTopologyEngine), or call "
        "repro.core.simulate_edge for a single grouped edge",
        DeprecationWarning, stacklevel=3,
    )


def simulate_stream(grouper: Grouper, keys: Sequence, **kwargs
                    ) -> StreamMetrics:
    """Deprecated single-hop shim: the batched engine on a uniform source.

    Kept so legacy call sites keep working; new code builds a
    :class:`repro.topology.Topology` and runs it through an engine, or calls
    :func:`simulate_edge` directly.  Accepts the same keyword arguments as
    :func:`simulate_edge` (minus ``times``/``mode``).
    """
    _warn_legacy("simulate_stream")
    return simulate_edge(grouper, keys, mode="batched", **kwargs).metrics


def simulate_stream_reference(grouper: Grouper, keys: Sequence, **kwargs
                              ) -> StreamMetrics:
    """Deprecated single-hop shim: the per-tuple oracle on a uniform source
    (see :func:`simulate_stream`)."""
    _warn_legacy("simulate_stream_reference")
    return simulate_edge(grouper, keys, mode="reference", **kwargs).metrics
