"""Discrete-event DSPE simulator (paper §6.1 "Simulation Settings").

Models the paper's Fig. 1 DAG: sources emit a keyed tuple stream, a grouping
scheme assigns each tuple to a worker, each worker is a FIFO queue with a
processing capacity ``P_w`` (seconds per tuple — heterogeneous per paper
§4.2.3 / Fig. 7).  Reported metrics mirror the paper:

* ``execution_time``  — makespan = max_w(busy-until); the paper's simulated
  load-balance metric (Figs. 9/10: "execution time ... normalised to SG").
* ``latency_*``       — per-tuple queueing latency average / p50 / p95 / p99
  (Fig. 18's deployment metric).
* ``throughput``      — tuples / makespan (Fig. 19).
* ``memory_overhead`` — Σ_w distinct keys on w (Fig. 3/11/20), plus the
  FG-normalised form.
* ``imbalance``       — (max_w load − mean_w load) / mean_w load.

Dynamic membership events (paper §5 / RQ4) are supported via
:class:`MembershipEvent`; capacity sampling for FISH's estimator (Alg. 3) is
emulated with a periodic noisy sample of the true ``P_w``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .baselines import Grouper

__all__ = ["MembershipEvent", "StreamMetrics", "simulate_stream"]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """At tuple index ``at``, switch the active worker set to ``workers``."""

    at: int
    workers: Sequence[int]


@dataclasses.dataclass
class StreamMetrics:
    execution_time: float
    latency_avg: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    throughput: float
    memory_overhead: int
    memory_overhead_norm: float
    imbalance: float
    per_worker_busy: np.ndarray

    def row(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("per_worker_busy")
        return d


def simulate_stream(
    grouper: Grouper,
    keys: Sequence,
    *,
    capacities: Optional[np.ndarray] = None,
    arrival_rate: float = 10_000.0,
    sample_every: int = 5_000,
    sample_noise: float = 0.02,
    events: Sequence[MembershipEvent] = (),
    seed: int = 0,
) -> StreamMetrics:
    """Run ``keys`` through ``grouper`` over heterogeneous workers.

    capacities:   true seconds/tuple per worker (default: all 1/arrival_rate
                  scaled so ~W tuples are in flight — i.e. balanced feasible).
    arrival_rate: tuples per second entering the source.
    sample_every: period (in tuples) of the Alg.-3 capacity sampling hook.
    """
    rng = np.random.default_rng(seed)
    w = grouper.num_workers
    if capacities is None:
        # feasible utilisation ~0.9 across the initial worker set
        capacities = np.full(w, 0.9 * w / arrival_rate)
    capacities = np.asarray(capacities, dtype=np.float64).copy()

    # give capacity-aware groupers their initial (noisy) samples
    for wk in range(w):
        grouper.record_capacity_sample(wk, float(capacities[wk]))

    busy_until = np.zeros(max(w, 1 + max((max(e.workers) for e in events if e.workers),
                                          default=w - 1)), dtype=np.float64)
    if capacities.shape[0] < busy_until.shape[0]:
        pad = np.full(busy_until.shape[0] - capacities.shape[0], capacities.mean())
        capacities = np.concatenate([capacities, pad])

    dt = 1.0 / arrival_rate
    latencies = np.empty(len(keys), dtype=np.float64)
    ev = sorted(events, key=lambda e: e.at)
    ev_idx = 0
    active = set(range(w))

    for i, key in enumerate(keys):
        while ev_idx < len(ev) and ev[ev_idx].at == i:
            active = set(ev[ev_idx].workers)
            grouper.on_membership_change(sorted(active))
            ev_idx += 1
        now = i * dt
        worker = grouper.assign(key, now)
        start = max(busy_until[worker], now)
        finish = start + capacities[worker]
        busy_until[worker] = finish
        latencies[i] = finish - now
        if sample_every and (i + 1) % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    makespan = float(busy_until.max()) if len(keys) else 0.0
    loads = busy_until.copy()  # per-worker busy time in seconds
    counts = grouper.assigned_counts[: len(busy_until)].astype(np.float64)
    imbalance = float((counts.max() - counts.mean()) / max(counts.mean(), 1e-12))

    return StreamMetrics(
        execution_time=makespan,
        latency_avg=float(latencies.mean()) if len(keys) else 0.0,
        latency_p50=float(np.percentile(latencies, 50)) if len(keys) else 0.0,
        latency_p95=float(np.percentile(latencies, 95)) if len(keys) else 0.0,
        latency_p99=float(np.percentile(latencies, 99)) if len(keys) else 0.0,
        throughput=len(keys) / makespan if makespan > 0 else 0.0,
        memory_overhead=grouper.memory_overhead(),
        memory_overhead_norm=grouper.memory_overhead_normalized(),
        imbalance=imbalance,
        per_worker_busy=loads,
    )
