"""Discrete-event DSPE simulator (paper §6.1 "Simulation Settings").

Models the paper's Fig. 1 DAG: sources emit a keyed tuple stream, a grouping
scheme assigns each tuple to a worker, each worker is a FIFO queue with a
processing capacity ``P_w`` (seconds per tuple — heterogeneous per paper
§4.2.3 / Fig. 7).  Reported metrics mirror the paper:

* ``execution_time``  — makespan = max_w(busy-until); the paper's simulated
  load-balance metric (Figs. 9/10: "execution time ... normalised to SG").
* ``latency_*``       — per-tuple queueing latency average / p50 / p95 / p99
  (Fig. 18's deployment metric).
* ``throughput``      — tuples / makespan (Fig. 19).
* ``memory_overhead`` — Σ_w distinct keys on w (Fig. 3/11/20), plus the
  FG-normalised form.
* ``imbalance``       — (max_w load − mean_w load) / mean_w load.

Two engines share the metric plumbing (ISSUE 1 tentpole), unified behind
:func:`simulate_edge` (ISSUE 3): one grouped *edge* of a dataflow topology,
taking an optional explicit per-tuple arrival-time array (so successive
edges can feed the finish times of one stage into the FIFO queues of the
next) and returning per-tuple finish times alongside the metrics.

* ``mode="batched"`` — the stream is cut into event-free segments
  (membership/capacity events + capacity-sample points are the only cut
  sites), each segment is routed with one ``grouper.assign_batch`` call, and
  the per-worker FIFO recurrence ``f_j = max(f_{j-1}, t_j) + P_w`` is solved
  in closed form with ``np.maximum.accumulate`` — zero Python work per tuple.
* ``mode="reference"`` — the original per-tuple loop, kept as the oracle for
  the batched-vs-reference equivalence tests (exact for SG/FG/PKG, bounded
  drift for DC/WC/FISH — see DESIGN.md §6).

:func:`simulate_stream` / :func:`simulate_stream_reference` remain as
deprecated single-hop shims over :func:`simulate_edge`; new code goes
through :mod:`repro.topology` (ISSUE 3 — one engine protocol).

Incremental (sessioned) execution — ISSUE 5: :func:`simulate_edge` accepts a
carried :class:`EdgeState` (per-worker ``busy_until``, mutated capacities,
active set, sampling rng, global tuple offset) so a topology session can cut
one logical stream into successive record-batch feeds without losing FIFO
backlog, capacity-sample pacing or straggler state between them.  Feeding
the whole stream as one call is bit-identical to the legacy one-shot path.
Events may be addressed by stream timestamp instead of tuple index via
:func:`at_time` (resolved to the first tuple whose arrival time is >= the
requested timestamp — the same segment cut the equivalent index event
produces).

Dynamic membership events (paper §5 / RQ4) are supported via
:class:`MembershipEvent`; mid-stream capacity changes (straggler onset /
recovery, heterogeneity shifts — Fig. 7) via :class:`CapacityEvent`.  Both
kinds are segment cut sites in the batched engine and may be mixed freely in
the ``events`` sequence.  Capacity sampling for FISH's estimator (Alg. 3) is
emulated with a periodic noisy sample of the true ``P_w`` — a straggler is
therefore *discovered* at the next sample point, not instantaneously.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Mapping, Optional, Sequence

import numpy as np

from .baselines import Grouper

__all__ = [
    "CapacityEvent",
    "EdgeResult",
    "EdgeState",
    "MembershipEvent",
    "StreamMetrics",
    "at_time",
    "edge_metrics",
    "simulate_edge",
    "simulate_stream",
    "simulate_stream_reference",
]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """At tuple index ``at`` (or stream timestamp ``at_time`` — ISSUE 5),
    switch the active worker set to ``workers``."""

    at: int = -1
    workers: Sequence[int] = ()
    at_time: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class CapacityEvent:
    """At tuple index ``at`` (or stream timestamp ``at_time``), set the
    *true* seconds-per-tuple of the listed workers (straggler onset when
    slower, recovery when restored)."""

    at: int = -1
    capacities: Mapping[int, float] = dataclasses.field(default_factory=dict)
    at_time: Optional[float] = None


def at_time(event, t: float):
    """Re-address a membership/capacity event by stream timestamp: the event
    fires at the first tuple whose arrival time is >= ``t`` — the same
    segment cut as the equivalent index-addressed event.  Timestamps that
    precede the (remaining) stream fire at its first tuple; timestamps past
    the end never fire (mirroring out-of-range indices)."""
    return dataclasses.replace(event, at_time=float(t))


def _resolve_at_time(events, times: Optional[np.ndarray],
                     arrival_rate: float):
    """Lower ``at_time`` addressing onto tuple indices for one stream chunk
    (``times=None`` means the uniform grid ``i / arrival_rate``)."""
    out = []
    for e in events:
        t = getattr(e, "at_time", None)
        if t is not None:
            if times is None:
                idx = int(np.ceil(t * arrival_rate))
            else:
                idx = int(np.searchsorted(times, t, side="left"))
            e = dataclasses.replace(e, at=idx, at_time=None)
        out.append(e)
    return out


@dataclasses.dataclass
class EdgeState:
    """Carried execution state of one grouped edge across successive feeds
    (ISSUE 5 sessions).  The grouper itself is stateful and carried by the
    caller; this holds everything :func:`simulate_edge` used to rebuild per
    call: per-worker FIFO backlog, the (event-mutated) true capacities, the
    live worker set, the capacity-sampling rng, and the global index of the
    next tuple (so ``sample_every`` pacing stays on the stream-global grid).
    """

    busy_until: np.ndarray
    capacities: np.ndarray
    active: set
    rng: np.random.Generator
    offset: int = 0
    #: fused-mode residency: a ``FusedEdgeRunner`` holding this edge's
    #: device-resident arrays across feeds (ISSUE 6), or the
    #: ``_FUSED_FALLBACK`` sentinel once the edge has dropped to batched
    device: object = None


@dataclasses.dataclass
class StreamMetrics:
    execution_time: float
    latency_avg: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    throughput: float
    memory_overhead: int
    memory_overhead_norm: float
    imbalance: float
    per_worker_busy: np.ndarray

    def row(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("per_worker_busy")
        return d


@dataclasses.dataclass
class EdgeResult:
    """One grouped edge's outcome: paper metrics + per-tuple finish times
    (the arrival times of the downstream stage's input stream).

    ``metrics`` is ``None`` when the call opted out via
    ``compute_metrics=False`` (sessions aggregate at close instead);
    ``latencies`` are the raw per-tuple queueing latencies of this call
    (``finishes - arrivals`` computed before the finish-time rounding, so
    sessions can aggregate cross-feed percentiles bit-identically);
    ``state`` is the carried :class:`EdgeState` — pass it back into the
    next :func:`simulate_edge` call to continue the same stream;
    ``dispatches`` counts host↔device launches this call made (ISSUE 6 —
    the fused engine's "one dispatch per steady-state feed" claim is
    measured here; the host engines report 0)."""

    metrics: Optional[StreamMetrics]
    finishes: np.ndarray
    latencies: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0))
    state: Optional[EdgeState] = None
    dispatches: int = 0


# sentinel stored on EdgeState.device once a fused edge has fallen back to
# the batched engine — later feeds delegate silently (one warning per edge)
_FUSED_FALLBACK = object()


def _split_events(events, n: int):
    """Partition a mixed event sequence into (membership, capacity) lists
    sorted by tuple index.  Events outside [0, n) can never fire (there is
    no tuple at their index) and are dropped here — keeping them would
    stall the in-order event cursor and silently suppress later events."""
    for e in events:
        if not isinstance(e, (MembershipEvent, CapacityEvent)):
            raise TypeError(
                f"unknown event type {type(e).__name__!r}; expected "
                "MembershipEvent or CapacityEvent"
            )
    mem = sorted((e for e in events
                  if isinstance(e, MembershipEvent) and 0 <= e.at < n),
                 key=lambda e: e.at)
    cap = sorted((e for e in events
                  if isinstance(e, CapacityEvent) and 0 <= e.at < n),
                 key=lambda e: e.at)
    return mem, cap


def _apply_events(i, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
                  active, event_observer):
    """Fire every event scheduled at tuple index ``i`` (shared by both
    engines).  Returns the advanced cursors and active set."""
    while ev_idx < len(mem_ev) and mem_ev[ev_idx].at == i:
        e = mem_ev[ev_idx]
        if event_observer is not None:
            event_observer("pre_membership", grouper, e)
        active = set(e.workers)
        grouper.on_membership_change(sorted(active))
        if event_observer is not None:
            event_observer("post_membership", grouper, e)
        ev_idx += 1
    while cap_idx < len(cap_ev) and cap_ev[cap_idx].at == i:
        e = cap_ev[cap_idx]
        for wk, cap in e.capacities.items():
            capacities[wk] = cap
        if event_observer is not None:
            event_observer("capacity", grouper, e)
        cap_idx += 1
    return ev_idx, cap_idx, active


def _event_hi_worker(mem_ev, cap_ev, hi_w: int) -> int:
    for e in mem_ev:
        if e.workers:
            hi_w = max(hi_w, max(e.workers))
    for e in cap_ev:
        if e.capacities:
            hi_w = max(hi_w, max(e.capacities))
    return hi_w


def _setup(grouper, capacities, arrival_rate, mem_ev, cap_ev, seed):
    """Fresh-edge preamble: capacities, initial samples, busy array sizing —
    bundled into the :class:`EdgeState` a session carries across feeds."""
    w = grouper.num_workers
    if capacities is None:
        # feasible utilisation ~0.9 across the initial worker set
        capacities = np.full(w, 0.9 * w / arrival_rate)
    capacities = np.asarray(capacities, dtype=np.float64).copy()

    # give capacity-aware groupers their initial (noisy) samples
    for wk in range(w):
        grouper.record_capacity_sample(wk, float(capacities[wk]))

    hi_w = _event_hi_worker(mem_ev, cap_ev, w - 1)
    busy_until = np.zeros(hi_w + 1, dtype=np.float64)
    if capacities.shape[0] < busy_until.shape[0]:
        pad = np.full(busy_until.shape[0] - capacities.shape[0],
                      capacities.mean())
        capacities = np.concatenate([capacities, pad])
    return EdgeState(busy_until=busy_until, capacities=capacities,
                     active=set(range(w)),
                     rng=np.random.default_rng(seed))


def _grow_state(state: EdgeState, mem_ev, cap_ev) -> None:
    """Extend a carried state's worker arrays when this feed's events name
    workers beyond the current range (scale-out in a later feed)."""
    hi_w = _event_hi_worker(mem_ev, cap_ev, state.busy_until.shape[0] - 1)
    need = hi_w + 1 - state.busy_until.shape[0]
    if need > 0:
        state.busy_until = np.concatenate(
            [state.busy_until, np.zeros(need, dtype=np.float64)])
        state.capacities = np.concatenate(
            [state.capacities, np.full(need, state.capacities.mean())])


def edge_metrics(grouper, busy_until, latencies, n) -> StreamMetrics:
    """The paper metrics for one grouped edge, computed from the grouper's
    cumulative counters, the final per-worker busy-until array and the
    per-tuple latencies (sessions call this at close over the concatenated
    feeds; one-shot calls get it per :func:`simulate_edge` call)."""
    makespan = float(busy_until.max()) if n else 0.0
    counts = grouper.assigned_counts[: len(busy_until)].astype(np.float64)
    imbalance = float((counts.max() - counts.mean()) / max(counts.mean(), 1e-12))
    return StreamMetrics(
        execution_time=makespan,
        latency_avg=float(latencies.mean()) if n else 0.0,
        latency_p50=float(np.percentile(latencies, 50)) if n else 0.0,
        latency_p95=float(np.percentile(latencies, 95)) if n else 0.0,
        latency_p99=float(np.percentile(latencies, 99)) if n else 0.0,
        throughput=n / makespan if makespan > 0 else 0.0,
        memory_overhead=grouper.memory_overhead(),
        memory_overhead_norm=grouper.memory_overhead_normalized(),
        imbalance=imbalance,
        per_worker_busy=busy_until.copy(),
    )


def _advance_fifo(busy_until: np.ndarray, workers: np.ndarray,
                  times: np.ndarray, capacities: np.ndarray,
                  latencies_out: np.ndarray) -> None:
    """Vectorised per-worker FIFO advance for one segment.

    For a worker with service time P and tuples at times t_0 <= t_1 <= ...,
    the FIFO recurrence ``f_j = max(f_{j-1}, t_j) + P`` (with ``f_{-1}`` the
    carried busy-until b0) unrolls to::

        f_j = (j + 1) P + max(b0, max_{k<=j}(t_k - k P))

    i.e. a single ``np.maximum.accumulate`` per worker.  Writes per-tuple
    latencies (finish - arrival) into ``latencies_out`` and updates
    ``busy_until`` in place.
    """
    order = np.argsort(workers, kind="stable")
    ws = workers[order]
    ts = times[order]
    finishes = np.empty_like(ts)
    seg_starts = np.concatenate(
        [[0], np.flatnonzero(ws[1:] != ws[:-1]) + 1]
    ) if ws.shape[0] else np.empty(0, dtype=np.int64)
    seg_ends = np.concatenate([seg_starts[1:], [ws.shape[0]]])
    for s, e in zip(seg_starts.tolist(), seg_ends.tolist()):
        wk = int(ws[s])
        cap = capacities[wk]
        tt = ts[s:e]
        j = np.arange(e - s, dtype=np.float64)
        m = np.maximum.accumulate(tt - j * cap)
        f = (j + 1.0) * cap + np.maximum(busy_until[wk], m)
        finishes[s:e] = f
        busy_until[wk] = f[-1]
    latencies_out[order] = finishes - ts


def simulate_edge(
    grouper: Grouper,
    keys: Sequence,
    *,
    times: Optional[np.ndarray] = None,
    mode: str = "batched",
    capacities: Optional[np.ndarray] = None,
    arrival_rate: float = 10_000.0,
    sample_every: int = 5_000,
    sample_noise: float = 0.02,
    events: Sequence[object] = (),
    seed: int = 0,
    event_observer: Optional[Callable[[str, Grouper, object], None]] = None,
    tuple_observer: Optional[Callable[..., None]] = None,
    state_sink: Optional[object] = None,
    values: Optional[np.ndarray] = None,
    state: Optional[EdgeState] = None,
    dt: Optional[float] = None,
    compute_metrics: bool = True,
    migration_biller: Optional[object] = None,
    telemetry: Optional[object] = None,
) -> EdgeResult:
    """Run one grouped edge: route ``keys`` through ``grouper`` and advance
    the destination stage's per-worker FIFO queues.

    times:        optional per-tuple arrival times (nondecreasing).  ``None``
                  means a uniform source at ``arrival_rate`` (tuple ``i``
                  arrives at ``i / arrival_rate``).  A topology engine passes
                  the *finish* times of the upstream stage here, which is how
                  a stream propagates through successive grouped edges.
    mode:         "batched" (segment-wise closed-form FIFO — ISSUE 1),
                  "reference" (the per-tuple oracle interpreter), or
                  "fused" (ISSUE 6: one jitted device launch per segment —
                  routing + FIFO + keyed-state update fused; device state
                  carried on ``EdgeState.device`` across feeds.  Falls
                  back to batched with a :class:`UserWarning` when the
                  feed is outside the fused envelope — see
                  ``repro.kernels.feed_fused.fused_reject_reason``).
    capacities:   true seconds/tuple per worker (default: all 1/arrival_rate
                  scaled so ~W tuples are in flight — i.e. balanced feasible).
                  Ignored when ``state`` is carried (its capacities rule).
    sample_every: period (in tuples) of the Alg.-3 capacity sampling hook,
                  counted on the stream-global grid (``state.offset`` aware).
    events:       mixed :class:`MembershipEvent` / :class:`CapacityEvent`
                  sequence; ``at`` indexes this call's input chunk and is a
                  segment cut site in the batched mode.  Events addressed via
                  :func:`at_time` are resolved against ``times`` (or the
                  uniform grid) before splitting.
    event_observer: optional ``f(kind, grouper, event)`` callback fired with
                  kind "pre_membership"/"post_membership" around membership
                  changes and "capacity" after a capacity change — the
                  remap-accounting hook.
    tuple_observer: optional ``f(keys, workers, values)`` callback fed the
                  routed chunks of the stream in order (each tuple exactly
                  once, interleaved correctly with the event hooks) — the
                  keyed operator-state hook (:mod:`repro.state`).  ``values``
                  is the matching payload slice, or ``None`` when the stream
                  carries no payload column.  In batched mode it fires once
                  per segment; in reference mode the per-tuple assignments
                  are buffered and flushed before each event and at stream
                  end.  Fused mode rejects it (keyed state flows through
                  ``state_sink`` there) and falls back to batched.
    state_sink:   fused-mode keyed-state consumer — a
                  :class:`repro.state.window.KeyedStateManager` (or
                  anything with ``op``/``idx``/``feed_aggregated``).  The
                  fused engine aggregates (key, worker) pane contributions
                  on device and syncs them at pane boundaries and events
                  via ``feed_aggregated`` instead of streaming every
                  routed chunk through ``tuple_observer``.  Only valid
                  with ``mode="fused"``.
    values:       optional per-tuple float64 payload column (ISSUE 5
                  record batches) — routed alongside the keys and handed to
                  the tuple observer; it does not affect routing or timing.
    state:        carried :class:`EdgeState` from this edge's previous feed
                  (sessions).  ``None`` starts a fresh edge; the (fresh or
                  carried) state is returned on :attr:`EdgeResult.state`.
                  Continuing a stream requires explicit ``times`` — with
                  ``times=None`` arrivals would restart at 0 against a
                  carried absolute-time backlog, so that is rejected.
    dt:           explicit estimator-tick pacing (seconds/tuple) handed to
                  the grouper.  Default: ``1/arrival_rate``, or the mean
                  spacing of ``times`` when given.  Sessions pin the source
                  edge to ``1/arrival_rate`` so cutting a uniform stream
                  into feeds keeps epoch pacing bit-identical.
    compute_metrics: set False to skip the per-call :class:`StreamMetrics`
                  (``EdgeResult.metrics`` is then ``None``) — sessions
                  aggregate latencies across feeds and compute metrics
                  once at close, so per-feed percentile passes are waste.
    migration_biller: optional :class:`repro.state.migration.MigrationBiller`
                  (ISSUE 8): after each membership event its pending
                  per-worker charges — engine-clock stall from migrated
                  keyed state — are popped and added to the destination
                  workers' busy time at the event's stream position, so
                  scale-out's state transfer competes with serving
                  bandwidth.  Chain its ``on_event`` after the keyed-state
                  manager's in ``event_observer`` so it sees each event's
                  migration bill.
    telemetry:    optional :class:`repro.obs.Telemetry` bundle (ISSUE 9).
                  Only the fused engine consumes it here — the
                  :class:`~repro.kernels.feed_fused.FusedEdgeRunner` mints
                  its dispatch/pane/sync counters from it and emits launch
                  spans + FISH epoch timeline points when enabled.  The
                  host engines are instrumented at the session layer
                  instead (per-feed spans around :func:`simulate_edge`).

    ``keys`` must be a 1-D integer array of interned key ids for the batched
    mode (``repro.data.synthetic`` generators emit int32); anything else
    falls back to the reference interpreter with a :class:`UserWarning`
    (a 10-20x slowdown that should never be silent).
    """
    if mode not in ("batched", "reference", "fused"):
        raise ValueError(
            f"unknown mode {mode!r}; 'batched', 'reference' or 'fused'")
    if state_sink is not None and mode != "fused":
        raise ValueError(
            "state_sink is the fused engine's keyed-state channel; "
            "batched/reference modes stream state via tuple_observer")
    if state_sink is not None and tuple_observer is not None:
        raise ValueError("pass state_sink or tuple_observer, not both")
    if times is not None:
        times = np.asarray(times, dtype=np.float64)
        if times.shape[0] != len(keys):
            raise ValueError(
                f"times has {times.shape[0]} entries for {len(keys)} keys")
    if values is not None:
        values = np.asarray(values, dtype=np.float64)
        if values.shape[0] != len(keys):
            raise ValueError(
                f"values has {values.shape[0]} entries for {len(keys)} keys")
    if state is not None and state.offset > 0 and times is None:
        raise ValueError(
            "continuing a carried EdgeState requires explicit times: with "
            "times=None arrivals restart at 0 while busy_until carries the "
            "previous feeds' absolute finish times — pass the stream's "
            "real timestamps")
    events = _resolve_at_time(events, times, arrival_rate)
    if mode == "fused":
        keys_arr = np.asarray(keys)
        int_keys = keys_arr.ndim == 1 and keys_arr.dtype.kind in "iu"
        obs = state_sink.feed if state_sink is not None else tuple_observer
        dev = state.device if state is not None else None
        if dev is _FUSED_FALLBACK:  # this edge already dropped to batched
            if int_keys:
                return _edge_batched(
                    grouper, keys_arr, times, capacities, arrival_rate,
                    sample_every, sample_noise, events, seed,
                    event_observer, obs, values, state, dt, compute_metrics,
                    migration_biller)
            return _edge_reference(
                grouper, keys, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                obs, values, state, compute_metrics, migration_biller)
        from ..kernels.feed_fused import fused_reject_reason

        if not int_keys:
            reason = (f"keys dtype={keys_arr.dtype} shape={keys_arr.shape}"
                      " is not a 1-D integer array")
        else:
            reason = fused_reject_reason(grouper, keys_arr, values,
                                         state_sink, tuple_observer)
        if reason is None:
            return _edge_fused(
                grouper, keys_arr, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                state_sink, values, state, dt, compute_metrics,
                migration_biller, telemetry)
        warnings.warn(
            f"simulate_edge falling back to the batched engine: {reason}",
            UserWarning, stacklevel=2)
        if dev is not None:  # mid-session: sync device state out first
            if state_sink is not None:
                dev.flush_pane(state_sink)
            dev.host_sync(grouper)
        if state is not None:
            state.device = _FUSED_FALLBACK
        if int_keys:
            res = _edge_batched(
                grouper, keys_arr, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                obs, values, state, dt, compute_metrics, migration_biller)
        else:
            res = _edge_reference(
                grouper, keys, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                obs, values, state, compute_metrics, migration_biller)
        res.state.device = _FUSED_FALLBACK
        return res
    if mode == "batched":
        keys_arr = np.asarray(keys)
        if keys_arr.ndim == 1 and keys_arr.dtype.kind in "iu":
            return _edge_batched(
                grouper, keys_arr, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                tuple_observer, values, state, dt, compute_metrics,
                migration_biller)
        warnings.warn(
            f"simulate_edge falling back to the per-tuple reference "
            f"interpreter: keys dtype={keys_arr.dtype} shape="
            f"{keys_arr.shape} is not a 1-D integer array (a 10-20x "
            f"slowdown; intern keys via repro.data.synthetic.intern_keys "
            f"to stay on the batched path)",
            UserWarning, stacklevel=2)
    return _edge_reference(
        grouper, keys, times, capacities, arrival_rate,
        sample_every, sample_noise, events, seed, event_observer,
        tuple_observer, values, state, compute_metrics, migration_biller)


def _apply_migration_stall(migration_biller, busy_until) -> None:
    """Add a membership event's pending migration charges to the destination
    workers' busy time (tick-billed migration — ISSUE 8)."""
    for wk, stall in migration_biller.pop_charges().items():
        busy_until[wk] += stall


def _edge_batched(grouper, keys_arr, times, capacities, arrival_rate,
                  sample_every, sample_noise, events, seed,
                  event_observer, tuple_observer=None, values=None,
                  state=None, dt=None, compute_metrics=True,
                  migration_biller=None) -> EdgeResult:
    n = keys_arr.shape[0]
    mem_ev, cap_ev = _split_events(events, n)
    if state is None:
        state = _setup(grouper, capacities, arrival_rate, mem_ev, cap_ev,
                       seed)
    else:
        _grow_state(state, mem_ev, cap_ev)
    busy_until = state.busy_until
    capacities = state.capacities
    rng = state.rng
    off = state.offset

    if dt is None:
        dt = 1.0 / arrival_rate
        if times is not None and n > 1:
            # mean spacing of this chunk — FISH's estimator-tick pacing
            dt = float((times[-1] - times[0]) / (n - 1)) or dt
    latencies = np.empty(n, dtype=np.float64)
    active = state.active

    # segment cut sites: membership/capacity events + capacity-sample points
    # (sample points sit on the stream-global grid: offset-aware)
    cuts = {0, n}
    cuts.update(e.at for e in mem_ev)
    cuts.update(e.at for e in cap_ev)
    if sample_every:
        first = (-off) % sample_every or sample_every
        cuts.update(range(first, n, sample_every))
    bounds = sorted(cuts)
    ev_idx = 0
    cap_idx = 0

    for lo, hi in zip(bounds[:-1], bounds[1:]):
        ev_idx, cap_idx, active = _apply_events(
            lo, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
            active, event_observer)
        if migration_biller is not None:
            _apply_migration_stall(migration_biller, busy_until)
        if times is None:
            seg_times = np.arange(lo, hi, dtype=np.float64) * dt
            now0 = lo * dt
        else:
            seg_times = times[lo:hi]
            now0 = float(seg_times[0])
        seg_workers = grouper.assign_batch(keys_arr[lo:hi], now0, dt)
        if tuple_observer is not None:
            tuple_observer(keys_arr[lo:hi], seg_workers,
                           None if values is None else values[lo:hi])
        _advance_fifo(busy_until, seg_workers, seg_times, capacities,
                      latencies[lo:hi])
        if sample_every and (off + hi) % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    state.active = active
    state.offset = off + n
    all_times = (np.arange(n, dtype=np.float64) * dt if times is None
                 else times)
    metrics = (edge_metrics(grouper, busy_until, latencies, n)
               if compute_metrics else None)
    return EdgeResult(metrics, all_times + latencies, latencies, state)


def _edge_fused(grouper, keys_arr, times, capacities, arrival_rate,
                sample_every, sample_noise, events, seed, event_observer,
                state_sink=None, values=None, state=None, dt=None,
                compute_metrics=True, migration_biller=None,
                telemetry=None) -> EdgeResult:
    """ISSUE 6 fused engine: one jitted device launch per event-free
    segment.  Cut sites are only events and operator pane boundaries —
    capacity-sample points are *not* cuts (the sample snapshots are taken
    from the host-authoritative capacities after the covering segment,
    preserving the batched engine's exact rng draw sequence), so a
    steady-state feed with aligned panes is a single dispatch."""
    from ..kernels.feed_fused import FusedEdgeRunner

    n = keys_arr.shape[0]
    mem_ev, cap_ev = _split_events(events, n)
    if state is None:
        state = _setup(grouper, capacities, arrival_rate, mem_ev, cap_ev,
                       seed)
    else:
        _grow_state(state, mem_ev, cap_ev)
    capacities = state.capacities
    rng = state.rng
    off = state.offset

    runner = state.device
    if runner is None:
        runner = FusedEdgeRunner(grouper, state, state_sink,
                                 telemetry=telemetry)
        state.device = runner

    if dt is None:
        dt = 1.0 / arrival_rate
        if times is not None and n > 1:
            dt = float((times[-1] - times[0]) / (n - 1)) or dt
    if times is None:
        times = np.arange(n, dtype=np.float64) * dt
    latencies = np.empty(n, dtype=np.float64)
    finishes = np.empty(n, dtype=np.float64)
    active = state.active

    # segment cut sites: events + pane boundaries.  The pane grid is
    # global: tuples already synced to the sink plus the open device pane.
    cuts = {0, n}
    cuts.update(e.at for e in mem_ev)
    cuts.update(e.at for e in cap_ev)
    stride = 0
    gbase = 0
    if state_sink is not None:
        stride = state_sink.op.stride
        gbase = state_sink.idx + runner.pane_fed
        first = (-gbase) % stride or stride
        cuts.update(range(first, n, stride))
    bounds = sorted(cuts)
    ev_idx = 0
    cap_idx = 0

    runner.begin_feed(grouper, state, keys_arr, values, times, state_sink)

    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if stride and (gbase + lo) % stride == 0:
            runner.flush_pane(state_sink)
        due = ((ev_idx < len(mem_ev) and mem_ev[ev_idx].at == lo)
               or (cap_idx < len(cap_ev) and cap_ev[cap_idx].at == lo))
        if due:
            # the sink must see every pre-event tuple and the grouper its
            # replicas before the event handler reshapes the worker set
            runner.flush_pane(state_sink)
            runner.host_sync(grouper)
            mem0 = ev_idx
            ev_idx, cap_idx, active = _apply_events(
                lo, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
                active, event_observer)
            if migration_biller is not None:
                # busy_until is host-authoritative here (host_sync above;
                # run_segment re-uploads it), so billing lands on device
                _apply_migration_stall(migration_biller, state.busy_until)
            state.active = active
            if ev_idx > mem0:
                runner.refresh_membership(grouper, state)
        fin = runner.run_segment(grouper, state, lo, hi)
        finishes[lo:hi] = fin
        latencies[lo:hi] = fin - times[lo:hi]
        if sample_every:
            # sample points crossed by this segment (global grid); the
            # capacities/active set are constant inside a segment, so the
            # snapshot equals the batched engine's — same rng sequence
            k0 = (off + lo) // sample_every + 1
            k1 = (off + hi) // sample_every
            for _k in range(k0, k1 + 1):
                for wk in sorted(active):
                    noisy = capacities[wk] * (
                        1.0 + rng.normal(0.0, sample_noise))
                    grouper.record_capacity_sample(
                        wk, float(max(noisy, 1e-12)))

    if stride and (gbase + n) % stride == 0:
        runner.flush_pane(state_sink)  # feed ends on a pane boundary
    state.active = active
    state.offset = off + n
    metrics = None
    if compute_metrics:
        runner.host_sync(grouper)
        metrics = edge_metrics(grouper, state.busy_until, latencies, n)
    return EdgeResult(metrics, finishes, latencies, state,
                      dispatches=runner.dispatches)


def _edge_reference(grouper, keys, times, capacities, arrival_rate,
                    sample_every, sample_noise, events, seed,
                    event_observer, tuple_observer=None, values=None,
                    state=None, compute_metrics=True,
                    migration_biller=None) -> EdgeResult:
    n = len(keys)
    mem_ev, cap_ev = _split_events(events, n)
    if state is None:
        state = _setup(grouper, capacities, arrival_rate, mem_ev, cap_ev,
                       seed)
    else:
        _grow_state(state, mem_ev, cap_ev)
    busy_until = state.busy_until
    capacities = state.capacities
    rng = state.rng
    off = state.offset

    dt = 1.0 / arrival_rate
    latencies = np.empty(n, dtype=np.float64)
    finishes = np.empty(n, dtype=np.float64)
    ev_idx = 0
    cap_idx = 0
    active = state.active

    # per-tuple assignments are buffered and flushed to the tuple observer
    # before any event fires, preserving the batched mode's interleaving
    buf_k: list = []
    buf_w: list = []
    buf_v: list = []

    def _flush_tuples() -> None:
        if buf_k and tuple_observer is not None:
            tuple_observer(np.asarray(buf_k),
                           np.asarray(buf_w, dtype=np.int64),
                           np.asarray(buf_v, dtype=np.float64)
                           if values is not None else None)
            buf_k.clear()
            buf_w.clear()
            buf_v.clear()

    for i, key in enumerate(keys):
        if tuple_observer is not None and (
                (ev_idx < len(mem_ev) and mem_ev[ev_idx].at == i)
                or (cap_idx < len(cap_ev) and cap_ev[cap_idx].at == i)):
            _flush_tuples()
        ev_idx, cap_idx, active = _apply_events(
            i, mem_ev, ev_idx, cap_ev, cap_idx, grouper, capacities,
            active, event_observer)
        if migration_biller is not None:
            _apply_migration_stall(migration_biller, busy_until)
        now = i * dt if times is None else float(times[i])
        worker = grouper.assign(key, now)
        if tuple_observer is not None:
            buf_k.append(key)
            buf_w.append(worker)
            if values is not None:
                buf_v.append(float(values[i]))
        start = max(busy_until[worker], now)
        finish = start + capacities[worker]
        busy_until[worker] = finish
        latencies[i] = finish - now
        finishes[i] = finish
        if sample_every and (off + i + 1) % sample_every == 0:
            for wk in sorted(active):
                noisy = capacities[wk] * (1.0 + rng.normal(0.0, sample_noise))
                grouper.record_capacity_sample(wk, float(max(noisy, 1e-12)))

    _flush_tuples()
    state.active = active
    state.offset = off + n
    metrics = (edge_metrics(grouper, busy_until, latencies, n)
               if compute_metrics else None)
    return EdgeResult(metrics, finishes, latencies, state)


def _warn_legacy(name: str) -> None:
    warnings.warn(
        f"{name} is deprecated; build a Topology and run it through "
        "repro.topology (SimulatorEngine / ServingTopologyEngine), or call "
        "repro.core.simulate_edge for a single grouped edge",
        DeprecationWarning, stacklevel=3,
    )


def simulate_stream(grouper: Grouper, keys: Sequence, **kwargs
                    ) -> StreamMetrics:
    """Deprecated single-hop shim: the batched engine on a uniform source.

    Kept so legacy call sites keep working; new code builds a
    :class:`repro.topology.Topology` and runs it through an engine, or calls
    :func:`simulate_edge` directly.  Accepts the same keyword arguments as
    :func:`simulate_edge` (minus ``times``/``mode``).
    """
    _warn_legacy("simulate_stream")
    return simulate_edge(grouper, keys, mode="batched", **kwargs).metrics


def simulate_stream_reference(grouper: Grouper, keys: Sequence, **kwargs
                              ) -> StreamMetrics:
    """Deprecated single-hop shim: the per-tuple oracle on a uniform source
    (see :func:`simulate_stream`)."""
    _warn_legacy("simulate_stream_reference")
    return simulate_edge(grouper, keys, mode="reference", **kwargs).metrics
