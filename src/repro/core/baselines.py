"""Stream grouping schemes (paper §2.2) + the FISH grouper itself.

All groupers share one interface used by the stream simulator
(:mod:`repro.core.stream`), the data pipeline and the serving router::

    worker = grouper.assign(key, now)

and expose ``state_replicas()`` — the set of (key -> workers) mappings they
created, which is the paper's memory-overhead metric (Σ_w distinct keys held
on w, normalised to FG's 1 replica per key).

Baselines:
  * SG  — Shuffle Grouping: round-robin, ignores the key.
  * FG  — Field Grouping: hash(key) mod W.
  * PKG — Partial Key Grouping: power-of-two-choices between 2 hashed
          candidates, pick the one with the smaller local assigned count.
  * DC  — D-Choices: SpaceSaving heavy hitters over the *entire lifetime* get
          d hashed candidates; the rest use PKG.
  * WC  — W-Choices: like DC but heavy hitters may use *all* workers.
  * FISH — epoch-decayed hot keys (Alg. 1) + CHK (Alg. 2) + heuristic worker
          assignment (Alg. 3) over consistent-hash candidates (§5).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from .assignment import WorkerStateEstimator
from .chash import ConsistentHashRing, hash32
from .fish import EpochFrequencyTracker, FishParams, chk_num_workers

__all__ = [
    "Grouper",
    "ShuffleGrouping",
    "FieldGrouping",
    "PartialKeyGrouping",
    "DChoices",
    "WChoices",
    "FishGrouper",
    "make_grouper",
]


class Grouper:
    """Base class: tracks key->worker replicas and per-worker assigned counts."""

    name = "base"

    def __init__(self, num_workers: int):
        self.num_workers = num_workers
        self.replicas: Dict[object, Set[int]] = {}
        self.assigned_counts = np.zeros(num_workers, dtype=np.int64)

    # -- interface ---------------------------------------------------------------
    def assign(self, key, now: float = 0.0) -> int:
        raise NotImplementedError

    def _record(self, key, worker: int) -> int:
        self.replicas.setdefault(key, set()).add(worker)
        self.assigned_counts[worker] += 1
        return worker

    # -- metrics -----------------------------------------------------------------
    def memory_overhead(self) -> int:
        """Σ_w |distinct keys on worker w|  (paper's memory metric)."""
        return int(sum(len(ws) for ws in self.replicas.values()))

    def memory_overhead_normalized(self) -> float:
        """Normalised to FG (= 1 replica per distinct key)."""
        n_keys = max(len(self.replicas), 1)
        return self.memory_overhead() / float(n_keys)

    # hooks for heterogeneous-capacity runtimes; default no-op
    def record_capacity_sample(self, worker: int, seconds_per_tuple: float) -> None:
        pass

    def on_membership_change(self, workers: Sequence[int]) -> None:
        raise NotImplementedError(f"{self.name} does not support elasticity")


class ShuffleGrouping(Grouper):
    name = "sg"

    def __init__(self, num_workers: int):
        super().__init__(num_workers)
        self._rr = 0

    def assign(self, key, now: float = 0.0) -> int:
        w = self._rr
        self._rr = (self._rr + 1) % self.num_workers
        return self._record(key, w)


class FieldGrouping(Grouper):
    name = "fg"

    def assign(self, key, now: float = 0.0) -> int:
        return self._record(key, hash32((key, 0)) % self.num_workers)


class PartialKeyGrouping(Grouper):
    """Power of two choices between two hash candidates [14]."""

    name = "pkg"
    _salts = (0, 1)

    def _candidates(self, key) -> List[int]:
        cands = [hash32((key, s)) % self.num_workers for s in self._salts]
        if cands[0] == cands[1] and self.num_workers > 1:
            cands[1] = (cands[1] + 1) % self.num_workers
        return cands

    def _pick_least_loaded(self, cands: Sequence[int]) -> int:
        loads = self.assigned_counts[list(cands)]
        return int(cands[int(np.argmin(loads))])

    def assign(self, key, now: float = 0.0) -> int:
        return self._record(key, self._pick_least_loaded(self._candidates(key)))


class DChoices(PartialKeyGrouping):
    """D-Choices [15]: lifetime SpaceSaving heavy hitters -> d candidates.

    ``d`` is chosen per [15] as the smallest d such that the head frequency can
    be spread below the imbalance bound; we use their practical rule
    d = ceil(f_k * W / theta-bound) capped at W, matching the reference
    implementation's behaviour for skewed streams.
    """

    name = "dc"

    def __init__(self, num_workers: int, k_max: int = 1000, theta_frac: float = 0.25):
        super().__init__(num_workers)
        # entire-lifetime tracker == Alg. 1 with alpha=1 and one giant epoch
        self.tracker = EpochFrequencyTracker(
            FishParams(alpha=1.0, epoch=2**62, k_max=k_max)
        )
        self.theta = theta_frac / num_workers

    def _heavy_d(self, f_k: float) -> int:
        d = int(math.ceil(f_k * self.num_workers / max(self.theta, 1e-12) ** 0.5))
        return max(2, min(d, self.num_workers))

    def _candidates_d(self, key, d: int) -> List[int]:
        cands = {hash32((key, s)) % self.num_workers for s in range(d)}
        return list(cands)

    def assign(self, key, now: float = 0.0) -> int:
        self.tracker.update(key)
        f_k = self.tracker.frequency(key)
        if f_k > self.theta:
            cands = self._candidates_d(key, self._heavy_d(f_k))
        else:
            cands = self._candidates(key)
        return self._record(key, self._pick_least_loaded(cands))


class WChoices(DChoices):
    """W-Choices [15]: heavy hitters may use the entire worker set."""

    name = "wc"

    def assign(self, key, now: float = 0.0) -> int:
        self.tracker.update(key)
        f_k = self.tracker.frequency(key)
        if f_k > self.theta:
            cands = list(range(self.num_workers))
        else:
            cands = self._candidates(key)
        return self._record(key, self._pick_least_loaded(cands))


class FishGrouper(Grouper):
    """The paper's grouper: Alg. 1 + Alg. 2 + Alg. 3 + consistent hashing."""

    name = "fish"

    def __init__(
        self,
        num_workers: int,
        params: Optional[FishParams] = None,
        capacities: Optional[np.ndarray] = None,
        interval: float = 10.0,
        virtual_nodes: int = 64,
        use_consistent_hash: bool = True,
    ):
        super().__init__(num_workers)
        self.params = params or FishParams()
        self.tracker = EpochFrequencyTracker(self.params)
        self.estimator = WorkerStateEstimator(
            capacities=(
                np.ones(num_workers) if capacities is None else np.asarray(capacities)
            ),
            interval=interval,
        )
        self.use_consistent_hash = use_consistent_hash
        self.ring = ConsistentHashRing(range(num_workers), virtual_nodes=virtual_nodes)
        self._active = list(range(num_workers))
        self.m_k: Dict[object, int] = {}  # CHK monotone memory M

    def assign(self, key, now: float = 0.0) -> int:
        self.tracker.update(key)
        theta = self.params.theta(self.num_workers)
        f_k = self.tracker.frequency(key)
        f_top = self.tracker.top_frequency()
        d, m_new = chk_num_workers(
            f_k, f_top, theta, self.num_workers, self.params.d_min,
            self.m_k.get(key, 0),
        )
        if m_new:
            self.m_k[key] = m_new
        if self.use_consistent_hash:
            candidates = self.ring.lookup_n(key, d)
        else:
            # mod-hash candidates (the §5 strawman — remaps everything on
            # membership change; used for the RQ4 w/o-CH comparison)
            n_active = len(self._active)
            candidates = list(
                {self._active[hash32((key, s)) % n_active] for s in range(d)}
            )
        worker = self.estimator.select(candidates, now)
        return self._record(key, worker)

    # -- heterogeneity + elasticity hooks -----------------------------------------
    def record_capacity_sample(self, worker: int, seconds_per_tuple: float) -> None:
        self.estimator.record_capacity_sample(worker, seconds_per_tuple)

    def on_membership_change(self, workers: Sequence[int]) -> None:
        """Elastic add/remove via consistent hashing (paper §5)."""
        current = set(self.ring.workers)
        target = set(workers)
        self._active = sorted(target)
        for w in current - target:
            self.ring.remove_worker(w)
        for w in target - current:
            self.ring.add_worker(w)
            if w >= self.num_workers:
                grow = w + 1 - self.num_workers
                self.assigned_counts = np.concatenate(
                    [self.assigned_counts, np.zeros(grow, dtype=np.int64)]
                )
                self.estimator.capacities = np.concatenate(
                    [self.estimator.capacities, np.ones(grow)]
                )
                self.estimator.backlog = np.concatenate(
                    [self.estimator.backlog, np.zeros(grow)]
                )
                self.estimator.assigned = np.concatenate(
                    [self.estimator.assigned, np.zeros(grow)]
                )
                self.num_workers = w + 1


_GROUPERS = {
    "sg": ShuffleGrouping,
    "fg": FieldGrouping,
    "pkg": PartialKeyGrouping,
    "dc": DChoices,
    "wc": WChoices,
    "fish": FishGrouper,
}


def make_grouper(name: str, num_workers: int, **kwargs) -> Grouper:
    try:
        cls = _GROUPERS[name.lower()]
    except KeyError:
        raise ValueError(f"unknown grouping scheme {name!r}; one of {list(_GROUPERS)}")
    return cls(num_workers, **kwargs)
