"""Stream grouping schemes (paper §2.2) + the FISH grouper itself.

All groupers share one interface used by the stream simulator
(:mod:`repro.core.stream`), the data pipeline and the serving router::

    worker = grouper.assign(key, now)

and expose ``state_replicas()`` — the set of (key -> workers) mappings they
created, which is the paper's memory-overhead metric (Σ_w distinct keys held
on w, normalised to FG's 1 replica per key).

Membership is first class (ISSUE 2 tentpole): every grouper tracks the live
worker set and honors it from both ``assign`` and ``assign_batch``.  SG
round-robins over the live list; the hash-based schemes (FG/PKG/DC/WC/FISH)
draw candidates from a shared consistent-hash ring over the live set (the
paper's §5 mechanism), so a membership change only remaps keys whose ring
arcs are affected.  Scale-out grows the per-worker arrays in place — worker
ids are never reused.  Per-scheme semantics are tabulated in DESIGN.md §5.

Baselines:
  * SG  — Shuffle Grouping: round-robin, ignores the key.
  * FG  — Field Grouping: single owner per key (nearest live worker
          clockwise on the ring).
  * PKG — Partial Key Grouping: power-of-two-choices between the first 2
          ring candidates, pick the one with the smaller local count.
  * DC  — D-Choices: SpaceSaving heavy hitters over the *entire lifetime* get
          d ring candidates; the rest use PKG.
  * WC  — W-Choices: like DC but heavy hitters may use all live workers.
  * FISH — epoch-decayed hot keys (Alg. 1) + CHK (Alg. 2) + heuristic worker
          assignment (Alg. 3) over consistent-hash candidates (§5).
"""

from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from .assignment import WorkerStateEstimator, greedy_allocate
from .chash import ConsistentHashRing, hash32
from .fish import (EpochFrequencyTracker, FishParams, chk_num_workers,
                   chk_num_workers_batch)

__all__ = [
    "Grouper",
    "ShuffleGrouping",
    "FieldGrouping",
    "PartialKeyGrouping",
    "DChoices",
    "WChoices",
    "FishGrouper",
    "make_grouper",
]


_RING_CACHE: Dict[tuple, ConsistentHashRing] = {}


def _initial_ring(num_workers: int, virtual_nodes: int) -> ConsistentHashRing:
    """Memoised pristine ring for the initial [0, W) worker set — each
    grouper gets a private clone, so membership mutations never leak."""
    key = (num_workers, virtual_nodes)
    ring = _RING_CACHE.get(key)
    if ring is None:
        ring = _RING_CACHE[key] = ConsistentHashRing(
            range(num_workers), virtual_nodes=virtual_nodes
        )
    return ring.clone()


class Grouper:
    """Base class: key->worker replicas, per-worker counts, live membership."""

    name = "base"
    _uses_ring = True  # SG routes without hashing and skips ring construction

    def __init__(self, num_workers: int, virtual_nodes: int = 64):
        self.num_workers = num_workers  # worker-id universe size (array length)
        self.replicas: Dict[object, Set[int]] = {}
        self.assigned_counts = np.zeros(num_workers, dtype=np.int64)
        self._active: List[int] = list(range(num_workers))
        self.ring: Optional[ConsistentHashRing] = (
            _initial_ring(num_workers, virtual_nodes) if self._uses_ring
            else None
        )
        # unique-key cache of the clockwise live-worker order, shared by every
        # ring-based scheme; invalidated on membership change
        self._ring_order: Dict[object, List[int]] = {}

    # -- interface ---------------------------------------------------------------
    def assign(self, key, now: float = 0.0) -> int:
        raise NotImplementedError

    def assign_batch(self, keys, now0: float = 0.0, dt: float = 0.0) -> np.ndarray:
        """Vectorised routing of a whole chunk (ISSUE 1 tentpole).

        ``keys`` is a 1-D integer ndarray of interned key ids; tuple ``i``
        arrives at logical time ``now0 + i*dt``.  Subclasses override with
        NumPy implementations; this fallback replays :meth:`assign` per tuple
        and is the oracle the equivalence tests compare against.
        """
        keys = np.asarray(keys)
        out = np.empty(keys.shape[0], dtype=np.int64)
        for i in range(keys.shape[0]):
            out[i] = self.assign(keys[i], now0 + i * dt)
        return out

    def _record(self, key, worker: int) -> int:
        self.replicas.setdefault(key, set()).add(worker)
        self.assigned_counts[worker] += 1
        return worker

    def _record_batch(self, keys: np.ndarray, workers: np.ndarray) -> np.ndarray:
        """Bulk :meth:`_record`: replica sets via unique (key, worker) pairs,
        assigned counts via one bincount."""
        self.assigned_counts += np.bincount(
            workers, minlength=self.assigned_counts.shape[0]
        )
        if keys.dtype.kind in "iu":
            w_mod = self.assigned_counts.shape[0]
            pair = keys.astype(np.int64) * np.int64(w_mod) \
                + workers.astype(np.int64)
            for p in np.unique(pair).tolist():
                self.replicas.setdefault(p // w_mod, set()).add(int(p % w_mod))
        else:
            # object/string keys: the caches above are dtype-agnostic, only
            # the pair encoding needs integers — record per tuple instead
            for k, w in zip(keys.tolist(), workers.tolist()):
                self.replicas.setdefault(k, set()).add(int(w))
        return workers

    # -- live-set helpers ----------------------------------------------------------
    @property
    def active_workers(self) -> List[int]:
        return list(self._active)

    def _ring_prefix(self, key, d: int) -> List[int]:
        """First ``d`` distinct live workers clockwise from ``key``.

        The clockwise order is stable, so ``lookup_n(key, d)`` is a prefix of
        ``lookup_n(key, d')`` for d' > d: cache the longest walk so far and
        extend lazily (non-hot keys only ever walk 1-2 steps).
        """
        order = self._ring_order.get(key)
        if order is None or (len(order) < d and len(order) < len(self.ring)):
            order = self._ring_order[key] = self.ring.lookup_n(key, d)
        return order[:d]

    def probe_route(self, key) -> Optional[int]:
        """Primary route for ``key`` without recording anything — the remap
        accounting probe (Fig. 17 "keys moved per membership event").  None
        for schemes with no key affinity (SG)."""
        if self.ring is None:
            return None
        return self._ring_prefix(key, 1)[0]

    # -- metrics -----------------------------------------------------------------
    def memory_overhead(self) -> int:
        """Σ_w |distinct keys on worker w|  (paper's memory metric)."""
        return int(sum(len(ws) for ws in self.replicas.values()))

    def memory_overhead_normalized(self) -> float:
        """Normalised to FG (= 1 replica per distinct key)."""
        n_keys = max(len(self.replicas), 1)
        return self.memory_overhead() / float(n_keys)

    # hooks for heterogeneous-capacity runtimes; default no-op
    def record_capacity_sample(self, worker: int, seconds_per_tuple: float,
                               ema: float = 0.5) -> None:
        pass

    # -- elasticity (paper §5) -----------------------------------------------------
    def on_membership_change(self, workers: Sequence[int]) -> None:
        """Switch the live worker set.  Honored by every scheme: SG
        round-robins over the new list, ring-based schemes remap only the
        keys on affected arcs.  Worker ids beyond the current universe grow
        the per-worker arrays in place (ids are never reused)."""
        target = sorted(int(w) for w in workers)
        if not target:
            raise ValueError("membership change needs at least one live worker")
        if target[-1] >= self.num_workers:
            self._grow_arrays(target[-1] + 1)
            self.num_workers = target[-1] + 1
        if self.ring is not None:
            current = set(self.ring.workers)
            tset = set(target)
            # sorted: add/remove order decides linear-probe placement on
            # ring-point hash collisions, so set order must not leak in
            for w in sorted(current - tset):
                self.ring.remove_worker(w)
            for w in sorted(tset - current):
                self.ring.add_worker(w)
        self._active = target
        self._ring_order.clear()  # candidate caches are keyed on membership
        self._membership_caches_clear()

    def _grow_arrays(self, new_size: int) -> None:
        grow = new_size - self.assigned_counts.shape[0]
        if grow > 0:
            self.assigned_counts = np.concatenate(
                [self.assigned_counts, np.zeros(grow, dtype=np.int64)]
            )

    def _membership_caches_clear(self) -> None:
        pass


class ShuffleGrouping(Grouper):
    name = "sg"
    _uses_ring = False

    def __init__(self, num_workers: int):
        super().__init__(num_workers)
        self._rr = 0

    def assign(self, key, now: float = 0.0) -> int:
        act = self._active
        w = act[self._rr]
        self._rr = (self._rr + 1) % len(act)
        return self._record(key, w)

    def assign_batch(self, keys, now0: float = 0.0, dt: float = 0.0) -> np.ndarray:
        keys = np.asarray(keys)
        n = keys.shape[0]
        act = np.asarray(self._active, dtype=np.int64)
        workers = act[(self._rr + np.arange(n, dtype=np.int64)) % act.shape[0]]
        self._rr = int((self._rr + n) % act.shape[0])
        return self._record_batch(keys, workers)

    def _membership_caches_clear(self) -> None:
        self._rr %= len(self._active)


class FieldGrouping(Grouper):
    """One owner per key: the nearest live worker clockwise on the ring.

    With a static membership this is the paper's FG (a fixed hash of the
    key); under churn the consistent-hash property keeps every key whose
    owner survived on the same worker (tested in tests/test_membership.py).
    """

    name = "fg"

    def assign(self, key, now: float = 0.0) -> int:
        return self._record(key, self._ring_prefix(key, 1)[0])

    def assign_batch(self, keys, now0: float = 0.0, dt: float = 0.0) -> np.ndarray:
        keys = np.asarray(keys)
        uniq, inv = np.unique(keys, return_inverse=True)
        w_uniq = np.empty(uniq.shape[0], dtype=np.int64)
        for j, k in enumerate(uniq.tolist()):
            w_uniq[j] = self._ring_prefix(k, 1)[0]
        return self._record_batch(keys, w_uniq[inv])


class PartialKeyGrouping(Grouper):
    """Power of two choices between the first two ring candidates [14]."""

    name = "pkg"

    def _candidates(self, key) -> List[int]:
        cands = self._ring_prefix(key, 2)
        if len(cands) == 1:  # single live worker
            return [cands[0], cands[0]]
        return cands

    def _pick_least_loaded(self, cands: Sequence[int]) -> int:
        loads = self.assigned_counts[list(cands)]
        return int(cands[int(np.argmin(loads))])

    def assign(self, key, now: float = 0.0) -> int:
        return self._record(key, self._pick_least_loaded(self._candidates(key)))

    def _pairs_for(self, uniq: np.ndarray) -> np.ndarray:
        """(U, 2) candidate pairs; ring walks cached per unique key ever."""
        pairs = np.empty((uniq.shape[0], 2), dtype=np.int64)
        for j, k in enumerate(uniq.tolist()):
            pairs[j] = self._candidates(k)
        return pairs

    def _two_choice_loop(self, c0: np.ndarray, c1: np.ndarray) -> np.ndarray:
        """Exact sequential two-choice selection with cumulative-count
        tie-breaking (ties go to the first candidate, as np.argmin does)."""
        counts = self.assigned_counts.tolist()
        ol = []
        append = ol.append
        for a, b in zip(c0.tolist(), c1.tolist()):
            w = a if counts[a] <= counts[b] else b
            counts[w] += 1
            append(w)
        return np.asarray(ol, dtype=np.int64)

    def assign_batch(self, keys, now0: float = 0.0, dt: float = 0.0) -> np.ndarray:
        keys = np.asarray(keys)
        uniq, inv = np.unique(keys, return_inverse=True)
        pairs = self._pairs_for(uniq)[inv]
        workers = self._two_choice_loop(pairs[:, 0], pairs[:, 1])
        return self._record_batch(keys, workers)


class DChoices(PartialKeyGrouping):
    """D-Choices [15]: lifetime SpaceSaving heavy hitters -> d candidates.

    ``d`` is chosen per [15] as the smallest d such that the head frequency can
    be spread below the imbalance bound; we use their practical rule
    d = ceil(f_k * W / theta-bound) capped at W, matching the reference
    implementation's behaviour for skewed streams.
    """

    name = "dc"

    # batched sub-chunk size: frequencies refresh at this granularity (the
    # epoch-batching discipline of FISH applied to the D-C/W-C trackers)
    _batch_cap = 2048

    # sentinel returned by _heavy_candidates meaning "every live worker": the
    # batched selection loop dispatches on it to the global-least-loaded
    # heap instead of scanning a W-element candidate list per tuple
    _FULL_SET: List[int] = []

    def __init__(self, num_workers: int, k_max: int = 1000, theta_frac: float = 0.25):
        super().__init__(num_workers)
        # entire-lifetime tracker == Alg. 1 with alpha=1 and one giant epoch
        self.tracker = EpochFrequencyTracker(
            FishParams(alpha=1.0, epoch=2**62, k_max=k_max)
        )
        self.theta_frac = theta_frac

    @property
    def theta(self) -> float:
        """Heavy-hitter threshold theta_frac/W — tracks the worker universe
        as it grows on scale-out (same rule FISH applies per call)."""
        return self.theta_frac / self.num_workers

    def _heavy_d(self, f_k: float) -> int:
        d = int(math.ceil(f_k * self.num_workers / max(self.theta, 1e-12) ** 0.5))
        return max(2, min(d, self.num_workers))

    def assign(self, key, now: float = 0.0) -> int:
        self.tracker.update(key)
        f_k = self.tracker.frequency(key)
        if f_k > self.theta:
            cands = self._ring_prefix(key, self._heavy_d(f_k))
        else:
            cands = self._candidates(key)
        return self._record(key, self._pick_least_loaded(cands))

    # -- batched path ------------------------------------------------------------
    def _heavy_candidates(self, key: int, f_k: float) -> List[int]:
        return self._ring_prefix(key, self._heavy_d(f_k))

    def assign_batch(self, keys, now0: float = 0.0, dt: float = 0.0) -> np.ndarray:
        """Sub-chunked D-C/W-C: one batched SpaceSaving update per sub-chunk,
        then cumulative-count least-loaded selection with per-unique-key
        candidate arrays (frequencies are read at sub-chunk granularity —
        the bounded divergence documented in DESIGN.md §6)."""
        keys = np.asarray(keys)
        n = keys.shape[0]
        out = np.empty(n, dtype=np.int64)
        counts = self.assigned_counts.tolist()
        for lo in range(0, n, self._batch_cap):
            chunk = keys[lo : lo + self._batch_cap]
            self.tracker.update_many(chunk)
            total = sum(self.tracker.counts.values())
            uniq, inv = np.unique(chunk, return_inverse=True)
            pairs = self._pairs_for(uniq)
            cand_lists: List[Optional[List[int]]] = []
            for j, k in enumerate(uniq.tolist()):
                f_k = self.tracker.counts.get(k, 0.0) / total if total > 0 else 0.0
                if f_k > self.theta:
                    cand_lists.append(self._heavy_candidates(k, f_k))
                else:
                    cand_lists.append(None)  # light: use the PKG pair
            c0, c1 = pairs[:, 0].tolist(), pairs[:, 1].tolist()
            full_set = self._FULL_SET
            heap = None  # lazy (count, worker) min-heap for full-set argmin
            for i, j in enumerate(inv.tolist()):
                cl = cand_lists[j]
                if cl is None:
                    a, b = c0[j], c1[j]
                    w = a if counts[a] <= counts[b] else b
                elif cl is full_set:
                    # global least-loaded over the live set (W-Choices heavy
                    # hitters): a lazy heap replaces the O(W) scan;
                    # (count, idx) ordering reproduces np.argmin's
                    # smallest-index tie-breaking
                    if heap is None:
                        heap = [(counts[wk], wk) for wk in self._active]
                        heapq.heapify(heap)
                    while True:
                        ch, w = heap[0]
                        if counts[w] == ch:
                            break
                        heapq.heappop(heap)  # stale entry
                else:
                    w = min(cl, key=counts.__getitem__)
                counts[w] += 1
                if heap is not None:
                    heapq.heappush(heap, (counts[w], w))
                out[lo + i] = w
        self._record_batch(keys, out)
        return out


class WChoices(DChoices):
    """W-Choices [15]: heavy hitters may use the entire live worker set."""

    name = "wc"

    def assign(self, key, now: float = 0.0) -> int:
        self.tracker.update(key)
        f_k = self.tracker.frequency(key)
        if f_k > self.theta:
            cands = self._active
        else:
            cands = self._candidates(key)
        return self._record(key, self._pick_least_loaded(cands))

    def _heavy_candidates(self, key: int, f_k: float) -> List[int]:
        return self._FULL_SET  # sentinel: least-loaded over the live set


class FishGrouper(Grouper):
    """The paper's grouper: Alg. 1 + Alg. 2 + Alg. 3 + consistent hashing."""

    name = "fish"

    def __init__(
        self,
        num_workers: int,
        params: Optional[FishParams] = None,
        capacities: Optional[np.ndarray] = None,
        interval: float = 10.0,
        virtual_nodes: int = 64,
        use_consistent_hash: bool = True,
    ):
        super().__init__(num_workers, virtual_nodes=virtual_nodes)
        self.params = params or FishParams()
        self.tracker = EpochFrequencyTracker(self.params)
        self.estimator = WorkerStateEstimator(
            capacities=(
                np.ones(num_workers) if capacities is None else np.asarray(capacities)
            ),
            interval=interval,
        )
        self.use_consistent_hash = use_consistent_hash
        self.m_k: Dict[object, int] = {}  # CHK monotone memory M
        # mod-hash candidate cache per (key, d) — the §5 strawman path only
        self._mod_cands: Dict[tuple, List[int]] = {}

    def _mod_candidates(self, key, d: int) -> List[int]:
        """Mod-hash candidates (the §5 strawman — remaps everything on
        membership change; used for the RQ4 w/o-CH comparison)."""
        ck = (key, d)
        cands = self._mod_cands.get(ck)
        if cands is None:
            n_active = len(self._active)
            cands = self._mod_cands[ck] = list(
                {self._active[hash32((key, s)) % n_active] for s in range(d)}
            )
        return cands

    def assign(self, key, now: float = 0.0) -> int:
        self.tracker.update(key)
        theta = self.params.theta(self.num_workers)
        f_k = self.tracker.frequency(key)
        f_top = self.tracker.top_frequency()
        d, m_new = chk_num_workers(
            f_k, f_top, theta, self.num_workers, self.params.d_min,
            self.m_k.get(key, 0),
        )
        if m_new:
            self.m_k[key] = m_new
        if self.use_consistent_hash:
            candidates = self._ring_prefix(key, d)
        else:
            candidates = self._mod_candidates(key, d)
        worker = self.estimator.select(candidates, now)
        return self._record(key, worker)

    # -- batched path --------------------------------------------------------------
    def _candidates_batch(self, key: int, d: int) -> List[int]:
        if self.use_consistent_hash:
            return self._ring_prefix(key, d)
        return self._mod_candidates(key, d)

    def assign_batch(self, keys, now0: float = 0.0, dt: float = 0.0) -> np.ndarray:
        """Epoch-batched FISH: per sub-chunk one bulk Alg. 1 update, one
        vectorised Alg. 2 (CHK) pass over the chunk's unique keys, and one
        one greedy Alg. 3 allocation per unique key (an exact heap replay
        of the per-tuple Eq. 2 argmin)."""
        keys = np.asarray(keys)
        n = keys.shape[0]
        out = np.empty(n, dtype=np.int64)
        p = self.params
        est = self.estimator
        i = 0
        while i < n:
            # sub-chunk: cut at tracker epoch boundaries and estimator ticks
            now_i = now0 + i * dt
            est.maybe_estimate(now_i)
            room = p.epoch - self.tracker._tuples_in_epoch
            hi = min(n, i + (room if room > 0 else p.epoch))
            if dt > 0.0:
                tick = int(
                    math.floor((est._t_prior + est.interval - now0) / dt)
                ) + 1
                if i < tick < hi:
                    hi = tick
            chunk = keys[i:hi]
            self.tracker.update_many(chunk)
            self._assign_chunk(chunk, out[i:hi])
            i = hi
        self._record_batch(keys, out)
        return out

    def _assign_chunk(self, chunk: np.ndarray, out: np.ndarray) -> None:
        uniq, first, inv, cnt = np.unique(
            chunk, return_index=True, return_inverse=True, return_counts=True
        )
        counts = self.tracker.counts
        total = sum(counts.values())
        uniq_l = uniq.tolist()
        if total <= 0.0:
            f_u = np.zeros(uniq.shape[0])
            f_top = 0.0
        else:
            f_u = np.fromiter(
                (counts.get(k, 0.0) for k in uniq_l), dtype=np.float64,
                count=len(uniq_l),
            ) / total
            f_top = max(counts.values()) / total

        # vectorised CHK (Alg. 2) with monotone memory M_k
        m_prev = np.fromiter(
            (self.m_k.get(k, 0) for k in uniq_l), dtype=np.int64,
            count=len(uniq_l),
        )
        d_eff, m_new = chk_num_workers_batch(
            f_u, f_top, self.params.theta(self.num_workers),
            self.num_workers, self.params.d_min, m_prev,
        )
        for j in np.flatnonzero(m_new > m_prev).tolist():
            self.m_k[uniq_l[j]] = int(m_new[j])

        # Alg. 3 allocation, unique keys in first-appearance order
        # (approximates the stream-order argmin interleaving).  The estimator
        # state is pulled into scalar lists for the chunk; each key's share
        # is the exact greedy Eq. 2 replay (scalar loop for tiny
        # allocations, heap for large ones).
        pos_order = np.argsort(inv, kind="stable")
        starts = np.concatenate([[0], np.cumsum(cnt)[:-1]])
        est = self.estimator
        b_l = est.backlog.tolist()
        a_l = est.assigned.tolist()
        p_l = est.capacities.tolist()
        cnt_l = cnt.tolist()
        d_l = d_eff.tolist()
        for j in np.argsort(first, kind="stable").tolist():
            cands = self._candidates_batch(uniq_l[j], d_l[j])
            c = cnt_l[j]
            s = starts[j]
            if c == 1:
                best = cands[0]
                bw = (b_l[best] + a_l[best]) * p_l[best]
                for cd in cands[1:]:
                    wv = (b_l[cd] + a_l[cd]) * p_l[cd]
                    if wv < bw:
                        best, bw = cd, wv
                a_l[best] += 1.0
                out[pos_order[s]] = best
            elif c * len(cands) <= 256:
                # small allocation: replay the exact sequential greedy
                # (argmin per tuple) on scalar state — cheaper than NumPy
                # setup and preserves the sequential interleaving exactly
                waits = [(b_l[cd] + a_l[cd]) * p_l[cd] for cd in cands]
                seq = []
                for _ in range(c):
                    bi = 0
                    bw = waits[0]
                    for ii in range(1, len(waits)):
                        if waits[ii] < bw:
                            bw, bi = waits[ii], ii
                    cd = cands[bi]
                    waits[bi] += p_l[cd]
                    a_l[cd] += 1.0
                    seq.append(cd)
                out[pos_order[s : s + c]] = seq
            else:
                carr = np.asarray(cands, dtype=np.int64)
                caps = np.asarray([p_l[cd] for cd in cands])
                waits = np.asarray(
                    [(b_l[cd] + a_l[cd]) * p_l[cd] for cd in cands]
                )
                alloc = greedy_allocate(waits, caps, c)
                for cd, nc in zip(cands, alloc.tolist()):
                    a_l[cd] += float(nc)
                # interleave the key's tuples across its candidates (stride
                # proportional to each share) instead of contiguous blocks —
                # keeps per-worker arrivals smooth, matching the sequential
                # argmin's alternation and its latency profile
                wk_seq = np.repeat(carr, alloc)
                frac = np.concatenate(
                    [(np.arange(nc) + 0.5) / nc for nc in alloc.tolist() if nc]
                )
                out[pos_order[s : s + c]] = wk_seq[
                    np.argsort(frac, kind="stable")
                ]
        est.assigned[: len(a_l)] = a_l

    # -- heterogeneity + elasticity hooks -----------------------------------------
    def record_capacity_sample(self, worker: int, seconds_per_tuple: float,
                               ema: float = 0.5) -> None:
        self.estimator.record_capacity_sample(worker, seconds_per_tuple, ema)

    def probe_route(self, key) -> Optional[int]:
        if self.use_consistent_hash:
            return self._ring_prefix(key, 1)[0]
        return self._active[hash32((key, 0)) % len(self._active)]

    def _grow_arrays(self, new_size: int) -> None:
        super()._grow_arrays(new_size)
        self.estimator.ensure_size(new_size)

    def _membership_caches_clear(self) -> None:
        self._mod_cands.clear()


def make_grouper(name: str, num_workers: int, **kwargs) -> Grouper:
    """Deprecated stringly-typed factory — a thin shim over the typed-config
    registry in :mod:`repro.topology.configs`.

    New code uses one config per scheme (``FishConfig(...).build(w)``) or
    :func:`repro.topology.configs.build_grouper`; this shim keeps legacy
    ``make_grouper(name, **kwargs)`` call sites working unchanged.
    """
    import warnings

    warnings.warn(
        "make_grouper is deprecated; use the typed scheme configs in "
        "repro.topology.configs (e.g. FishConfig().build(num_workers)) or "
        "repro.topology.configs.build_grouper",
        DeprecationWarning, stacklevel=2,
    )
    from ..topology.configs import legacy_build

    return legacy_build(name, num_workers, **kwargs)
