"""FISH-grouped streaming data pipeline.

Keyed documents stream in; a pluggable grouping scheme (any of
``repro.core.baselines``, FISH by default) assigns each document to a
data-parallel *host shard*; each shard packs tokens into fixed (B_local, S)
batches.  This is the paper's DAG (source -> grouping -> worker) with the
worker = a training host's input queue:

* hot document keys are spread over several hosts (CHK) so no host's input
  queue backs up (latency = step-time jitter at the training level);
* per-host *state* (e.g. dedup tables / tokenizer caches keyed by doc key)
  is replicated only where a key was actually routed — the paper's memory
  metric, exposed via ``memory_overhead()``;
* straggler mitigation: the Alg. 3 estimator routes fewer documents to slow
  hosts (heterogeneous ``P_w``), and :meth:`report_host_time` feeds measured
  step times back as capacity samples;
* elastic scaling: host join/leave remaps via consistent hashing (§5).
"""

from __future__ import annotations

from collections import deque
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.baselines import Grouper
from ..core.fish import FishParams

__all__ = ["StreamingPipeline"]


class StreamingPipeline:
    """Route keyed documents to host shards and pack token batches."""

    def __init__(
        self,
        num_hosts: int,
        seq_len: int,
        batch_per_host: int,
        grouping: Union[str, "SchemeConfig"] = "fish",
        fish_params: Optional[FishParams] = None,
        host_capacities: Optional[np.ndarray] = None,
        seed: int = 0,
    ):
        from ..topology.configs import FishConfig, SchemeConfig, config_for

        self.num_hosts = num_hosts
        self.seq_len = seq_len
        self.batch_per_host = batch_per_host
        # grouping: a typed SchemeConfig (ISSUE 3) or a scheme name
        if not isinstance(grouping, SchemeConfig):
            grouping = config_for(grouping)
        if isinstance(grouping, FishConfig) and fish_params is not None:
            grouping = FishConfig.from_params(
                fish_params, interval=grouping.interval,
                virtual_nodes=grouping.virtual_nodes,
                use_consistent_hash=grouping.use_consistent_hash)
        self.grouper: Grouper = grouping.build(num_hosts,
                                               capacities=host_capacities)
        self._buffers: Dict[int, deque] = {h: deque() for h in range(num_hosts)}
        self._clock = 0.0
        self._docs_routed = np.zeros(num_hosts, dtype=np.int64)
        self._rng = np.random.default_rng(seed)

    # -- ingestion ---------------------------------------------------------------
    def ingest(self, doc_key, tokens: np.ndarray) -> int:
        """Route one document; returns the host it went to."""
        host = self.grouper.assign(doc_key, self._clock)
        self._clock += 1e-4
        buf = self._buffers.setdefault(host, deque())
        buf.extend(tokens.tolist())
        self._docs_routed[host] += 1
        return host

    def ingest_batch(self, doc_keys: Sequence,
                     token_arrays: Sequence[np.ndarray]) -> np.ndarray:
        """Route a whole chunk of documents with one ``assign_batch`` call.

        ``doc_keys`` must be interned integer ids (see
        :func:`repro.data.synthetic.intern_keys`); returns the host id per
        document.  This is the data-pipeline face of the batched grouping
        engine — no per-document Python hashing or routing.
        """
        keys = np.asarray(doc_keys)
        hosts = self.grouper.assign_batch(keys, self._clock, 1e-4)
        self._clock += 1e-4 * keys.shape[0]
        for h, toks in zip(hosts.tolist(), token_arrays):
            self._buffers.setdefault(h, deque()).extend(toks.tolist())
        counts = np.bincount(hosts, minlength=self._docs_routed.shape[0])
        if counts.shape[0] > self._docs_routed.shape[0]:
            self._docs_routed = np.concatenate(
                [self._docs_routed,
                 np.zeros(counts.shape[0] - self._docs_routed.shape[0],
                          dtype=np.int64)]
            )
        self._docs_routed[: counts.shape[0]] += counts
        return hosts

    def ingest_stream(self, stream: Iterator[Tuple[int, np.ndarray]],
                      max_docs: Optional[int] = None, batch: int = 1024) -> None:
        """Drain ``stream`` through :meth:`ingest_batch` in chunks."""
        pending_k: List[int] = []
        pending_t: List[np.ndarray] = []
        for i, (key, tokens) in enumerate(stream):
            if max_docs is not None and i >= max_docs:
                break
            pending_k.append(key)
            pending_t.append(tokens)
            if len(pending_k) >= batch:
                self.ingest_batch(np.asarray(pending_k), pending_t)
                pending_k, pending_t = [], []
        if pending_k:
            self.ingest_batch(np.asarray(pending_k), pending_t)

    # -- batching ----------------------------------------------------------------
    def host_ready(self, host: int) -> bool:
        need = self.seq_len * self.batch_per_host + self.batch_per_host
        return len(self._buffers.get(host, ())) >= need

    def ready(self) -> bool:
        return all(self.host_ready(h) for h in self._active_hosts())

    def _active_hosts(self) -> List[int]:
        return sorted(self._buffers)

    def next_host_batch(self, host: int) -> Optional[Dict[str, np.ndarray]]:
        """(B_local, S) tokens + next-token labels, or None if not ready."""
        if not self.host_ready(host):
            return None
        buf = self._buffers[host]
        n = self.batch_per_host * (self.seq_len + 1)
        flat = np.array([buf.popleft() for _ in range(n)], dtype=np.int32)
        flat = flat.reshape(self.batch_per_host, self.seq_len + 1)
        return {"tokens": flat[:, :-1], "labels": flat[:, 1:]}

    def next_global_batch(self, steal: bool = True
                          ) -> Optional[Dict[str, np.ndarray]]:
        """Assemble one global batch; with ``steal`` (default) starved hosts
        borrow tokens from the longest backlog (work stealing — the batch-
        assembly form of straggler mitigation).  Stolen tokens are a
        *contiguous run from the donor's head*, so both the donor's and the
        recipient's token streams stay in ingestion order (``pop()`` from
        the tail would hand the recipient a reversed slice of the donor's
        newest tokens)."""
        hosts = self._active_hosts()
        if steal:
            need = self.seq_len * self.batch_per_host + self.batch_per_host
            for h in hosts:
                while not self.host_ready(h):
                    donor = max(hosts, key=lambda x: len(self._buffers[x]))
                    dbuf = self._buffers[donor]
                    deficit = need - len(self._buffers[h])
                    if donor == h or len(dbuf) <= need:
                        return None  # nothing to steal anywhere
                    take = min(deficit, len(dbuf) - need)
                    if take <= 0:
                        return None
                    self._buffers[h].extend(
                        dbuf.popleft() for _ in range(take))
        parts = []
        for h in hosts:
            p = self.next_host_batch(h)
            if p is None:
                return None
            parts.append(p)
        return {
            k: np.concatenate([p[k] for p in parts], axis=0)
            for k in parts[0]
        }

    # -- runtime feedback / elasticity --------------------------------------------
    def report_host_time(self, host: int, seconds_per_doc: float) -> None:
        """Measured host speed -> Alg. 3 capacity sample (straggler feedback)."""
        self.grouper.record_capacity_sample(host, seconds_per_doc)

    def backlog(self) -> np.ndarray:
        return np.array([len(self._buffers.get(h, ()))
                         for h in self._active_hosts()])

    def memory_overhead(self) -> int:
        return self.grouper.memory_overhead()

    def rescale(self, hosts: Sequence[int]) -> None:
        """Elastic membership change (consistent hashing remap, §5).

        A removed host's backlog is *redistributed*, not stranded: its
        buffered tokens move as one in-order run to a surviving host chosen
        by the grouper (ring route for key-affine schemes; least-loaded for
        SG), and the dead buffer is deleted — otherwise ``_active_hosts``
        would keep the dead host and ``ready()``/``next_global_batch()``
        would wait forever on a queue nothing drains.
        """
        hosts = sorted(int(h) for h in hosts)
        live = set(hosts)
        self.grouper.on_membership_change(hosts)
        for h in hosts:
            self._buffers.setdefault(h, deque())
        for h in list(self._buffers):
            if h in live:
                continue
            buf = self._buffers.pop(h)
            if buf:
                target = self.grouper.probe_route(("rescale", h))
                if target is None or target not in live:
                    target = min(hosts,
                                 key=lambda x: len(self._buffers[x]))
                self._buffers[target].extend(buf)
        self.num_hosts = len(hosts)
        grow = max(hosts) + 1 - self._docs_routed.shape[0]
        if grow > 0:
            self._docs_routed = np.concatenate(
                [self._docs_routed, np.zeros(grow, dtype=np.int64)]
            )
