"""Time-evolving stream dataset generators (paper Table 2 / §6.1).

* :func:`zipf_time_evolving` — the paper's ZF dataset, generated exactly per
  §6.1: first ``0.8·N`` tuples draw key ``i`` with ``Pr[i] ∝ i^-z``; the last
  ``0.2·N`` tuples draw with ``Pr[i] ∝ (k - i + 1)^-z`` (k = 10^4), i.e. the
  hot head jumps to the other end of the key space — a hard hot-key flip.
* :func:`piecewise_zipf` — a generalised generator with ``phases`` hot-set
  rotations; used as the proxy for the MemeTracker / Amazon-Movie real-world
  datasets (catchwords drift across time), with tuple/key cardinalities scaled
  from Table 2 (noted in DESIGN.md §7).
* :func:`token_stream` — keyed *document* stream for the data-pipeline
  integration (keys follow piecewise zipf; payload is a token array).
* :func:`record_batches` — the token stream re-columnated as session-ready
  :class:`~repro.topology.RecordBatch` chunks (ISSUE 5): keys + a real
  float64 payload column + uniform-grid timestamps, so the Table-2 dataset
  proxies replay end to end through ``Engine.open(...).feed``.
"""

from __future__ import annotations

from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "zipf_probs",
    "zipf_time_evolving",
    "piecewise_zipf",
    "token_stream",
    "record_batches",
    "intern_keys",
]


def intern_keys(keys: Sequence) -> Tuple[np.ndarray, np.ndarray]:
    """Map arbitrary hashable keys to contiguous int32 ids.

    Returns ``(ids, vocab)`` with ``vocab[ids[i]] == keys[i]``.  The batched
    grouping engine routes on interned ids so the per-tuple hot path never
    hashes Python objects (ISSUE 1); generators below emit int32 directly.
    """
    vocab, ids = np.unique(np.asarray(keys), return_inverse=True)
    return ids.astype(np.int32), vocab


def zipf_probs(num_keys: int, z: float) -> np.ndarray:
    ranks = np.arange(1, num_keys + 1, dtype=np.float64)
    p = ranks ** (-z)
    return p / p.sum()


def zipf_time_evolving(
    num_tuples: int,
    num_keys: int = 100_000,
    z: float = 1.2,
    flip_at: float = 0.8,
    flip_head: int = 10_000,
    seed: int = 0,
) -> np.ndarray:
    """Paper §6.1 ZF generator.  Returns interned int32 key ids in
    [0, num_keys) — contiguous ids keep the batched engine hash-free."""
    rng = np.random.default_rng(seed)
    n1 = int(flip_at * num_tuples)
    n2 = num_tuples - n1
    p1 = zipf_probs(num_keys, z)
    # Pr[i] ∝ (k - i + 1)^-z for i in [1, k]; keys beyond k keep tail mass
    ranks = np.arange(1, num_keys + 1, dtype=np.float64)
    k = min(flip_head, num_keys)
    head = np.maximum(k - ranks + 1.0, 1.0) ** (-z)
    tail = np.maximum(ranks - k + 1.0, 1.0) ** (-z)
    p2 = np.where(ranks <= k, head, tail)
    p2 = p2 / p2.sum()
    part1 = rng.choice(num_keys, size=n1, p=p1)
    part2 = rng.choice(num_keys, size=n2, p=p2)
    return np.concatenate([part1, part2]).astype(np.int32)


def _piecewise_key_chunks(
    rng: np.random.Generator,
    num_tuples: int,
    num_keys: int,
    z: float,
    phases: int,
    chunk: int = 4096,
) -> Iterator[np.ndarray]:
    """Lazy piecewise-Zipf key chunks: the hot set rotates (rank->key
    permutation reshuffles) every ``num_tuples/phases`` tuples.  Shared by
    :func:`piecewise_zipf` (which concatenates) and :func:`token_stream`
    (which streams — callers routinely pass ``num_docs=10**9`` as
    "infinite", so nothing may be materialised upfront).

    Exactly ``phases`` rotations: the last phase absorbs the remainder when
    ``phases`` does not divide ``num_tuples``."""
    p = zipf_probs(num_keys, z)
    per = num_tuples // phases
    starts = [ph * per for ph in range(phases)] + [num_tuples]
    perm = np.arange(num_keys)
    for ph in range(phases):
        n_phase = starts[ph + 1] - starts[ph]
        if n_phase <= 0:
            continue
        rng.shuffle(perm)  # new rank->key mapping = new hot set
        done = 0
        while done < n_phase:
            n = min(chunk, n_phase - done)
            yield perm[rng.choice(num_keys, size=n, p=p)]
            done += n


def piecewise_zipf(
    num_tuples: int,
    num_keys: int,
    z: float = 1.2,
    phases: int = 5,
    seed: int = 0,
) -> np.ndarray:
    """Hot set rotates every num_tuples/phases tuples (real-dataset proxy).
    Returns interned int32 key ids."""
    rng = np.random.default_rng(seed)
    return np.concatenate(
        list(_piecewise_key_chunks(rng, num_tuples, num_keys, z, phases))
    ).astype(np.int32)


# Table 2 cardinality-matched proxies (tuples scaled down 50x for CI speed;
# scale=1.0 reproduces the paper's cardinalities).
def memetracker_proxy(scale: float = 0.02, seed: int = 1) -> np.ndarray:
    return piecewise_zipf(int(49_210_000 * scale), int(390_000 * max(scale, 0.02)),
                          z=1.1, phases=8, seed=seed)


def amazon_movie_proxy(scale: float = 0.02, seed: int = 2) -> np.ndarray:
    return piecewise_zipf(int(7_910_000 * scale), int(250_000 * max(scale, 0.02)),
                          z=1.2, phases=6, seed=seed)


def token_stream(
    num_docs: int,
    num_keys: int,
    doc_len: int,
    vocab_size: int,
    z: float = 1.2,
    phases: int = 4,
    seed: int = 0,
    token_z: float = 1.3,
) -> Iterator[Tuple[int, np.ndarray]]:
    """Yield (doc_key, tokens) pairs with a time-evolving key distribution.

    Token payloads are zipf-distributed with a key-dependent rotation, so a
    language model has learnable (unigram + doc-conditional) structure.

    Keys stream lazily from :func:`_piecewise_key_chunks` (same phase
    structure as :func:`piecewise_zipf`).  Callers routinely pass
    ``num_docs=10**9`` as "infinite"; materialising that key array upfront
    cost ~4 GB and minutes of rng.choice before the first doc was yielded.
    """
    rng = np.random.default_rng(seed)
    p_tok = zipf_probs(vocab_size, token_z)
    for keys in _piecewise_key_chunks(rng, num_docs, num_keys, z, phases):
        for k in keys.tolist():
            draws = rng.choice(vocab_size, size=doc_len, p=p_tok)
            toks = (draws + (k * 7)) % vocab_size  # doc-conditional shift
            yield int(k), toks.astype(np.int32)


def record_batches(
    num_docs: int,
    num_keys: int,
    doc_len: int,
    vocab_size: int,
    batch: int = 1_024,
    arrival_rate: float = 10_000.0,
    z: float = 1.2,
    phases: int = 4,
    seed: int = 0,
    token_z: float = 1.3,
):
    """Replay :func:`token_stream` as session-ready record batches.

    Each document becomes one record: key = the doc key, value = the doc's
    token sum (a real — and integral, so ``sum`` aggregation is exact —
    float64 payload), timestamp = its position on the uniform
    ``arrival_rate`` grid.  Yields :class:`~repro.topology.RecordBatch`
    chunks of ``batch`` records (last one short), lazily — nothing is
    materialised upfront, matching :func:`token_stream`'s contract.
    """
    from ..topology.graph import RecordBatch

    dt = 1.0 / arrival_rate
    ks: list = []
    vs: list = []
    base = 0
    for k, toks in token_stream(num_docs, num_keys, doc_len, vocab_size,
                                z=z, phases=phases, seed=seed,
                                token_z=token_z):
        ks.append(k)
        vs.append(float(int(toks.sum())))
        if len(ks) == batch:
            n = len(ks)
            yield RecordBatch(np.asarray(ks, dtype=np.int32),
                              (base + np.arange(n, dtype=np.float64)) * dt,
                              np.asarray(vs))
            base += n
            ks, vs = [], []
    if ks:
        n = len(ks)
        yield RecordBatch(np.asarray(ks, dtype=np.int32),
                          (base + np.arange(n, dtype=np.float64)) * dt,
                          np.asarray(vs))
