"""Model assembly for all assigned architectures.

One config-driven stack covers: dense GQA/MQA decoders (qwen1.5, starcoder2,
olmo, gemma2, qwen2-vl), MoE decoders (kimi-k2, deepseek-v2-lite incl. MLA),
attention-free Mamba-2, the Griffin hybrid (recurrentgemma), and the Whisper
encoder-decoder.  Three entry points per model:

* :func:`forward_train`  — full-sequence loss (+ MoE aux, FISH hotness carry)
* :func:`prefill`        — full-sequence pass that also builds the decode cache
* :func:`decode_step`    — one token against the cache (the ``serve_step``)

Layers are ``lax.scan``-stacked (param leaves lead with the layer axis) with
optional ``jax.checkpoint`` remat; heterogeneous stacks (gemma2 local/global
alternation, griffin's rec-rec-attn pattern, MoE first-dense prefix) are
handled by pattern-grouped scans so every attention mask stays static.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import ssm as ssm_mod
from .attention import (decode_attention, flash_attention, mla_decode_scores,
                        mla_expand)
from .common import (apply_mrope, apply_norm, apply_rope, soft_cap)
from .moe import init_hotness, init_moe_params, moe_ffn
from .sharding import current_rules, shard, shard_seq

__all__ = [
    "init_params",
    "init_hotness_state",
    "forward_train",
    "prefill",
    "decode_step",
    "init_cache",
    "num_params",
]


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rows padded to a multiple of 128 (Megatron-style) so the
    vocab dim shards evenly over tp; pad logits are masked to -inf."""
    return -(-cfg.vocab_size // 128) * 128


# ===========================================================================
# Parameter init
# ===========================================================================


def _norm_params(cfg: ModelConfig, dim: int, dtype):
    if cfg.norm == "nonparametric":
        return {}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if cfg.norm == "rmsnorm_plus_one":
        return {"scale": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype)}


def _norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm == "nonparametric":
        return apply_norm(x, None, "nonparametric", cfg.norm_eps)
    return apply_norm(x, p, cfg.norm, cfg.norm_eps)


def _init_attn(key, cfg: ModelConfig, dtype):
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, hq * dh), jnp.float32) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * dh), jnp.float32) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * dh), jnp.float32) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (hq * dh, d), jnp.float32)
               * (1.0 / math.sqrt(hq * dh))).astype(dtype),
    }
    if cfg.qkv_bias:
        p.update(
            bq=jnp.zeros((hq * dh,), dtype),
            bk=jnp.zeros((hkv * dh,), dtype),
            bv=jnp.zeros((hkv * dh,), dtype),
        )
    return p


def _init_mla(key, cfg: ModelConfig, dtype):
    mla = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv, r = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_head_dim, mla.kv_lora_rank
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    return {
        "w_q_mla": (jax.random.normal(ks[0], (d, h * (dn + dr)), jnp.float32)
                    * std).astype(dtype),
        "w_dkv": (jax.random.normal(ks[1], (d, r + dr), jnp.float32) * std
                  ).astype(dtype),
        "kv_norm": {"scale": jnp.ones((r,), dtype)},
        "w_uk": (jax.random.normal(ks[2], (r, h, dn), jnp.float32)
                 * (1.0 / math.sqrt(r))).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (r, h, dv), jnp.float32)
                 * (1.0 / math.sqrt(r))).astype(dtype),
        "w_o_mla": (jax.random.normal(ks[4], (h * dv, d), jnp.float32)
                    * (1.0 / math.sqrt(h * dv))).astype(dtype),
    }


def _init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    std_in, std_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": (jax.random.normal(ks[0], (d, f), jnp.float32) * std_in
                       ).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (d, f), jnp.float32) * std_in
                     ).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (f, d), jnp.float32) * std_out
                       ).astype(dtype),
        }
    return {  # plain 2-matrix MLP (starcoder2 / whisper)
        "w_in": (jax.random.normal(ks[0], (d, f), jnp.float32) * std_in
                 ).astype(dtype),
        "b_in": jnp.zeros((f,), dtype),
        "w_out": (jax.random.normal(ks[1], (f, d), jnp.float32) * std_out
                  ).astype(dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def _init_layer(key, cfg: ModelConfig, dtype, *, kind: str):
    """kind: attn_mlp | mla_moe | attn_moe | mamba | rec_mlp | attn_mlp_local
    | enc_layer | dec_layer | attn_dense_prefix | mla_dense_prefix"""
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": _norm_params(cfg, d, dtype),
                         "ln2": _norm_params(cfg, d, dtype)}
    if cfg.post_norms:
        p["ln1_post"] = _norm_params(cfg, d, dtype)
        p["ln2_post"] = _norm_params(cfg, d, dtype)

    if kind in ("attn_mlp", "attn_moe", "attn_dense_prefix", "enc_layer"):
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    elif kind in ("mla_moe", "mla_dense_prefix"):
        p["attn"] = _init_mla(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba2_params(ks[0], d, cfg.ssm, dtype)
        del p["ln2"]
        return p
    elif kind == "rec_mlp":
        p["rec"] = ssm_mod.init_rglru_params(ks[0], d, cfg.rglru, dtype)
    elif kind == "dec_layer":
        p["attn"] = _init_attn(ks[0], cfg, dtype)
        p["cross"] = _init_attn(ks[1], cfg, dtype)
        p["ln_cross"] = _norm_params(cfg, d, dtype)
    else:
        raise ValueError(kind)

    if kind in ("attn_moe", "mla_moe"):
        p["moe"] = init_moe_params(ks[2], d, cfg.moe, dtype)
    else:
        p["mlp"] = _init_mlp(ks[3], cfg, dtype)
    return p


def _stack_init(key, cfg, dtype, kind, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, dtype, kind=kind))(keys)


def init_params(cfg: ModelConfig, key) -> Dict:
    """Full parameter pytree.  Run under jax.eval_shape for the dry-run."""
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    pv = padded_vocab(cfg)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (pv, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": _norm_params(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = (
            jax.random.normal(ks[1], (cfg.d_model, pv), jnp.float32)
            * 0.02
        ).astype(dtype)

    if cfg.ssm is not None:  # mamba2
        params["stack"] = _stack_init(ks[2], cfg, dtype, "mamba", cfg.num_layers)
        return params

    if cfg.rglru is not None:  # griffin / recurrentgemma
        n_groups, tail = _griffin_layout(cfg)
        params["rec_stack"] = jax.vmap(
            lambda k: _stack_init(k, cfg, dtype, "rec_mlp", 2)
        )(jax.random.split(ks[2], n_groups))
        params["attn_stack"] = _stack_init(ks[3], cfg, dtype, "attn_mlp", n_groups)
        if tail:
            params["rec_tail"] = _stack_init(ks[4], cfg, dtype, "rec_mlp", tail)
        return params

    if cfg.encoder_layers:  # whisper
        params["enc_stack"] = _stack_init(ks[2], cfg, dtype, "enc_layer",
                                          cfg.encoder_layers)
        params["enc_final_norm"] = _norm_params(cfg, cfg.d_model, dtype)
        params["stack"] = _stack_init(ks[3], cfg, dtype, "dec_layer",
                                      cfg.num_layers)
        return params

    if cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        kind = "mla_moe" if cfg.mla else "attn_moe"
        pkind = "mla_dense_prefix" if cfg.mla else "attn_dense_prefix"
        if nd:
            params["prefix"] = [
                _init_layer(k, cfg, dtype, kind=pkind)
                for k in jax.random.split(ks[2], nd)
            ]
        params["stack"] = _stack_init(ks[3], cfg, dtype, kind,
                                      cfg.num_layers - nd)
        return params

    # dense (possibly with a local/global pattern)
    pat = len(cfg.local_global_pattern) if cfg.local_global_pattern else 1
    assert cfg.num_layers % pat == 0
    if pat == 1:
        params["stack"] = _stack_init(ks[2], cfg, dtype, "attn_mlp",
                                      cfg.num_layers)
    else:
        params["stack"] = jax.vmap(
            lambda k: _stack_init(k, cfg, dtype, "attn_mlp", pat)
        )(jax.random.split(ks[2], cfg.num_layers // pat))
    return params


def _griffin_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(full rec-rec-attn groups, trailing rec layers)."""
    every = cfg.rglru.attention_every
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    assert every == 3, "griffin layout assumes (rec, rec, attn)"
    return n_groups, tail


def init_hotness_state(cfg: ModelConfig) -> Optional[jnp.ndarray]:
    if cfg.moe is None:
        return None
    n_moe = cfg.num_layers - cfg.moe.first_dense_layers
    return jnp.zeros((n_moe, cfg.moe.num_experts), jnp.float32)


def num_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ===========================================================================
# Scan helper (cost_exact mode unrolls so HloCostAnalysis sees every layer)
# ===========================================================================


def _scan(body, carry, xs, *, unroll: bool):
    """lax.scan, or an unrolled python loop with identical semantics."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    ys_stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return carry, ys_stacked


# ===========================================================================
# Layer bodies
# ===========================================================================


def _attn_block(p, h, cfg: ModelConfig, *, positions, window, causal=True,
                rope=True):
    """Full-sequence attention sub-block.  Returns (out, (k_rot, v))."""
    b, s, d = h.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if rope:
        if cfg.rope_kind == "mrope":
            q, k = apply_mrope(q, k, positions, cfg.mrope_sections,
                               theta=cfg.rope_theta)
        elif cfg.rope_kind == "rope":
            q, k = apply_rope(q, k, positions[0] if positions.ndim == 3
                              else positions, theta=cfg.rope_theta)
    kv_cache = (k, v)  # caches keep the unrepeated kv heads

    rules = current_rules()
    heads_mode = rules is not None and rules.heads_shardable(hq)
    if heads_mode:
        # head-parallel attention: repeat kv to hq so the head dim shards
        # evenly over tp (the (hkv, rep) split would not)
        rep = hq // hkv
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        q = shard(q, "dp", None, "tp", None)
        k = shard(k, "dp", None, "tp", None)
        v = shard(v, "dp", None, "tp", None)
    else:
        # sequence-parallel attention (heads don't divide tp): shard the
        # query sequence; kv stays whole per dp row (cheap all-gather)
        q = shard(q, "dp", "tp", None, None)
        k = shard(k, "dp", None, None, None)
        v = shard(v, "dp", None, None, None)
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
        scale=cfg.query_scale,
        block_k=(k.shape[1] if cfg.cost_exact else 1024),
        remat_blocks=not cfg.cost_exact,
    )
    if heads_mode:
        out = shard(out, "dp", None, "tp", None)
    else:
        out = shard(out, "dp", "tp", None, None)
    out = out.reshape(b, s, hq * dh) @ p["wo"]
    return out.astype(h.dtype), kv_cache


def _cross_attn_block(p, h, enc_kv, cfg: ModelConfig):
    """Decoder→encoder cross attention (whisper).  enc_kv = (k, v)."""
    b, s, d = h.shape
    hq, dh = cfg.num_heads, cfg.head_dim
    q = h @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, hq, dh)
    k, v = enc_kv
    out = flash_attention(q, k, v, causal=False, window=None,
                          block_k=min(512, k.shape[1]))
    out = out.reshape(b, s, hq * dh) @ p["wo"]
    return out.astype(h.dtype)


def _cross_kv(p, enc_h, cfg: ModelConfig):
    b, se, _ = enc_h.shape
    hq, dh = cfg.num_heads, cfg.head_dim
    k = enc_h @ p["wk"]
    v = enc_h @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k.reshape(b, se, hq, dh), v.reshape(b, se, hq, dh)


def _mla_block(p, h, cfg: ModelConfig, *, positions):
    """DeepSeek-V2 MLA, expanded (train/prefill) form.

    Returns (out, (c_kv, k_rope)) — the compressed decode cache entries.
    """
    mla = cfg.mla
    b, s, d = h.shape
    hq = cfg.num_heads
    dn, dr, dv, r = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_head_dim, mla.kv_lora_rank

    q = (h @ p["w_q_mla"]).reshape(b, s, hq, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    dkv = h @ p["w_dkv"]
    c_kv = apply_norm(dkv[..., :r], p["kv_norm"], "rmsnorm", cfg.norm_eps)
    k_rope = dkv[..., r:].reshape(b, s, 1, dr)

    q_rope, k_rope = apply_rope(
        q_rope, k_rope, positions, theta=cfg.rope_theta
    )
    k_nope, v = mla_expand(c_kv, p["w_uk"], p["w_uv"])
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, hq, dr))],
                        axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    rules = current_rules()
    if rules is not None and rules.heads_shardable(hq):
        qfull = shard(qfull, "dp", None, "tp", None)
        k = shard(k, "dp", None, "tp", None)
        v = shard(v, "dp", None, "tp", None)
    else:
        qfull = shard(qfull, "dp", "tp", None, None)
    out = flash_attention(qfull, k, v, causal=True,
                          scale=1.0 / math.sqrt(dn + dr),
                          block_k=(k.shape[1] if cfg.cost_exact else 1024),
                          remat_blocks=not cfg.cost_exact)
    out = out.reshape(b, s, hq * dv) @ p["w_o_mla"]
    return out.astype(h.dtype), (c_kv, k_rope[:, :, 0, :])


def _mlp_block(p, h, cfg: ModelConfig):
    from .common import activation_fn

    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = activation_fn("silu" if cfg.mlp_kind == "swiglu" else "gelu_tanh")
        gate = act(h @ p["w_gate"])
        up = h @ p["w_up"]
        return ((gate * up) @ p["w_down"]).astype(h.dtype)
    act = activation_fn(cfg.activation)
    return (act(h @ p["w_in"] + p["b_in"]) @ p["w_out"] + p["b_out"]).astype(h.dtype)


def _residual(cfg, p, name, h, out):
    """residual add, with gemma2-style post-norm sandwich if configured."""
    out = shard_seq(out)  # partial sums lower to reduce-scatter (§Perf)
    if cfg.post_norms:
        out = _norm_apply(cfg, p.get(f"{name}_post"), out)
    return h + out


# ===========================================================================
# Full-sequence stacks (train / prefill)
# ===========================================================================


def _layer_fwd(p, h, cfg: ModelConfig, *, positions, window, hot_row,
               mode: str, enc_h=None):
    """One decoder layer, full sequence.  Returns (h, cache_entry, new_hot, aux, metrics)."""
    cache_entry = None
    aux = jnp.float32(0.0)
    new_hot = hot_row
    metrics = {}

    if "mamba" in p:
        if mode == "prefill":
            raise AssertionError("handled by _mamba_layer_fwd")
        out = ssm_mod.mamba2_block(p["mamba"], _norm_apply(cfg, p["ln1"], h),
                                   cfg.ssm,
                                   impl="ref" if cfg.cost_exact else None)
        h = shard(h + out, "dp", "tp", None)
        return h, cache_entry, new_hot, aux, metrics

    if "rec" in p:
        out = ssm_mod.rglru_block(p["rec"], _norm_apply(cfg, p["ln1"], h),
                                  cfg.rglru)
        h = _residual(cfg, p, "ln1", h, out)
    elif "cross" in p:  # whisper decoder layer
        out, kv = _attn_block(p["attn"], _norm_apply(cfg, p["ln1"], h), cfg,
                              positions=positions, window=None, rope=False)
        cache_entry = kv
        h = h + out
        ck, cv = _cross_kv(p["cross"], enc_h, cfg)
        out = _cross_attn_block(p["cross"], _norm_apply(cfg, p["ln_cross"], h),
                                (ck, cv), cfg)
        h = h + out
        if mode == "prefill":
            cache_entry = (cache_entry, (ck, cv))
    elif cfg.mla is not None and "w_q_mla" in p.get("attn", {}):
        out, kv = _mla_block(p["attn"], _norm_apply(cfg, p["ln1"], h), cfg,
                             positions=positions)
        cache_entry = kv
        h = _residual(cfg, p, "ln1", h, out)
    else:
        causal = not (cfg.encoder_layers and enc_h is None and mode == "encode")
        out, kv = _attn_block(
            p["attn"], _norm_apply(cfg, p["ln1"], h), cfg,
            positions=positions, window=window,
            causal=(mode != "encode"),
        )
        cache_entry = kv
        h = _residual(cfg, p, "ln1", h, out)

    # FFN half
    hin = _norm_apply(cfg, p["ln2"], h)
    if "moe" in p:
        t = hin.shape[0] * hin.shape[1]
        if hot_row is None:  # prefill/serving: stateless routing
            hot_row = jnp.zeros((cfg.moe.num_experts,), jnp.float32)
        y, new_hot, aux, metrics = moe_ffn(
            p["moe"], hin.reshape(t, -1), cfg.moe, hot_row
        )
        out = y.reshape(hin.shape)
    else:
        out = _mlp_block(p["mlp"], hin, cfg)
    h = _residual(cfg, p, "ln2", h, out)
    h = shard(h, "dp", "tp", None)  # sequence-parallel residual stream
    return h, cache_entry, new_hot, aux, metrics


def _stack_scan(stack_params, h, cfg: ModelConfig, *, positions, mode,
                hotness=None, enc_h=None):
    """Scan over a uniform (or pattern-grouped) layer stack.

    Returns (h, caches, new_hotness, total_aux).
    """
    pat = cfg.local_global_pattern
    pat_n = len(pat) if pat else 1
    windows = [
        (cfg.sliding_window if (pat and pat[i] == "local") else None)
        for i in range(pat_n)
    ]

    has_hot = hotness is not None

    def body(carry, xs):
        h, aux_sum = carry
        p_group, hot_rows = xs
        caches, new_rows = [], []
        aux_total = jnp.float32(0.0)
        for i in range(pat_n):
            p_i = jax.tree_util.tree_map(lambda x: x[i], p_group) if pat_n > 1 else p_group
            hot_i = (hot_rows if pat_n == 1 else hot_rows[i]) if has_hot else None
            h, ce, nh, aux, _ = _layer_fwd(
                p_i, h, cfg, positions=positions, window=windows[i],
                hot_row=hot_i, mode=mode, enc_h=enc_h,
            )
            caches.append(ce)
            new_rows.append(nh)
            aux_total += aux
        caches = caches[0] if pat_n == 1 else jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *caches
        )
        if has_hot:
            new_rows = new_rows[0] if pat_n == 1 else jnp.stack(new_rows)
        else:
            new_rows = None
        return (h, aux_sum + aux_total), (caches, new_rows)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    hot_xs = hotness  # (L, E) or (L//pat, pat, E) or None
    if hotness is not None and pat_n > 1:
        hot_xs = hotness.reshape(-1, pat_n, hotness.shape[-1])
    n_steps = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
    if hot_xs is None:
        hot_xs = jnp.zeros((n_steps, 0), jnp.float32)  # dummy scan input

    (h, aux), (caches, new_hot) = _scan(
        body, (h, jnp.float32(0.0)), (stack_params, hot_xs),
        unroll=cfg.cost_exact,
    )
    if hotness is not None and pat_n > 1 and new_hot is not None:
        new_hot = new_hot.reshape(-1, hotness.shape[-1])
    return h, caches, (new_hot if hotness is not None else None), aux


# ===========================================================================
# Griffin (recurrentgemma) stack: (rec, rec, attn) groups + rec tail
# ===========================================================================


def _griffin_scan(params, h, cfg: ModelConfig, *, positions, mode):
    window = cfg.rglru.local_window

    def body(carry, xs):
        h, aux = carry
        rec_pair, attn_p = xs
        caches = []
        for i in range(2):
            p_i = jax.tree_util.tree_map(lambda x: x[i], rec_pair)
            h, ce, _, _, _ = _layer_fwd(p_i, h, cfg, positions=positions,
                                        window=None, hot_row=None, mode=mode)
        h, ce, _, _, _ = _layer_fwd(attn_p, h, cfg, positions=positions,
                                    window=window, hot_row=None, mode=mode)
        return (h, aux), ce

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), attn_caches = _scan(
        body, (h, jnp.float32(0.0)),
        (params["rec_stack"], params["attn_stack"]), unroll=cfg.cost_exact,
    )
    if "rec_tail" in params:
        for i in range(jax.tree_util.tree_leaves(params["rec_tail"])[0].shape[0]):
            p_i = jax.tree_util.tree_map(lambda x: x[i], params["rec_tail"])
            h, _, _, _, _ = _layer_fwd(p_i, h, cfg, positions=positions,
                                       window=None, hot_row=None, mode=mode)
    return h, attn_caches, None, aux


# ===========================================================================
# Embedding / head / loss
# ===========================================================================


def _embed(params, batch, cfg: ModelConfig):
    if cfg.embeds_input:
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.scale_embeddings:
        h = h * math.sqrt(cfg.d_model)
    return shard(h, "dp", "tp", None)  # sequence-parallel residual stream


def _head_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def _masked_logits(h_last, params, cfg: ModelConfig):
    logits = (h_last @ _head_matrix(params, cfg)).astype(jnp.float32)
    logits = soft_cap(logits, cfg.logit_softcap)
    pv = padded_vocab(cfg)
    if pv != cfg.vocab_size:
        logits = jnp.where(jnp.arange(pv) >= cfg.vocab_size, -1e30, logits)
    return logits


def _lm_loss(params, h, labels, cfg: ModelConfig, *, loss_chunks: int = 8):
    """Chunked cross-entropy (keeps the (B,S,V) logits off HBM)."""
    b, s, d = h.shape
    head = _head_matrix(params, cfg)
    if cfg.cost_exact:
        loss_chunks = 1
    chunks = loss_chunks if s % loss_chunks == 0 and s >= loss_chunks else 1
    hc = h.reshape(b, chunks, s // chunks, d)
    lc = labels.reshape(b, chunks, s // chunks)

    pv = padded_vocab(cfg)
    pad_mask = jnp.arange(pv) >= cfg.vocab_size  # (PV,)

    def chunk_loss(carry, xs):
        hx, lx = xs  # (B, Sc, D), (B, Sc)
        logits = (hx @ head).astype(jnp.float32)
        logits = soft_cap(logits, cfg.logit_softcap)
        logits = jnp.where(pad_mask, -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(lx, 0), pv,
                                dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", onehot, logits)
        mask = (lx >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        loss_sum, count = carry
        return (loss_sum + jnp.sum(nll), count + jnp.sum(mask)), None

    if not cfg.cost_exact:
        chunk_loss = jax.checkpoint(chunk_loss, prevent_cse=False)
    (loss_sum, count), _ = _scan(
        chunk_loss, (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)),
        unroll=cfg.cost_exact,
    )
    return loss_sum / jnp.maximum(count, 1.0)


# ===========================================================================
# Public entry points
# ===========================================================================


def _positions_from(batch, cfg: ModelConfig, seq: int):
    if cfg.rope_kind == "mrope":
        return batch["positions"]  # (3, B, S)
    b = (batch["tokens"].shape[0] if "tokens" in batch
         else batch["embeds"].shape[0])
    return jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))


def _run_stack(params, h, cfg, *, positions, mode, hotness, enc_h=None):
    aux_total = jnp.float32(0.0)
    caches_prefix = []
    if "prefix" in params:
        for p in params["prefix"]:
            h, ce, _, aux, _ = _layer_fwd(p, h, cfg, positions=positions,
                                          window=None, hot_row=None, mode=mode)
            caches_prefix.append(ce)
            aux_total += aux
    if cfg.rglru is not None:
        h, caches, new_hot, aux = _griffin_scan(params, h, cfg,
                                                positions=positions, mode=mode)
    else:
        h, caches, new_hot, aux = _stack_scan(
            params["stack"], h, cfg, positions=positions, mode=mode,
            hotness=hotness, enc_h=enc_h,
        )
    return h, (caches_prefix, caches), new_hot, aux_total + aux


def forward_train(params, batch, cfg: ModelConfig, hotness=None):
    """Returns (loss, dict(new_hotness=..., metrics...))."""
    seq = (batch["tokens"].shape[1] if "tokens" in batch
           else batch["embeds"].shape[1])
    positions = _positions_from(batch, cfg, seq)
    h = _embed(params, batch, cfg)

    enc_h = None
    if cfg.encoder_layers:
        enc_h = _encode(params, batch, cfg)

    h, _, new_hot, aux = _run_stack(params, h, cfg, positions=positions,
                                    mode="train", hotness=hotness, enc_h=enc_h)
    h = _norm_apply(cfg, params["final_norm"], h)
    loss = _lm_loss(params, h, batch["labels"], cfg)
    total = loss + aux
    return total, {"ce_loss": loss, "aux_loss": aux, "new_hotness": new_hot}


def _encode(params, batch, cfg: ModelConfig):
    """Whisper encoder over stubbed (pre-conv) frame embeddings."""
    enc_h = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
    se = enc_h.shape[1]
    pos = jnp.broadcast_to(jnp.arange(se)[None], (enc_h.shape[0], se))

    def body(h, p):
        out, _ = _attn_block(p["attn"], _norm_apply(cfg, p["ln1"], h), cfg,
                             positions=pos, window=None, causal=False,
                             rope=False)
        h = h + out
        out = _mlp_block(p["mlp"], _norm_apply(cfg, p["ln2"], h), cfg)
        return h + out, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    enc_h, _ = _scan(lambda c, p: body(c, p), enc_h,
                     params["enc_stack"], unroll=cfg.cost_exact)
    return _norm_apply(cfg, params["enc_final_norm"], enc_h)


# ---------------------------------------------------------------------------
# Prefill & decode
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence pass building the decode cache.

    Returns (cache dict, last-token logits (B, V)).
    """
    seq = (batch["tokens"].shape[1] if "tokens" in batch
           else batch["embeds"].shape[1])
    positions = _positions_from(batch, cfg, seq)
    h = _embed(params, batch, cfg)

    enc_h = _encode(params, batch, cfg) if cfg.encoder_layers else None

    if cfg.ssm is not None:
        return _mamba_prefill(params, h, cfg)
    if cfg.rglru is not None:
        return _griffin_prefill(params, h, cfg, positions)

    h, (pre, caches), _, _ = _run_stack(params, h, cfg, positions=positions,
                                        mode="prefill", hotness=None,
                                        enc_h=enc_h)
    h = _norm_apply(cfg, params["final_norm"], h)
    logits = _masked_logits(h[:, -1], params, cfg)
    cache = {"pos": jnp.int32(seq - 1), "layers": caches}
    if pre:
        cache["prefix"] = pre
    return cache, logits


def _mamba_prefill(params, h, cfg: ModelConfig):
    # run layer-by-layer via scan, capturing final ssm/conv states
    def body(h, p):
        hin = _norm_apply(cfg, p["ln1"], h)
        b, s, d = hin.shape
        ssm = cfg.ssm
        z, xbc, dt, d_inner, n_heads = ssm_mod._mamba2_preproc(p["mamba"], hin, ssm)
        xbc_c = ssm_mod._causal_conv(xbc, p["mamba"]["conv_w"], p["mamba"]["conv_b"])
        gn = ssm.n_groups * ssm.d_state
        xs, bm, cm = jnp.split(xbc_c, [d_inner, d_inner + gn], axis=-1)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["mamba"]["dt_bias"])
        a = -jnp.exp(p["mamba"]["a_log"])
        xh = xs.reshape(b, s, n_heads, ssm.head_dim)
        from ..kernels import ops as kops
        y, final = kops.ssd_scan(
            xh.astype(jnp.float32) * dtp[..., None], a * dtp,
            bm.reshape(b, s, ssm.n_groups, ssm.d_state),
            cm.reshape(b, s, ssm.n_groups, ssm.d_state), chunk=ssm.chunk,
            impl="ref" if cfg.cost_exact else None,
        )
        y = y + p["mamba"]["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, s, d_inner)
        from .common import rms_norm
        y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                     p["mamba"]["norm_scale"])
        out = (y.astype(h.dtype) @ p["mamba"]["out_proj"]).astype(h.dtype)
        conv_state = xbc[:, -(ssm.d_conv - 1):, :]
        return h + out, {"conv": conv_state, "ssm": final}

    h, states = _scan(body, h, params["stack"], unroll=cfg.cost_exact)
    h = _norm_apply(cfg, params["final_norm"], h)
    logits = _masked_logits(h[:, -1], params, cfg)
    seq = h.shape[1]
    return {"pos": jnp.int32(seq - 1), "layers": states}, logits


def _griffin_prefill(params, h, cfg: ModelConfig, positions):
    window = cfg.rglru.local_window
    rg = cfg.rglru

    def rec_apply(p, h):
        hin = _norm_apply(cfg, p["ln1"], h)
        gate = jax.nn.gelu(hin @ p["rec"]["w_gate"])
        xr = hin @ p["rec"]["w_x"]
        xc = ssm_mod._rglru_conv(xr, p["rec"])
        a, bvec = ssm_mod._rglru_gates(p["rec"], xc)
        hh = ssm_mod._lru_scan(a, bvec)
        y = hh.astype(h.dtype) * gate
        out = (y @ p["rec"]["w_out"]).astype(h.dtype)
        h = _residual(cfg, p, "ln1", h, out)
        out = _mlp_block(p["mlp"], _norm_apply(cfg, p["ln2"], h), cfg)
        h = _residual(cfg, p, "ln2", h, out)
        state = {"conv": xr[:, -(rg.conv_width - 1):, :].astype(jnp.float32),
                 "h": hh[:, -1]}
        return h, state

    def body(h, xs):
        rec_pair, attn_p = xs
        sts = []
        for i in range(2):
            p_i = jax.tree_util.tree_map(lambda x: x[i], rec_pair)
            h, st = rec_apply(p_i, h)
            sts.append(st)
        h, kv, _, _, _ = _layer_fwd(attn_p, h, cfg, positions=positions,
                                    window=window, hot_row=None, mode="prefill")
        sts = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sts)
        return h, (sts, kv)

    h, (rec_states, attn_kv) = _scan(
        body, h, (params["rec_stack"], params["attn_stack"]),
        unroll=cfg.cost_exact,
    )
    tail_states = []
    if "rec_tail" in params:
        for i in range(jax.tree_util.tree_leaves(params["rec_tail"])[0].shape[0]):
            p_i = jax.tree_util.tree_map(lambda x: x[i], params["rec_tail"])
            h, st = rec_apply(p_i, h)
            tail_states.append(st)
    h = _norm_apply(cfg, params["final_norm"], h)
    logits = _masked_logits(h[:, -1], params, cfg)
    seq = positions.shape[-1]
    # clip attention kv caches to the local window
    k, v = attn_kv
    if k.shape[2] > window:
        k, v = k[:, :, -window:], v[:, :, -window:]
    cache = {"pos": jnp.int32(seq - 1), "rec": rec_states,
             "attn": (k, v), "tail": tail_states}
    return cache, logits


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    """Zero-initialised decode cache (for decode-only dry-runs)."""
    dtype = jnp.dtype(cfg.dtype)
    hkv, dh, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    if cfg.ssm is not None:
        d_inner, n_heads, conv_dim, _ = ssm_mod._mamba2_dims(cfg.d_model, cfg.ssm)
        return {
            "pos": jnp.int32(0),
            "layers": {
                "conv": jnp.zeros((L, batch, cfg.ssm.d_conv - 1, conv_dim), dtype),
                "ssm": jnp.zeros((L, batch, n_heads, cfg.ssm.d_state,
                                  cfg.ssm.head_dim), jnp.float32),
            },
        }
    if cfg.rglru is not None:
        n_groups, tail = _griffin_layout(cfg)
        width = cfg.rglru.lru_width or cfg.d_model
        w = min(max_seq, cfg.rglru.local_window)
        return {
            "pos": jnp.int32(0),
            "rec": {
                "conv": jnp.zeros((n_groups, 2, batch, cfg.rglru.conv_width - 1,
                                   width), jnp.float32),
                "h": jnp.zeros((n_groups, 2, batch, width), jnp.float32),
            },
            "attn": (
                jnp.zeros((n_groups, batch, w, hkv, dh), dtype),
                jnp.zeros((n_groups, batch, w, hkv, dh), dtype),
            ),
            "tail": [
                {"conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, width),
                                   jnp.float32),
                 "h": jnp.zeros((batch, width), jnp.float32)}
                for _ in range(tail)
            ],
        }
    if cfg.mla is not None:
        r, dr = cfg.mla.kv_lora_rank, cfg.mla.qk_rope_dim
        nd = cfg.moe.first_dense_layers if cfg.moe else 0
        cache = {
            "pos": jnp.int32(0),
            "layers": (
                jnp.zeros((L - nd, batch, max_seq, r), dtype),
                jnp.zeros((L - nd, batch, max_seq, dr), dtype),
            ),
        }
        if nd:
            cache["prefix"] = [
                (jnp.zeros((batch, max_seq, r), dtype),
                 jnp.zeros((batch, max_seq, dr), dtype))
                for _ in range(nd)
            ]
        return cache
    if cfg.encoder_layers:
        return {
            "pos": jnp.int32(0),
            "layers": (
                (jnp.zeros((L, batch, max_seq, hkv, dh), dtype),
                 jnp.zeros((L, batch, max_seq, hkv, dh), dtype)),
                (jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_heads, dh), dtype),
                 jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_heads, dh), dtype)),
            ),
        }
    nd = cfg.moe.first_dense_layers if cfg.moe else 0
    pat = len(cfg.local_global_pattern) if cfg.local_global_pattern else 1
    ls = L - nd
    shape = ((ls // pat, pat, batch, max_seq, hkv, dh) if pat > 1
             else (ls, batch, max_seq, hkv, dh))
    cache = {
        "pos": jnp.int32(0),
        "layers": (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)),
    }
    if nd:
        cache["prefix"] = [
            (jnp.zeros((batch, max_seq, hkv, dh), dtype),
             jnp.zeros((batch, max_seq, hkv, dh), dtype))
            for _ in range(nd)
        ]
    return cache


# --- decode layer bodies ----------------------------------------------------


def _mla_decode(p, h, cache, pos, cfg: ModelConfig):
    mla = cfg.mla
    b = h.shape[0]
    hq = cfg.num_heads
    dn, dr, dv, r = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_head_dim, mla.kv_lora_rank
    q = (h @ p["w_q_mla"]).reshape(b, 1, hq, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    dkv = h @ p["w_dkv"]
    c_kv = apply_norm(dkv[..., :r], p["kv_norm"], "rmsnorm", cfg.norm_eps)
    k_rope = dkv[..., r:].reshape(b, 1, 1, dr)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_rope, k_rope = apply_rope(q_rope, k_rope, posv, theta=cfg.rope_theta)

    ckv_c, krope_c = cache
    ckv_c = jax.lax.dynamic_update_slice(
        ckv_c, c_kv.astype(ckv_c.dtype), (0, pos, 0))
    krope_c = jax.lax.dynamic_update_slice(
        krope_c, k_rope[:, :, 0, :].astype(krope_c.dtype), (0, pos, 0))
    ctx = mla_decode_scores(
        q_nope[:, 0], q_rope[:, 0], ckv_c, krope_c, p["w_uk"], p["w_uv"],
        cur_pos=pos, scale=1.0 / math.sqrt(dn + dr),
    )
    out = ctx.reshape(b, 1, hq * dv) @ p["w_o_mla"]
    return out.astype(h.dtype), (ckv_c, krope_c)


def decode_step(params, cache: Dict, tokens, cfg: ModelConfig,
                embeds=None):
    """One decode step.  tokens: (B, 1) int32 (or embeds (B, 1, D)).

    Returns (logits (B, V) f32, new cache).
    """
    pos = cache["pos"] + 1
    if cfg.embeds_input and embeds is not None:
        h = embeds.astype(jnp.dtype(cfg.dtype))
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        h = h * math.sqrt(cfg.d_model)

    if cfg.ssm is not None:
        h, layers = _mamba_decode_stack(params, h, cache["layers"], cfg)
        new_cache = {"pos": pos, "layers": layers}
    elif cfg.rglru is not None:
        h, new_cache = _griffin_decode_stack(params, h, cache, cfg, pos)
    else:
        h, new_cache = _attn_decode_stack(params, h, cache, cfg, pos)

    h = _norm_apply(cfg, params["final_norm"], h)
    logits = _masked_logits(h[:, 0], params, cfg)
    return logits, new_cache


def _mamba_decode_stack(params, h, states, cfg):
    def body(h, xs):
        p, st = xs
        hin = _norm_apply(cfg, p["ln1"], h)
        out, new_st = ssm_mod.mamba2_decode(p["mamba"], hin, st, cfg.ssm)
        return h + out, new_st

    h, new_states = _scan(body, h, (params["stack"], states),
                          unroll=cfg.cost_exact)
    return h, new_states


def _griffin_decode_stack(params, h, cache, cfg, pos):
    rg = cfg.rglru
    window = cache["attn"][0].shape[2]

    def rec_apply(p, h, st):
        hin = _norm_apply(cfg, p["ln1"], h)
        out, new_st = ssm_mod.rglru_decode(p["rec"], hin, st, rg)
        h = _residual(cfg, p, "ln1", h, out)
        out = _mlp_block(p["mlp"], _norm_apply(cfg, p["ln2"], h), cfg)
        return _residual(cfg, p, "ln2", h, out), new_st

    def body(h, xs):
        rec_pair, attn_p, rec_st, attn_kv = xs
        new_rec = []
        for i in range(2):
            p_i = jax.tree_util.tree_map(lambda x: x[i], rec_pair)
            s_i = jax.tree_util.tree_map(lambda x: x[i], rec_st)
            h, st = rec_apply(p_i, h, s_i)
            new_rec.append(st)
        hin = _norm_apply(cfg, attn_p["ln1"], h)
        out, new_kv = _attn_decode_ring(attn_p["attn"], hin, attn_kv, pos, cfg)
        h = _residual(cfg, attn_p, "ln1", h, out)
        out = _mlp_block(attn_p["mlp"], _norm_apply(cfg, attn_p["ln2"], h), cfg)
        h = _residual(cfg, attn_p, "ln2", h, out)
        new_rec = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_rec)
        return h, (new_rec, new_kv)

    h, (rec_states, attn_kv) = _scan(
        body, h,
        (params["rec_stack"], params["attn_stack"], cache["rec"], cache["attn"]),
        unroll=cfg.cost_exact,
    )
    new_tail = []
    for i, st in enumerate(cache["tail"]):
        p_i = jax.tree_util.tree_map(lambda x: x[i], params["rec_tail"])
        h, nst = rec_apply(p_i, h, st)
        new_tail.append(nst)
    return h, {"pos": pos, "rec": rec_states, "attn": attn_kv, "tail": new_tail}


def _attn_decode_ring(p, h, kv_cache, pos, cfg):
    """Decode against a ring-buffer (window-sized) cache."""
    b = h.shape[0]
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(b, 1, hq, dh)
    k = (h @ p["wk"]).reshape(b, 1, hkv, dh)
    v = (h @ p["wv"]).reshape(b, 1, hkv, dh)
    posv = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope_kind == "rope":
        q, k = apply_rope(q, k, posv, theta=cfg.rope_theta)
    kc, vc = kv_cache
    w = kc.shape[1]
    slot = pos % w
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
    # ring buffer: every slot written so far is within the window
    out = decode_attention(q, kc, vc, cur_pos=jnp.minimum(pos, w - 1),
                           softcap=cfg.attn_softcap, scale=cfg.query_scale)
    out = out.reshape(b, 1, hq * dh) @ p["wo"]
    return out.astype(h.dtype), (kc, vc)


def _attn_decode_stack(params, h, cache, cfg, pos):
    pat = len(cfg.local_global_pattern) if cfg.local_global_pattern else 1
    windows = [
        (cfg.sliding_window if (cfg.local_global_pattern
                                and cfg.local_global_pattern[i] == "local")
         else None)
        for i in range(pat)
    ]
    new_prefix = []
    if "prefix" in cache:
        for p, kv in zip(params["prefix"], cache["prefix"]):
            hin = _norm_apply(cfg, p["ln1"], h)
            if cfg.mla is not None:
                out, nkv = _mla_decode(p["attn"], hin, kv, pos, cfg)
            else:
                out, nkv = _attn_decode_full(p["attn"], hin, kv, pos, cfg,
                                             window=None)
            h = _residual(cfg, p, "ln1", h, out)
            out = _mlp_block(p["mlp"], _norm_apply(cfg, p["ln2"], h), cfg)
            h = _residual(cfg, p, "ln2", h, out)
            new_prefix.append(nkv)

    is_whisper = bool(cfg.encoder_layers)

    def body(h, xs):
        p_group, kv_group = xs
        new_kvs = []
        for i in range(pat):
            p_i = (jax.tree_util.tree_map(lambda x: x[i], p_group)
                   if pat > 1 else p_group)
            kv_i = (jax.tree_util.tree_map(lambda x: x[i], kv_group)
                    if pat > 1 else kv_group)
            hin = _norm_apply(cfg, p_i["ln1"], h)
            if is_whisper:
                self_kv, cross_kv = kv_i
                out, nkv = _attn_decode_full(p_i["attn"], hin, self_kv, pos,
                                             cfg, window=None)
                h = h + out
                hin2 = _norm_apply(cfg, p_i["ln_cross"], h)
                q = (hin2 @ p_i["cross"]["wq"]).reshape(
                    h.shape[0], 1, cfg.num_heads, cfg.head_dim)
                if cfg.qkv_bias:
                    q = q + p_i["cross"]["bq"].reshape(1, 1, cfg.num_heads,
                                                       cfg.head_dim)
                ck, cv = cross_kv
                out = decode_attention(q, ck, cv, cur_pos=ck.shape[1] - 1)
                out = out.reshape(h.shape[0], 1, -1) @ p_i["cross"]["wo"]
                h = h + out.astype(h.dtype)
                nkv = (nkv, cross_kv)
            elif cfg.mla is not None:
                out, nkv = _mla_decode(p_i["attn"], hin, kv_i, pos, cfg)
                h = _residual(cfg, p_i, "ln1", h, out)
            else:
                out, nkv = _attn_decode_full(p_i["attn"], hin, kv_i, pos, cfg,
                                             window=windows[i])
                h = _residual(cfg, p_i, "ln1", h, out)
            hin = _norm_apply(cfg, p_i["ln2"], h)
            if "moe" in p_i:
                t = hin.shape[0] * hin.shape[1]
                y, _, _, _ = moe_ffn(p_i["moe"], hin.reshape(t, -1), cfg.moe,
                                     jnp.zeros((cfg.moe.num_experts,),
                                               jnp.float32))
                out = y.reshape(hin.shape)
            else:
                out = _mlp_block(p_i["mlp"], hin, cfg)
            h = _residual(cfg, p_i, "ln2", h, out)
            new_kvs.append(nkv)
        new_kv = (new_kvs[0] if pat == 1
                  else jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                              *new_kvs))
        return h, new_kv

    h, new_layers = _scan(body, h, (params["stack"], cache["layers"]),
                          unroll=cfg.cost_exact)
    new_cache = {"pos": pos, "layers": new_layers}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return h, new_cache


def _attn_decode_full(p, h, kv_cache, pos, cfg, *, window):
    """Decode against a full-length cache (windowing by mask)."""
    b = h.shape[0]
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, hq, dh)
    k = k.reshape(b, 1, hkv, dh)
    v = v.reshape(b, 1, hkv, dh)
    if cfg.rope_kind == "mrope":
        posv = jnp.full((3, b, 1), pos, jnp.int32)
        q, k = apply_mrope(q, k, posv, cfg.mrope_sections, theta=cfg.rope_theta)
    elif cfg.rope_kind == "rope":
        posv = jnp.full((b, 1), pos, jnp.int32)
        q, k = apply_rope(q, k, posv, theta=cfg.rope_theta)
    kc, vc = kv_cache
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
    out = decode_attention(q, kc, vc, cur_pos=pos, window=window,
                           softcap=cfg.attn_softcap, scale=cfg.query_scale)
    out = out.reshape(b, 1, hq * dh) @ p["wo"]
    return out.astype(h.dtype), (kc, vc)
