"""Shared model building blocks: norms, RoPE/M-RoPE, activations, inits.

Everything is functional: params are plain nested dicts of arrays, and every
function takes/returns pytrees so the whole stack works under jit / pjit /
eval_shape (the dry-run never materialises weights).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "nonparametric_layer_norm",
    "apply_norm",
    "soft_cap",
    "rope_freqs",
    "apply_rope",
    "apply_mrope",
    "dense_init",
    "embed_init",
    "activation_fn",
]


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6, *, plus_one: bool = False):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32) if plus_one else scale.astype(jnp.float32))
    return y.astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def nonparametric_layer_norm(x, eps: float = 1e-5):
    """OLMo-style LN without learnable scale/bias (arXiv:2402.00838)."""
    return layer_norm(x, None, None, eps)


def apply_norm(x, params, kind: str, eps: float = 1e-6):
    """Dispatch on the config's norm kind."""
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"], eps)
    if kind == "rmsnorm_plus_one":  # gemma convention: weight stored as (w-1)
        return rms_norm(x, params["scale"], eps, plus_one=True)
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], eps)
    if kind == "nonparametric":
        return nonparametric_layer_norm(x, eps)
    raise ValueError(f"unknown norm kind {kind!r}")


def soft_cap(x, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim/2,)


def _rotate(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, *, theta: float = 10_000.0,
               rotary_dim: Optional[int] = None):
    """Standard RoPE.  q/k: (B, S, H, dh); positions: (B, S) int32."""
    dh = q.shape[-1]
    rd = rotary_dim or dh
    inv = rope_freqs(rd, theta)  # (rd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (B, S, rd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]

    def rot(x):
        if rd == x.shape[-1]:
            return _rotate(x.astype(jnp.float32), sin, cos).astype(x.dtype)
        head, rest = x[..., :rd], x[..., rd:]
        head = _rotate(head.astype(jnp.float32), sin, cos).astype(x.dtype)
        return jnp.concatenate([head, rest], axis=-1)

    return rot(q), rot(k)


def apply_mrope(q, k, positions, sections: Sequence[int], *,
                theta: float = 1_000_000.0):
    """Qwen2-VL multimodal RoPE (arXiv:2409.12191).

    positions: (3, B, S) — temporal/height/width position ids.  The rotary
    spectrum is split into ``sections`` (in half-dim units, e.g. [16, 24, 24]
    for head_dim 128) and each section takes its angle from the matching
    position stream.
    """
    dh = q.shape[-1]
    inv = rope_freqs(dh, theta)  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (3, B, S, dh/2)
    # pick, per frequency slot, which of the 3 position streams drives it
    idx = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=dh // 2)
    angles = jnp.take_along_axis(
        angles, idx[None, None, None, :].astype(jnp.int32), axis=0
    )[0]  # (B, S, dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    qr = _rotate(q.astype(jnp.float32), sin, cos).astype(q.dtype)
    kr = _rotate(k.astype(jnp.float32), sin, cos).astype(k.dtype)
    return qr, kr


# ---------------------------------------------------------------------------
# Activations / init
# ---------------------------------------------------------------------------


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=False),
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def dense_init(key, shape: Tuple[int, ...], in_axis: int = 0,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)
