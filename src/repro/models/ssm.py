"""Sequence-mixing state-space blocks: Mamba-2 (SSD) and RG-LRU (Griffin).

Both expose a train/prefill path (full sequence) and an O(1)-state decode
step — these are the archs that make the ``long_500k`` shape feasible.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import RGLRUConfig, SSMConfig
from ..kernels import ops as kops
from .common import rms_norm
from .sharding import shard

__all__ = [
    "init_mamba2_params",
    "mamba2_block",
    "mamba2_decode",
    "init_mamba2_state",
    "init_rglru_params",
    "rglru_block",
    "rglru_decode",
]


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def _mamba2_dims(d_model: int, ssm: SSMConfig):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.n_groups * ssm.d_state
    d_in_proj = 2 * d_inner + 2 * ssm.n_groups * ssm.d_state + n_heads
    return d_inner, n_heads, conv_dim, d_in_proj


def init_mamba2_params(key, d_model: int, ssm: SSMConfig, dtype=jnp.bfloat16):
    d_inner, n_heads, conv_dim, d_in_proj = _mamba2_dims(d_model, ssm)
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d_model)
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, d_in_proj), jnp.float32)
                    * std).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (ssm.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d_model), jnp.float32)
                     * (1.0 / math.sqrt(d_inner))).astype(dtype),
    }


def _mamba2_preproc(params, x, ssm: SSMConfig):
    """Shared in_proj + split for both train and decode paths."""
    d_model = x.shape[-1]
    d_inner, n_heads, conv_dim, _ = _mamba2_dims(d_model, ssm)
    gn = ssm.n_groups * ssm.d_state
    proj = x @ params["in_proj"]  # (..., d_in_proj)
    if proj.ndim == 3:
        proj = shard(proj, "dp", None, "tp")
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt, d_inner, n_heads


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv1d.  xbc: (B, S, C); conv_w: (K, C)."""
    k = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(k)
    )
    return jax.nn.silu(out + conv_b)


def mamba2_block(params, x, ssm: SSMConfig, impl: str = None):
    """Full-sequence Mamba-2 mixer.  x: (B, S, D) -> (B, S, D)."""
    b, s, d_model = x.shape
    z, xbc, dt, d_inner, n_heads = _mamba2_preproc(params, x, ssm)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    gn = ssm.n_groups * ssm.d_state
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])  # (H,) negative decay rate
    log_decay = a * dt  # (B,S,H)

    xh = xs.reshape(b, s, n_heads, ssm.head_dim)
    xh = shard(xh, "dp", None, "tp", None)
    x_scaled = xh.astype(jnp.float32) * dt[..., None]
    bm = bmat.reshape(b, s, ssm.n_groups, ssm.d_state)
    cm = cmat.reshape(b, s, ssm.n_groups, ssm.d_state)

    y, _ = kops.ssd_scan(x_scaled, log_decay, bm, cm, chunk=ssm.chunk,
                         impl=impl)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), params["norm_scale"])
    return (y.astype(x.dtype) @ params["out_proj"]).astype(x.dtype)


def init_mamba2_state(d_model: int, ssm: SSMConfig, batch: int,
                      dtype=jnp.float32) -> Dict:
    d_inner, n_heads, conv_dim, _ = _mamba2_dims(d_model, ssm)
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, ssm.d_state, ssm.head_dim), dtype),
    }


def mamba2_decode(params, x, state: Dict, ssm: SSMConfig):
    """Single-token recurrent step.  x: (B, 1, D) -> (B, 1, D), new state."""
    b, _, d_model = x.shape
    z, xbc, dt, d_inner, n_heads = _mamba2_preproc(params, x[:, 0], ssm)
    # conv over the window [state.conv | xbc]
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    conv_out = jnp.sum(window * params["conv_w"][None], axis=1) + params["conv_b"]
    xbc_t = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]

    gn = ssm.n_groups * ssm.d_state
    xs, bvec, cvec = jnp.split(xbc_t, [d_inner, d_inner + gn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(a * dt)  # (B,H)

    xh = xs.reshape(b, n_heads, ssm.head_dim).astype(jnp.float32)
    hpg = n_heads // ssm.n_groups
    bh = jnp.repeat(bvec.reshape(b, ssm.n_groups, ssm.d_state), hpg, axis=1)
    ch = jnp.repeat(cvec.reshape(b, ssm.n_groups, ssm.d_state), hpg, axis=1)

    new_ssm = state["ssm"] * decay[..., None, None] + (
        bh[..., :, None] * (xh * dt[..., None])[..., None, :]
    )  # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), new_ssm)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), params["norm_scale"])
    out = (y.astype(x.dtype) @ params["out_proj"])[:, None, :]
    return out.astype(x.dtype), {"conv": new_conv, "ssm": new_ssm}


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_params(key, d_model: int, rg: RGLRUConfig, dtype=jnp.bfloat16):
    width = rg.lru_width or d_model
    nb = rg.gate_blocks
    wb = width // nb  # block-diagonal gates (as in RecurrentGemma)
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d_model)
    stdw = 1.0 / math.sqrt(width)
    # Λ init so that a^c ∈ (0.9, 0.999) roughly (griffin appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, width)) / _RGLRU_C))
    return {
        "w_x": (jax.random.normal(ks[0], (d_model, width), jnp.float32) * std
                ).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (d_model, width), jnp.float32)
                   * std).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (rg.conv_width, width), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_input_gate": (jax.random.normal(ks[3], (nb, wb, wb), jnp.float32)
                         * (1.0 / math.sqrt(wb))).astype(dtype),
        "w_rec_gate": (jax.random.normal(ks[4], (nb, wb, wb), jnp.float32)
                       * (1.0 / math.sqrt(wb))).astype(dtype),
        "lambda": lam.astype(jnp.float32),
        "w_out": (jax.random.normal(ks[5], (width, d_model), jnp.float32)
                  * stdw).astype(dtype),
    }


def _block_diag_apply(xf, w):
    """xf: (..., W); w: (NB, WB, WB) block-diagonal linear."""
    nb, wb = w.shape[0], w.shape[1]
    xb = xf.reshape(xf.shape[:-1] + (nb, wb))
    out = jnp.einsum("...nw,nwv->...nv", xb, w.astype(jnp.float32))
    return out.reshape(xf.shape)


def _rglru_gates(params, xc):
    """Input/recurrence gates + log decay.  xc: (..., W) conv output."""
    xf = xc.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(_block_diag_apply(xf, params["w_input_gate"]))
    r_gate = jax.nn.sigmoid(_block_diag_apply(xf, params["w_rec_gate"]))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lambda"]) * r_gate
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = beta * (i_gate * xf)
    return a, b


def _lru_scan(a, b, chunks: int = 16):
    """Blocked linear scan h_t = a_t h_{t-1} + b_t.

    Chunk-local associative scans (fully local when the sequence is sharded
    into ``chunks`` pieces over tp) + one tiny sequential combine over the
    (B, chunks, W) chunk carries — replaces the global associative scan whose
    log-depth butterflies forced GSPMD to all-gather the full f32 (B, S, W)
    activations (§Perf, recurrentgemma hillclimb).
    """
    bsz, s, w = a.shape
    if s % chunks or s < 2 * chunks:
        def comb0(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        _, h = jax.lax.associative_scan(comb0, (a, b), axis=1)
        return h
    n, l = chunks, s // chunks
    ac = a.reshape(bsz, n, l, w)
    bc = b.reshape(bsz, n, l, w)

    def comb(lft, rgt):
        al, bl = lft
        ar, br = rgt
        return al * ar, ar * bl + br

    a_loc, h_loc = jax.lax.associative_scan(comb, (ac, bc), axis=2)
    a_last, h_last = a_loc[:, :, -1], h_loc[:, :, -1]  # (B, n, W)

    def step(carry, xs):
        ai, hi = xs
        return ai * carry + hi, carry  # emit carry *into* this chunk

    _, carry_in = jax.lax.scan(
        step, jnp.zeros_like(a_last[:, 0]),
        (jnp.moveaxis(a_last, 1, 0), jnp.moveaxis(h_last, 1, 0)))
    carry_in = jnp.moveaxis(carry_in, 0, 1)  # (B, n, W)
    h = h_loc + a_loc * carry_in[:, :, None, :]
    return h.reshape(bsz, s, w)


def rglru_block(params, x, rg: RGLRUConfig):
    """Full-sequence Griffin recurrent block.  x: (B, S, D)."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    gate = shard(gate, "dp", "tp", None)   # stay sequence-sharded
    xr = shard(x @ params["w_x"], "dp", "tp", None)
    xc = _rglru_conv(xr, params)
    a, b = _rglru_gates(params, xc)
    h = _lru_scan(a, b)
    y = h.astype(x.dtype) * gate
    return (y @ params["w_out"]).astype(x.dtype)


def _rglru_conv(xr, params):
    k = params["conv_w"].shape[0]
    pad = jnp.pad(xr, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xr.shape[1], :] * params["conv_w"][i][None, None, :]
        for i in range(k)
    )
    return out + params["conv_b"]


def init_rglru_state(d_model: int, rg: RGLRUConfig, batch: int) -> Dict:
    width = rg.lru_width or d_model
    return {
        "conv": jnp.zeros((batch, rg.conv_width - 1, width), jnp.float32),
        "h": jnp.zeros((batch, width), jnp.float32),
    }


def rglru_decode(params, x, state: Dict, rg: RGLRUConfig):
    """Single-token step.  x: (B, 1, D)."""
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate"])
    xr = x[:, 0] @ params["w_x"]
    window = jnp.concatenate([state["conv"], xr[:, None, :].astype(jnp.float32)],
                             axis=1)
    xc = jnp.sum(window * params["conv_w"][None].astype(jnp.float32), axis=1)
    xc = xc + params["conv_b"].astype(jnp.float32)
    a, b = _rglru_gates(params, xc)
    h = a * state["h"] + b
    y = h.astype(x.dtype) * gate
    out = (y @ params["w_out"])[:, None, :]
    return out.astype(x.dtype), {"conv": window[:, 1:, :], "h": h}
