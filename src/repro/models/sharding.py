"""Partitioning rules: param-tree PartitionSpecs + activation constraints.

The production mesh (launch/mesh.py) has axes ``("pod", "data", "model")``
(multi-pod) or ``("data", "model")`` (single pod).  Logical roles:

* **dp**   = ("pod", "data") — batch / token parallelism (+ ZeRO-1: optimizer
  state and the non-TP weight dim shard here when ``cfg.zero_sharding``),
* **tp**   = "model" — attention heads / FFN hidden / vocab / experts.

Activation constraints are applied through :func:`shard`, which no-ops when
no rules are installed (CPU smoke tests run without a mesh).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["ShardingRules", "set_rules", "current_rules", "shard", "param_specs"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    dp: Tuple[str, ...]          # batch axes, e.g. ("pod", "data")
    tp: str = "model"
    tp_size: int = 16            # size of the tp axis (attention-mode choice)
    zero: bool = True            # shard the non-TP weight dim over dp

    @property
    def fsdp(self):
        return self.dp if self.zero else None

    def heads_shardable(self, num_heads: int) -> bool:
        """True -> head-parallel attention; False -> sequence-parallel."""
        return num_heads % self.tp_size == 0


_ACTIVE: list = []


@contextlib.contextmanager
def set_rules(rules: Optional[ShardingRules]):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> Optional[ShardingRules]:
    return _ACTIVE[-1] if _ACTIVE else None


def shard_seq(x):
    """Constrain a (B, S, D) activation to (dp, tp, None) when the sequence
    divides the tp axis — placed on sub-block *outputs* so XLA lowers the
    partial-sum + reshard as one reduce-scatter instead of all-reduce+slice
    (sequence-parallel Megatron pattern).  No-op otherwise."""
    rules = current_rules()
    if rules is None or x.ndim != 3 or x.shape[1] % max(rules.tp_size, 1):
        return x
    return jax.lax.with_sharding_constraint(x, P(rules.dp, rules.tp, None))


def shard(x, *roles: Optional[str]):
    """Constrain activation sharding by role per axis.

    roles: one of "dp", "tp", None per array dim, e.g.
    ``shard(h, "dp", None, "tp")`` for (batch, seq, heads-sharded).
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = []
    for r in roles:
        if r == "dp":
            spec.append(rules.dp)
        elif r == "tp":
            spec.append(rules.tp)
        elif r is None:
            spec.append(None)
        else:
            raise ValueError(f"unknown sharding role {r!r}")
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Parameter specs (path-name rules)
# ---------------------------------------------------------------------------

def _leaf_spec(path: str, ndim: int, rules: ShardingRules) -> P:
    fsdp, tp = rules.fsdp, rules.tp
    # stacked-layer leading dim (from vmapped init / scan) is never sharded;
    # detect via ndim below per rule.

    def pick(*dims):
        """dims for the *unstacked* leaf; prepend None for the stack dim."""
        stack = ndim - len(dims)
        return P(*([None] * stack + list(dims)))

    # --- embeddings / head ---------------------------------------------------
    if path.endswith("embed"):
        return P(tp, fsdp)  # (V, D): vocab over tp (Megatron-style)
    if path.endswith("head"):
        return P(fsdp, tp)  # (D, V)
    if path.endswith("pos_embed"):
        return P(None, fsdp)

    # --- attention -----------------------------------------------------------
    if path.endswith(("wq", "wk", "wv")):
        return pick(fsdp, tp)
    if path.endswith("wo"):
        return pick(tp, fsdp)
    if path.endswith(("bq", "bk", "bv")):
        return pick(tp)
    if path.endswith("bo"):
        return pick(fsdp)
    # MLA
    if path.endswith("w_q_mla"):
        return pick(fsdp, tp)       # (D, H*(dn+dr))
    if path.endswith("w_dkv"):
        return pick(fsdp, None)     # (D, R+dr) latent stays replicated
    if path.endswith(("w_uk", "w_uv")):
        return pick(None, tp, None)  # (R, H, d*): heads over tp
    if path.endswith("w_o_mla"):
        return pick(tp, fsdp)

    # --- dense mlp / moe experts ------------------------------------------------
    moe_expert = "moe" in path and "shared" not in path
    if path.endswith(("w_gate", "w_up")):
        if moe_expert:                      # (E, D, F)
            return pick(tp, None, fsdp)
        return pick(fsdp, tp)
    if path.endswith("w_down"):
        if moe_expert:                      # (E, F, D)
            return pick(tp, fsdp, None)
        return pick(tp, fsdp)
    if path.endswith(("b_in",)):
        return pick(tp)
    if path.endswith(("b_out",)):
        return pick(fsdp)
    if path.endswith("router"):
        return pick(None, None)

    # --- mamba2 / rglru --------------------------------------------------------
    if path.endswith("in_proj"):
        return pick(fsdp, None)
    if path.endswith("out_proj"):
        return pick(None, fsdp)
    if path.endswith(("w_x",)):
        return pick(fsdp, tp)
    if path.endswith(("w_input_gate", "w_rec_gate")):
        return pick(tp, None, None)  # block-diag gates: blocks over tp
    if path.endswith("w_out"):
        return pick(tp, fsdp)

    # everything else (norms, convs, biases, scalars): replicated
    return P(*([None] * ndim))


def param_specs(params, rules: Optional[ShardingRules]):
    """Pytree of PartitionSpec matching ``params``."""
    if rules is None:
        return jax.tree_util.tree_map(lambda _: P(), params)

    def spec(path, leaf):
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        s = _leaf_spec(name, leaf.ndim, rules)
        # sanity: spec length must equal rank
        if len(s) < leaf.ndim:
            s = P(*(list(s) + [None] * (leaf.ndim - len(s))))
        return s

    return jax.tree_util.tree_map_with_path(spec, params)
