"""Mixture-of-Experts with FISH load balancing (the paper's technique as a
first-class training feature).

Token→expert routing *is* the paper's grouping problem: keys are the router's
expert choices, workers are experts, and expert hotness evolves over training
exactly like the paper's time-evolving stream keys.  The three routing modes
mirror the paper's schemes (DESIGN.md §1.2):

* ``fg``   — plain top-k with uniform per-expert capacity (Field-Grouping
             analog: key-affine, drops whatever overflows).
* ``pkg``  — top-k where each token's k candidates are claimed in *gate*
             order but capacity is still uniform (power-of-k-choices analog).
* ``fish`` — the paper's pipeline on device:
             1. intra-epoch counting: per-step expert demand counts
                (epoch = one optimizer step's token batch);
             2. inter-epoch decay:   hotness ← α·hotness + counts  (Alg. 1);
             3. CHK (Alg. 2):        per-expert capacity share follows the
                d = E / 2^⌊log2(f_top/f_e)⌋ hierarchy, so persistently-hot
                experts get proportionally bigger slices of the *fixed*
                dispatch buffer (bounded memory — the paper's tradeoff);
             4. heuristic assignment (Alg. 3): claims are ordered by
                *inferred* fill (cumsum over the routing tensor already on
                device — zero communication), and the FISH aux loss steers
                the router with the decayed (recent) load rather than the
                noisy single-batch load.

Dispatch/combine use GShard-style grouped one-hot einsums (static shapes,
GSPMD-shardable); ``dispatch_impl='scatter'`` switches to a gather/scatter
path that removes the one-hot matmul FLOPs (a §Perf hillclimb lever).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from .common import activation_fn
from .sharding import shard

__all__ = ["init_moe_params", "moe_ffn", "fish_capacities", "init_hotness"]


def init_hotness(num_experts: int) -> jnp.ndarray:
    return jnp.zeros((num_experts,), jnp.float32)


# ---------------------------------------------------------------------------
# CHK: hotness -> per-expert capacity allocation (Alg. 2 analog)
# ---------------------------------------------------------------------------


def fish_capacities(
    hotness: jnp.ndarray,
    *,
    budget: int,
    c_max: int,
    theta_frac: float = 0.25,
    d_min: int = 2,
) -> jnp.ndarray:
    """Split a fixed dispatch budget across experts by decayed hotness.

    Vectorised CHK: hot experts (f_e > θ = theta_frac/E) get a share that
    follows d_e = E / 2^⌊log2(f_top/f_e)⌋ (clamped to [d_min, E]); non-hot
    experts get the PKG fallback share of 2.  Capacities are clipped to the
    static buffer depth ``c_max`` (memory bound).
    """
    e = hotness.shape[0]
    total = jnp.maximum(jnp.sum(hotness), 1e-30)
    f = hotness / total
    f_top = jnp.maximum(jnp.max(f), 1e-30)
    theta = theta_frac / e
    ratio = jnp.maximum(f_top / jnp.maximum(f, 1e-30), 1.0)
    index = jnp.clip(jnp.floor(jnp.log2(ratio)), 0, 30)
    d = jnp.clip(e / jnp.exp2(index), d_min, e)
    share = jnp.where(f > theta, d, float(d_min))
    cap = jnp.floor(budget * share / jnp.maximum(jnp.sum(share), 1e-30))
    # cold-start: with no history (Σhot == 0) fall back to the uniform split
    uniform = jnp.full((e,), float(budget) / e)
    cap = jnp.where(total > 1e-20, cap, uniform)
    return jnp.clip(cap, 1.0, float(c_max)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe_params(key, d_model: int, moe: MoEConfig, dtype=jnp.bfloat16):
    import math

    ks = jax.random.split(key, 5)
    e, f = moe.num_experts, moe.d_ff_expert
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, e), jnp.float32) * 0.02
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, f), jnp.float32)
                   * std_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, f), jnp.float32)
                 * std_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d_model), jnp.float32)
                   * std_out).astype(dtype),
    }
    if moe.shared_experts:
        fs = f * moe.shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(kk[0], (d_model, fs), jnp.float32)
                       * std_in).astype(dtype),
            "w_up": (jax.random.normal(kk[1], (d_model, fs), jnp.float32)
                     * std_in).astype(dtype),
            "w_down": (jax.random.normal(kk[2], (fs, d_model), jnp.float32)
                       * (1.0 / math.sqrt(fs))).astype(dtype),
        }
    return p


# ---------------------------------------------------------------------------
# Routing + capacity-bounded claim (slot-by-slot, fill inferred via cumsum)
# ---------------------------------------------------------------------------


def _route(
    gates: jnp.ndarray,  # (G, T, E) f32 softmax probs
    moe: MoEConfig,
    capacities: jnp.ndarray,  # (E,) int32
):
    """Claim buffer slots for each token's top-k choices.

    Returns ids (G,T,K), combine gate weights (G,T,K), keep (G,T,K) bool,
    pos (G,T,K) int32 — position within the target expert's buffer.

    The running fill is *inferred* from the routing tensor itself (exclusive
    cumsum per expert), never communicated — the Alg. 3 idea in SPMD form.
    """
    g, t, e = gates.shape
    k = moe.top_k
    top_gates, ids = jax.lax.top_k(gates, k)  # (G,T,K)

    fill = jnp.zeros((g, e), jnp.float32)
    keeps, poss = [], []
    for j in range(k):
        oh = jax.nn.one_hot(ids[:, :, j], e, dtype=jnp.float32)  # (G,T,E)
        pos_in_slot = jnp.cumsum(oh, axis=1) - oh  # exclusive, (G,T,E)
        pos_t = jnp.sum(oh * (pos_in_slot + fill[:, None, :]), axis=-1)  # (G,T)
        cap_t = capacities[ids[:, :, j]].astype(jnp.float32)
        keep_j = pos_t < cap_t
        fill = fill + jnp.sum(oh * keep_j[..., None], axis=1)
        keeps.append(keep_j)
        poss.append(pos_t.astype(jnp.int32))
    keep = jnp.stack(keeps, axis=-1)  # (G,T,K)
    pos = jnp.stack(poss, axis=-1)

    # renormalise gates over surviving slots
    kept_gate = top_gates * keep.astype(top_gates.dtype)
    denom = jnp.maximum(jnp.sum(kept_gate, axis=-1, keepdims=True), 1e-9)
    combine_gates = kept_gate / denom
    return ids, combine_gates, keep, pos


def _dispatch_einsum(x, ids, gates, keep, pos, e: int, c: int):
    """GShard one-hot dispatch/combine tensors.

    x: (G, T, D).  Returns xin (G, E, C, D) and a combine closure.
    """
    oh_e = jax.nn.one_hot(ids, e, dtype=x.dtype)  # (G,T,K,E)
    oh_c = jax.nn.one_hot(pos, c, dtype=x.dtype)  # (G,T,K,C)
    keep_f = keep.astype(x.dtype)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e * keep_f[..., None], oh_c)
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, x)

    def combine(yout):  # (G,E,C,D) -> (G,T,D)
        comb = jnp.einsum(
            "gtke,gtkc->gtec", oh_e * (keep_f * gates.astype(x.dtype))[..., None],
            oh_c,
        )
        return jnp.einsum("gtec,gecd->gtd", comb, yout)

    return xin, combine


def _dispatch_scatter(x, ids, gates, keep, pos, e: int, c: int):
    """Gather/scatter dispatch: no one-hot matmul FLOPs (hillclimb lever)."""
    g, t, d = x.shape
    k = ids.shape[-1]
    flat_slot = ids * c + pos  # (G,T,K) buffer slot per (token, choice)
    flat_slot = jnp.where(keep, flat_slot, e * c)  # OOB -> dropped
    src = jnp.broadcast_to(jnp.arange(t)[None, :, None], (g, t, k))

    def scat(xg, slots, srcs):
        buf = jnp.zeros((e * c, d), x.dtype)
        return buf.at[slots.reshape(-1)].add(
            xg[srcs.reshape(-1)], mode="drop"
        )

    xin = jax.vmap(scat)(x, flat_slot, src).reshape(g, e, c, d)

    def combine(yout):  # (G,E,C,D) -> (G,T,D)
        yflat = yout.reshape(g, e * c, d)

        def gath(yg, slots):
            return jnp.take(yg, slots.reshape(-1), axis=0, mode="fill",
                            fill_value=0).reshape(t, k, d)

        per_choice = jax.vmap(gath)(yflat, flat_slot)  # (G,T,K,D)
        w = (gates * keep.astype(gates.dtype)).astype(x.dtype)
        return jnp.einsum("gtk,gtkd->gtd", w, per_choice)

    return xin, combine


# ---------------------------------------------------------------------------
# The MoE layer
# ---------------------------------------------------------------------------


def moe_ffn(
    params: Dict,
    x: jnp.ndarray,  # (T, D) flattened tokens
    moe: MoEConfig,
    hotness: jnp.ndarray,  # (E,) decayed demand counters (FISH state)
    *,
    dispatch_impl: str = None,
    hot_headroom: float = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, Dict]:
    """Returns (y (T,D), new_hotness, aux_loss, metrics)."""
    dispatch_impl = dispatch_impl or moe.dispatch_impl
    hot_headroom = hot_headroom or moe.hot_headroom
    t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    act = activation_fn("silu")

    tg = min(moe.tokens_per_group, t)
    assert t % tg == 0, f"tokens {t} not divisible by group {tg}"
    g = t // tg
    budget = int(tg * k * moe.capacity_factor)
    c_avg = max(budget // e, 1)
    c_max = max(int(c_avg * hot_headroom), 4)
    c_max = -(-c_max // 4) * 4  # round up to a multiple of 4

    xg = x.reshape(g, tg, d)
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), params["router"]
    )
    gates = jax.nn.softmax(logits, axis=-1)

    # --- FISH state: intra-epoch count + inter-epoch decay (Alg. 1) ---------
    topk_gates, topk_ids = jax.lax.top_k(gates, k)
    counts = jnp.sum(
        jax.nn.one_hot(topk_ids, e, dtype=jnp.float32), axis=(0, 1, 2)
    )  # (E,) demand this step
    new_hotness = moe.fish_alpha * hotness + counts

    if moe.routing == "fish":
        capacities = fish_capacities(
            hotness, budget=budget, c_max=c_max,
            theta_frac=moe.fish_theta_frac,
        )
        # time-aware balance loss: steer router with *recent* load, not the
        # single-batch estimate
        recent = new_hotness / jnp.maximum(jnp.sum(new_hotness), 1e-30)
        mean_gate = jnp.mean(gates, axis=(0, 1))
        aux = jnp.sum(recent * mean_gate) * e
    elif moe.routing in ("fg", "pkg"):
        capacities = jnp.full((e,), min(c_avg, c_max), jnp.int32)
        frac = counts / jnp.maximum(jnp.sum(counts), 1e-30)
        mean_gate = jnp.mean(gates, axis=(0, 1))
        aux = jnp.sum(frac * mean_gate) * e
    else:
        raise ValueError(f"unknown moe routing {moe.routing!r}")

    ids, cgates, keep, pos = _route(gates, moe, capacities)
    if moe.routing == "fg":
        # FG analog: only the argmax choice is used (hard key-affine routing)
        first = jnp.arange(k)[None, None, :] == 0
        keep = keep & first
        cgates = jnp.where(keep, 1.0, 0.0).astype(cgates.dtype)

    dispatch = _dispatch_scatter if dispatch_impl == "scatter" else _dispatch_einsum
    xin, combine = dispatch(xg, ids, cgates, keep, pos, e, c_max)

    # --- expert FFN (E batched einsum; E shards over "model") ---------------
    # groups stay data-parallel, experts shard over tp (EP): the reshard of
    # xin from (g-sharded, e-replicated) to (g-sharded, e-sharded) is the
    # GShard-style dispatch all-to-all, inserted by GSPMD at this constraint.
    xin = shard(xin, "dp", "tp", None, None)
    h = act(jnp.einsum("gecd,edf->gecf", xin, params["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xin, params["w_up"]
    )
    h = shard(h, "dp", "tp", None, None)
    yout = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    yout = shard(yout, "dp", "tp", None, None)
    y = combine(yout).reshape(t, d)

    if moe.shared_experts:
        sp = params["shared"]
        hs = act(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    load = counts / jnp.maximum(jnp.sum(counts), 1e-30)
    metrics = {
        "moe_drop_frac": dropped,
        "moe_load_max_over_mean": jnp.max(load) * e,
        "moe_aux": aux,
    }
    return y.astype(x.dtype), new_hotness, aux * moe.router_aux_weight, metrics
