"""Attention: blockwise (flash-style) training/prefill path, O(S) decode path,
GQA/MQA, sliding windows, soft-capping, and DeepSeek MLA.

The training path never materialises the (Sq, Skv) score matrix: it scans over
KV blocks with an online softmax (running max / denominator), which is what
keeps the 32k-prefill dry-run inside HBM.  All score math is float32.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import soft_cap

__all__ = ["flash_attention", "decode_attention", "mla_expand", "mla_decode_scores"]

_NEG_INF = -1e30


def _block_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(Sq, Bk) boolean mask from absolute positions."""
    rel = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones(rel.shape, bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    return mask


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_k: int = 1024,
    remat_blocks: bool = True,
) -> jnp.ndarray:
    """Blockwise attention with online softmax.

    q: (B, Sq, Hq, dh); k, v: (B, Skv, Hkv, dh) with Hq % Hkv == 0.
    Returns (B, Sq, Hq, dh) in q.dtype.

    ``remat_blocks`` checkpoints each KV block so the backward pass
    recomputes per-block scores instead of storing the O(Sq·Skv) attention
    matrix (flash-attention backward semantics).
    """
    b, sq, hq, dh = q.shape
    skv_orig, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # v head dim may differ (MLA)
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    block_k = min(block_k, skv_orig)
    pad_kv = -skv_orig % block_k
    if pad_kv:  # pad kv to a block multiple; padded positions are masked
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    skv = k.shape[1]
    n_blocks = skv // block_k

    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, rep, dh)
    kf = k.astype(jnp.float32).reshape(b, n_blocks, block_k, hkv, dh)
    vf = v.astype(jnp.float32).reshape(b, n_blocks, block_k, hkv, dv)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_run, l_run, acc = carry
        k_blk, v_blk, blk_idx = inp  # (B, Bk, Hkv, dh) ×2, scalar
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k_blk)  # (B,Hkv,rep,Sq,Bk)
        if softcap is not None:
            s = soft_cap(s, softcap)
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
        mask &= (k_pos < skv_orig)[None, :]
        s = jnp.where(mask[None, None, None], s, _NEG_INF)

        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_run, m_blk)
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(m_new <= _NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(m_run <= _NEG_INF, _NEG_INF, m_run - m_safe))
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhrqk,bkhd->bhrqd", p, v_blk)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, rep, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, rep, sq, dv), jnp.float32)
    ks = jnp.moveaxis(kf, 1, 0)  # (n_blocks, B, Bk, Hkv, dh)
    vs = jnp.moveaxis(vf, 1, 0)
    if remat_blocks:
        body = jax.checkpoint(body, prevent_cse=False)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (ks, vs, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]  # (B,Hkv,rep,Sq,dv)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cur_pos: jnp.ndarray,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention against a (possibly partially filled) KV cache.

    q: (B, 1, Hq, dh); caches: (B, S, Hkv, dh); cur_pos: scalar int — the
    position of the new token (cache entries at positions <= cur_pos are
    valid).
    """
    b, _, hq, dh = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, rep, dh)
    scores = jnp.einsum("bhrd,bkhd->bhrk", qf, k_cache.astype(jnp.float32))
    if softcap is not None:
        scores = soft_cap(scores, softcap)
    k_pos = jnp.arange(s)
    valid = k_pos <= cur_pos
    if window is not None:
        valid &= (cur_pos - k_pos) < window
    scores = jnp.where(valid[None, None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrk,bkhd->bhrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention
# ---------------------------------------------------------------------------


def mla_expand(c_kv: jnp.ndarray, w_uk: jnp.ndarray, w_uv: jnp.ndarray):
    """Expand the compressed KV latent into per-head K(nope)/V.

    c_kv: (B, S, R);  w_uk: (R, H, dn);  w_uv: (R, H, dv)
    returns k_nope (B, S, H, dn), v (B, S, H, dv)
    """
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, w_uk)
    v = jnp.einsum("bsr,rhd->bshd", c_kv, w_uv)
    return k_nope, v


def mla_decode_scores(
    q_nope: jnp.ndarray,  # (B, H, dn)
    q_rope: jnp.ndarray,  # (B, H, dr)
    ckv_cache: jnp.ndarray,  # (B, S, R)
    krope_cache: jnp.ndarray,  # (B, S, dr)
    w_uk: jnp.ndarray,  # (R, H, dn)
    w_uv: jnp.ndarray,  # (R, H, dv)
    cur_pos: jnp.ndarray,
    *,
    scale: float,
) -> jnp.ndarray:
    """Weight-absorbed MLA decode (arXiv:2405.04434 §2.1.3).

    Scores are computed in the compressed space:  q_c = q_nope · W_uk  gives
    (B, H, R); attention runs against the R-dim latent cache, and the context
    is expanded back through W_uv.  Returns (B, 1, H, dv).
    """
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    s_c = jnp.einsum("bhr,bsr->bhs", q_c, ckv_cache.astype(jnp.float32))
    s_r = jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                     krope_cache.astype(jnp.float32))
    scores = (s_c + s_r) * scale
    valid = jnp.arange(ckv_cache.shape[1]) <= cur_pos
    scores = jnp.where(valid[None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhs,bsr->bhr", p, ckv_cache.astype(jnp.float32))
    ctx = jnp.einsum("bhr,rhd->bhd", ctx_c, w_uv.astype(jnp.float32))
    return ctx[:, None].astype(q_nope.dtype)
