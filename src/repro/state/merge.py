"""Merging split-key partial aggregates + the stream oracle (ISSUE 4).

PKG/DC/WC/FISH split hot keys across several workers, so a key's window
aggregate exists as several partials that a downstream merge stage must
combine (the paper's stated cost of key splitting); SG splits *every* key.
:func:`merge_partials` is that combine: vectorised segment-reduce over all
partial entries of a window, then per-``agg`` finalisation (top-k cut for
``topk``).

:func:`direct_aggregate` computes the same result straight from the input
key stream — the routing-free oracle: merged results must equal it for
every scheme, engine, churn pattern and migration policy.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from .window import WindowOp, WindowPartial, tuple_values

__all__ = ["merge_partials", "direct_aggregate", "topk_cut"]


def topk_cut(keys: np.ndarray, counts: np.ndarray, k: int) -> List[List[int]]:
    """The k heaviest keys, ties broken toward the smaller key id
    (deterministic): ``[[key, count], ...]`` sorted heaviest-first."""
    order = np.lexsort((keys, -counts))[:k]
    return [[int(keys[i]), int(counts[i])] for i in order.tolist()]


def _finalize(op: WindowOp, acc: Dict[int, Dict[int, np.ndarray]]) -> Dict:
    out: Dict[int, object] = {}
    for w in sorted(acc):
        ks, vs = acc[w]
        if op.agg == "topk":
            out[int(w)] = topk_cut(ks, vs, op.k)
        else:
            out[int(w)] = {int(k): int(v)
                           for k, v in zip(ks.tolist(), vs.tolist())}
    return out


def merge_partials(partials: Sequence[WindowPartial], op: WindowOp) -> Dict:
    """Combine per-worker partials into final per-window results:
    ``{window_start: {key: value}}`` (count/sum) or
    ``{window_start: [[key, count], ...]}`` (topk)."""
    by_window: Dict[int, List[WindowPartial]] = {}
    for p in partials:
        by_window.setdefault(int(p.window), []).append(p)
    acc: Dict[int, Dict[int, np.ndarray]] = {}
    for w, ps in by_window.items():
        ks = np.concatenate([p.keys for p in ps])
        vs = np.concatenate([p.values for p in ps])
        uniq, inv = np.unique(ks, return_inverse=True)
        tot = np.zeros(uniq.shape[0], dtype=np.int64)
        np.add.at(tot, inv, vs)
        acc[w] = (uniq, tot)
    return _finalize(op, acc)


def direct_aggregate(keys, op: WindowOp, values=None) -> Dict:
    """The oracle: window results computed directly from the key stream
    (plus the payload ``values`` column for ``value="payload"`` operators),
    bypassing routing, state stores, churn and migration entirely."""
    keys = np.asarray(keys).astype(np.int64, copy=False)
    values = tuple_values(op, keys, payload=values)
    n = keys.shape[0]
    acc: Dict[int, Dict[int, np.ndarray]] = {}
    for start in range(0, n, op.stride):
        lo, hi = start, min(start + op.size, n)
        uniq, inv = np.unique(keys[lo:hi], return_inverse=True)
        tot = np.zeros(uniq.shape[0], dtype=np.int64)
        np.add.at(tot, inv, values[lo:hi])
        acc[start] = (uniq, tot)
    return _finalize(op, acc)
