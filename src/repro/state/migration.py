"""State-migration protocol under membership churn (ISSUE 4).

When the live worker set changes mid-window, the keyed state held by
downstream operators must follow the keys:

* every entry on a worker that *left* the live set moves — to the key's
  new primary route (``grouper.probe_route``) for affinity schemes, or
  round-robin over the live set for schemes with no key affinity (SG);
* for affinity schemes, an entry held by the key's *old* primary moves to
  the new primary when the route changed (a consistent-hash ring only
  remaps keys on affected arcs, so this is a ~1/W slice per host event) —
  partials on non-primary holders (split hot keys) stay put, the
  downstream merge reconciles them.

Two policies, identical results, different cost model:

* ``migrate`` — the entry's bytes are shipped (``bytes_moved`` accounts
  ``entries × ENTRY_BYTES``);
* ``rebuild`` — the entry is discarded and its tuples replayed at the new
  owner (``tuples_replayed`` accounts the per-entry fold counts; replaying
  the same tuples reconstructs the same aggregate, so exactness holds).

Either way the moved aggregates are folded into the target worker's store,
so no contribution is lost or double counted — post-merge results stay
bit-identical to the no-churn oracle (enforced by tests/test_state.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .store import ENTRY_BYTES, make_store

__all__ = ["MigrationStats", "MigrationBiller", "apply_membership_change"]


@dataclasses.dataclass
class MigrationStats:
    """Cumulative migration cost across membership events.

    ``last_recv_entries`` / ``last_recv_replays`` are reset at the start of
    each :func:`apply_membership_change` call and record, per *target*
    worker, how many entries (migrate policy) or folded tuples (rebuild
    policy) that event shipped to it — the per-destination bill a
    :class:`MigrationBiller` converts into engine-clock stall time
    (ISSUE 8: scale-out competes with serving bandwidth)."""

    events: int = 0
    bytes_moved: int = 0
    entries_moved: int = 0
    tuples_replayed: int = 0
    last_recv_entries: Dict[int, int] = dataclasses.field(
        default_factory=dict)
    last_recv_replays: Dict[int, int] = dataclasses.field(
        default_factory=dict)


class MigrationBiller:
    """Turns one membership event's migrated state into per-worker stall
    time on the engine clock (seconds for the DSPE simulator, scheduler
    ticks for the serving engine) — ISSUE 8 tick-billed migration.

    Chain :meth:`on_event` *after* the owning
    :class:`~repro.state.window.KeyedStateManager`'s ``on_event`` in the
    engine's observer sequence: the manager runs the migration protocol at
    ``post_membership`` and leaves the per-target bill on
    ``stats.last_recv_*``; this observer converts it to pending charges.
    The engine interpreter then pops the charges and adds them to the
    destination workers' busy time at the event's stream position, so a
    scale-out's state transfer delays exactly the tuples that route to the
    new worker while it is still ingesting state.
    """

    def __init__(self, stats: MigrationStats, cost_per_byte: float,
                 cost_per_replay: float = 0.0):
        self.stats = stats
        self.cost_per_byte = float(cost_per_byte)
        self.cost_per_replay = float(cost_per_replay)
        self.billed_total = 0.0
        self._pending: Dict[int, float] = {}

    def on_event(self, kind: str, grouper, event=None) -> None:
        if kind != "post_membership":
            return
        for w, entries in self.stats.last_recv_entries.items():
            charge = entries * ENTRY_BYTES * self.cost_per_byte
            if charge > 0.0:
                self._pending[w] = self._pending.get(w, 0.0) + charge
        for w, replays in self.stats.last_recv_replays.items():
            charge = replays * self.cost_per_replay
            if charge > 0.0:
                self._pending[w] = self._pending.get(w, 0.0) + charge

    def pop_charges(self) -> Dict[int, float]:
        """Drain the per-worker stall accumulated since the last pop."""
        out = self._pending
        self._pending = {}
        self.billed_total += sum(out.values())
        return out


def apply_membership_change(open_windows, pre_routes: Dict[int, Optional[int]],
                            grouper, op, stats: MigrationStats) -> None:
    """Run the migration protocol over every open window.

    ``pre_routes`` is the pre-event ``probe_route`` snapshot of every key
    resident in an open store; ``grouper`` has already applied the
    membership change (post-event routes and live set are read from it).
    """
    live = sorted(grouper.active_workers)
    live_set = set(live)
    post_routes: Dict[int, Optional[int]] = {}
    rr = 0  # round-robin cursor for no-affinity (SG) entries
    stats.last_recv_entries = {}
    stats.last_recv_replays = {}
    for win in open_windows:
        for w in sorted(win.stores):
            st = win.stores[w]
            if st.num_entries == 0:
                continue
            ks, _, _ = st.items()
            if w not in live_set:
                moved_keys = ks
            else:
                sel = []
                for k in ks.tolist():
                    pre = pre_routes.get(k)
                    if pre != w:
                        continue  # this worker was not the key's primary
                    post = post_routes.get(k, _MISSING)
                    if post is _MISSING:
                        post = post_routes[k] = grouper.probe_route(k)
                    if post is not None and post != w:
                        sel.append(k)
                if not sel:
                    continue
                moved_keys = np.asarray(sel, dtype=np.int64)
            vals, cnts = st.take(moved_keys)
            targets = np.empty(moved_keys.shape[0], dtype=np.int64)
            for i, k in enumerate(moved_keys.tolist()):
                post = post_routes.get(k, _MISSING)
                if post is _MISSING:
                    post = post_routes[k] = grouper.probe_route(k)
                if post is None:  # no key affinity: spread round-robin
                    post = live[rr % len(live)]
                    rr += 1
                targets[i] = post
            for t in np.unique(targets).tolist():
                m = targets == t
                tgt = win.stores.get(t)
                if tgt is None:
                    tgt = win.stores[t] = make_store(op.backend)
                tgt.merge_entries(moved_keys[m], vals[m], cnts[m])
                last = win.last_idx.get(w, -1)
                if last > win.last_idx.get(t, -1):
                    win.last_idx[t] = last
                if op.migration == "migrate":
                    stats.last_recv_entries[t] = (
                        stats.last_recv_entries.get(t, 0) + int(m.sum()))
                else:
                    stats.last_recv_replays[t] = (
                        stats.last_recv_replays.get(t, 0)
                        + int(cnts[m].sum()))
            stats.entries_moved += int(moved_keys.shape[0])
            if op.migration == "migrate":
                stats.bytes_moved += int(moved_keys.shape[0]) * ENTRY_BYTES
            else:  # rebuild: discard + replay the folded tuples
                stats.tuples_replayed += int(cnts.sum())
    stats.events += 1


_MISSING = object()
