"""Windowed keyed operators over per-worker state stores (ISSUE 4).

:class:`WindowOp` declares a stateful operator on a topology stage:
tumbling or sliding count-based windows (window boundaries indexed by the
stage's *input tuple index*, so results are identical across engines and
routing schemes), one of three aggregations (``count`` / ``sum`` /
``topk``), a store backend, and a migration policy for churn.

:class:`KeyedStateManager` is the runtime: engines feed it the routed
``(keys, workers[, values])`` chunks of one grouped edge (in stream order)
and fire its membership hooks around churn events.  State is held
*pane-based* (ISSUE 5): each tuple folds into exactly one state store per
worker — the store of its slide-aligned pane — and windows are composed
from ``size/slide`` consecutive panes when they close (for tumbling
windows a pane *is* the window, so this is the identical layout).  Sliding
windows therefore cost one store update per tuple instead of
``size/slide``, and live state bytes count each pane once instead of once
per overlapping window.  Closed windows flush into :class:`WindowPartial`
records (the partial aggregates a downstream merge stage combines), and
the state-migration protocol (:mod:`repro.state.migration`) runs over the
live panes on every membership change.

Because every tuple folds into exactly one worker's store with an
order-independent int64 aggregate, the *merged* per-key results are a pure
function of the input stream — independent of scheme, engine, churn and
migration policy.  That is the exactness contract ``tests/test_state.py``
enforces against the :func:`repro.state.merge.direct_aggregate` oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .migration import MigrationStats, apply_membership_change
from .store import ENTRY_BYTES, STORE_BACKENDS, make_store

__all__ = [
    "WindowOp",
    "WindowPartial",
    "StateReport",
    "KeyedStateManager",
    "tuple_values",
]

_MIX = np.int64(2654435761)  # Knuth multiplicative-hash constant


@dataclasses.dataclass(frozen=True)
class WindowOp:
    """A windowed keyed aggregation on a stage (count-based windows).

    agg:       "count" (tuples per key), "sum" (per-tuple payload summed
               per key) or "topk" (k heaviest keys per window by tuple
               count).
    size:      window length in tuples of the stage's input stream.
    slide:     sliding step; ``None`` means tumbling (slide == size).
               ``size`` must be a multiple of ``slide`` so window
               boundaries align with the slide grid.
    k:         top-k cut (``topk`` only).
    backend:   state-store backend ("array" | "dict").
    migration: churn policy — "migrate" ships state entries to the key's
               new owner (bytes-moved accounted); "rebuild" discards and
               replays the entry's tuples at the new owner
               (tuples-replayed accounted).  Results are exact either way.
    value:     payload for "sum" — "hashed" (deterministic pseudo-payload
               per key), "key" (the key id itself), or "payload" (the
               stream's real ``values`` column — ISSUE 5 record batches;
               folded as int64, so fractional payloads truncate).
    """

    agg: str = "count"
    size: int = 1_000
    slide: Optional[int] = None
    k: int = 8
    backend: str = "array"
    migration: str = "migrate"
    value: str = "hashed"

    def __post_init__(self) -> None:
        if self.agg not in ("count", "sum", "topk"):
            raise ValueError(f"unknown agg {self.agg!r}; "
                             f"one of ('count', 'sum', 'topk')")
        if self.size < 1:
            raise ValueError(f"window size must be >= 1, got {self.size}")
        if self.slide is not None:
            if not 1 <= self.slide <= self.size:
                raise ValueError(f"slide must be in [1, size], got "
                                 f"{self.slide}")
            if self.size % self.slide != 0:
                raise ValueError(f"size ({self.size}) must be a multiple of "
                                 f"slide ({self.slide})")
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.backend not in STORE_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; one of "
                             f"{sorted(STORE_BACKENDS)}")
        if self.migration not in ("migrate", "rebuild"):
            raise ValueError(f"unknown migration policy {self.migration!r}; "
                             f"'migrate' or 'rebuild'")
        if self.value not in ("hashed", "key", "payload"):
            raise ValueError(f"unknown value kind {self.value!r}; "
                             f"'hashed', 'key' or 'payload'")

    @property
    def stride(self) -> int:
        return self.slide if self.slide is not None else self.size


def tuple_values(op: WindowOp, keys: np.ndarray,
                 payload: Optional[np.ndarray] = None) -> np.ndarray:
    """The per-tuple int64 contribution folded into the key's state entry.
    For ``value="hashed"``/``"key"`` a pure function of the key (so
    aggregates are independent of routing/engine/churn); for
    ``value="payload"`` the stream's real values column (ISSUE 5 — still
    order-independent under int64 summation, so the same contract holds)."""
    keys = np.asarray(keys).astype(np.int64)
    if op.agg in ("count", "topk"):
        return np.ones(keys.shape[0], dtype=np.int64)
    if op.value == "payload":
        if payload is None:
            raise ValueError(
                "WindowOp(value='payload') needs the stream's values "
                "column — feed RecordBatches with values=, or use "
                "value='hashed'/'key' for payload-free streams")
        return np.asarray(payload).astype(np.int64)
    if op.value == "key":
        return keys
    return ((keys * _MIX) & np.int64(0x7FFFFFFF)) % 97 + 1


@dataclasses.dataclass
class WindowPartial:
    """One worker's partial aggregate for one closed window: the unit the
    downstream merge stage consumes (one merge tuple per entry)."""

    window: int          # window start (input tuple index)
    worker: int
    keys: np.ndarray     # int64, sorted
    values: np.ndarray   # int64 aggregates
    counts: np.ndarray   # tuples folded per entry (replay cost)
    last_index: int      # input index of the worker's last tuple in window


@dataclasses.dataclass
class StateReport:
    """Per-operator-stage state outcome (JSON-able via :meth:`summary`)."""

    stage: str
    agg: str
    backend: str
    migration_policy: str
    windows: int
    partials: int            # flushed (window, worker) partials
    partial_entries: int     # merge-stage input tuples (Σ entries)
    state_keys: int          # distinct keys aggregated over the stream
    state_bytes_peak: int    # max Σ_w store bytes over time
    state_bytes_final: int   # Σ_w store bytes at stream end (pre-flush)
    per_worker_bytes: List[int]  # per-worker peak store bytes
    migration_bytes: int
    migration_events: int
    tuples_replayed: int
    merged: Dict             # window -> {key: value} | topk [[key, count]..]

    def summary(self, include_merged: bool = True) -> Dict:
        d = dataclasses.asdict(self)
        if not include_merged:
            d.pop("merged")
        return d


class _Pane:
    """One slide-aligned block of per-worker stores: the unit every tuple
    folds into exactly once, and the unit migration moves.  (For tumbling
    windows a pane covers the whole window.)  Attribute layout matches what
    :func:`repro.state.migration.apply_membership_change` walks."""

    __slots__ = ("start", "end", "stores", "last_idx")

    def __init__(self, start: int, end: int):
        self.start = start
        self.end = end
        self.stores: Dict[int, object] = {}
        self.last_idx: Dict[int, int] = {}


class KeyedStateManager:
    """Keyed operator state for one grouped edge.

    Engines drive three entry points, all in stream order:

    * :meth:`feed` — the routed (keys, workers[, values]) of the next chunk;
    * :meth:`on_event` — the membership observer hook (same signature as
      the engines' ``event_observer``), which runs the migration protocol;
    * :meth:`finalize` — stream end: close the remaining open windows.

    Internally state lives in panes (one per slide block); a window's
    per-worker partial is composed from its ``size/slide`` panes when the
    window closes.  Windows close in start order; once the window starting
    at pane ``p`` has flushed, no later window needs ``p`` and the pane is
    dropped — so a pane is retained for exactly ``size`` tuples, the same
    horizon the per-window layout had.
    """

    def __init__(self, op: WindowOp):
        self.op = op
        self.idx = 0  # next input tuple index
        self.partials: List[WindowPartial] = []
        self.migration = MigrationStats()
        self.state_bytes_peak = 0
        self.state_bytes_final = 0
        self._per_worker_peak: Dict[int, int] = {}
        self._panes: Dict[int, _Pane] = {}
        self._next_window = 0  # start index of the next window to flush
        self._pre_routes: Optional[Dict[int, Optional[int]]] = None
        self._finalized = False
        self._seen_keys: set = set()
        self._seen_pending: List[np.ndarray] = []

    # -- bookkeeping --------------------------------------------------------------
    def _note_bytes(self) -> int:
        total = 0
        per_worker: Dict[int, int] = {}
        for pane in self._panes.values():
            for w, st in pane.stores.items():
                b = st.size_bytes()
                total += b
                per_worker[w] = per_worker.get(w, 0) + b
        for w, b in per_worker.items():
            if b > self._per_worker_peak.get(w, 0):
                self._per_worker_peak[w] = b
        if total > self.state_bytes_peak:
            self.state_bytes_peak = total
        return total

    def _flush_window(self, start: int) -> None:
        """Compose the window starting at ``start`` from its panes (one
        per-worker partial, keys sorted) and drop the panes no later
        window needs."""
        size, stride = self.op.size, self.op.stride
        panes = [self._panes[p] for p in range(start, start + size, stride)
                 if p in self._panes]
        workers = sorted({w for pane in panes for w in pane.stores})
        for w in workers:
            parts = [(pane.stores[w].items(), pane.last_idx.get(w, start))
                     for pane in panes
                     if w in pane.stores and pane.stores[w].num_entries]
            if not parts:
                continue
            if len(parts) == 1:
                (ks, vs, cs), last = parts[0]
            else:
                ks = np.concatenate([p[0][0] for p in parts])
                uniq, inv = np.unique(ks, return_inverse=True)
                vs = np.zeros(uniq.shape[0], dtype=np.int64)
                cs = np.zeros(uniq.shape[0], dtype=np.int64)
                np.add.at(vs, inv, np.concatenate([p[0][1] for p in parts]))
                np.add.at(cs, inv, np.concatenate([p[0][2] for p in parts]))
                ks = uniq
                last = max(p[1] for p in parts)
            self.partials.append(WindowPartial(
                window=start, worker=w, keys=ks, values=vs, counts=cs,
                last_index=last))
        self._next_window = start + stride
        for p in [p for p in self._panes if p < self._next_window]:
            del self._panes[p]

    def _flush_ready(self) -> None:
        """Flush every window whose end has passed (in start order)."""
        if self._next_window + self.op.size <= self.idx:
            self._note_bytes()
            while self._next_window + self.op.size <= self.idx:
                self._flush_window(self._next_window)

    # -- stream input -------------------------------------------------------------
    def feed(self, keys, workers, values=None) -> None:
        """Fold the next routed chunk into the live panes' stores.
        ``keys[i]`` was routed to ``workers[i]`` (carrying payload
        ``values[i]`` when the stream has a values column); tuple ``i``
        has global input index ``self.idx + i``."""
        if self._finalized:
            raise RuntimeError("KeyedStateManager already finalized")
        keys = np.asarray(keys).astype(np.int64, copy=False)
        workers = np.asarray(workers).astype(np.int64, copy=False)
        n = keys.shape[0]
        if n == 0:
            return
        self._seen_keys.update(np.unique(keys).tolist())
        values = tuple_values(self.op, keys, payload=values)
        stride = self.op.stride
        backend = self.op.backend
        pos = 0
        while pos < n:
            self._flush_ready()
            block = (self.idx // stride) * stride
            pane = self._panes.get(block)
            if pane is None:
                pane = self._panes[block] = _Pane(block, block + stride)
            take = min(n - pos, block + stride - self.idx)
            kc = keys[pos:pos + take]
            wc = workers[pos:pos + take]
            vc = values[pos:pos + take]
            order = np.argsort(wc, kind="stable")
            ws = wc[order]
            seg = np.concatenate([[0], np.flatnonzero(ws[1:] != ws[:-1]) + 1,
                                  [take]])
            for s, e in zip(seg[:-1].tolist(), seg[1:].tolist()):
                w = int(ws[s])
                sl = order[s:e]
                last = self.idx + int(sl.max())
                st = pane.stores.get(w)
                if st is None:
                    st = pane.stores[w] = make_store(backend)
                st.update_batch(kc[sl], vc[sl])
                if last > pane.last_idx.get(w, -1):
                    pane.last_idx[w] = last
            self.idx += take
            pos += take

    def feed_aggregated(self, n_tuples: int, entries) -> None:
        """Fused-engine input (ISSUE 6): the device engine aggregates one
        pane's (key, worker) contributions on device and syncs them here
        in bulk instead of streaming every routed chunk through
        :meth:`feed`.

        ``n_tuples`` is how many input tuples the sync covers (advances
        ``self.idx``); ``entries`` is a list of ``(worker, keys int64,
        values int64, counts int64, last_index)`` — values already folded
        through :func:`tuple_values` by the caller.  The covered span must
        lie within a single pane (the fused engine cuts segments at pane
        boundaries); store merging accumulates, so one pane may be synced
        in several calls (e.g. around membership events)."""
        if self._finalized:
            raise RuntimeError("KeyedStateManager already finalized")
        if n_tuples == 0:
            return
        self._flush_ready()
        stride = self.op.stride
        block = (self.idx // stride) * stride
        if self.idx + n_tuples > block + stride:
            raise ValueError(
                f"feed_aggregated span [{self.idx}, {self.idx + n_tuples})"
                f" crosses the pane boundary at {block + stride}; the "
                "fused engine must flush at pane boundaries")
        pane = self._panes.get(block)
        if pane is None:
            pane = self._panes[block] = _Pane(block, block + stride)
        backend = self.op.backend
        for w, ks, vs, cs, last in entries:
            if ks.shape[0] == 0:
                continue
            w = int(w)
            self._seen_pending.append(ks)
            st = pane.stores.get(w)
            if st is None:
                st = pane.stores[w] = make_store(backend)
            # the fused flush builds these columns fresh per sync — the
            # store may keep them without a defensive copy
            st.merge_entries(ks, vs, cs, own=True)
            if last > pane.last_idx.get(w, -1):
                pane.last_idx[w] = int(last)
        self.idx += n_tuples

    def _seen_count(self) -> int:
        """Distinct state keys seen.  Bulk (fused) inputs defer the set
        union — one ``np.unique`` over the accumulated arrays at metric
        time instead of per-worker set updates on the feed hot path."""
        if self._seen_pending:
            self._seen_keys.update(
                np.unique(np.concatenate(self._seen_pending)).tolist())
            self._seen_pending.clear()
        return len(self._seen_keys)

    def drain_partials(self, start: int) -> List[WindowPartial]:
        """Flush every window that has closed and return the partials
        appended since ``start`` — the incremental-emission hook (ISSUE 6
        satellite): engines call this after each feed to push completed
        windows downstream instead of holding them until close."""
        self._flush_ready()
        return self.partials[start:]

    # -- membership hook (engines' event_observer signature) -----------------------
    def on_event(self, kind: str, grouper, event=None) -> None:
        if kind == "pre_membership":
            # engines fire events before feeding the post-event chunk, so a
            # window that completed exactly at the event index may still be
            # lazily unflushed — flush it first, so its partials reflect
            # pre-event ownership; panes still serving open windows are
            # live state and migrate with their keys' new owners
            self._flush_ready()
            self._pre_routes = self._snapshot_routes(grouper)
        elif kind == "post_membership":
            apply_membership_change(
                list(self._panes.values()), self._pre_routes or {}, grouper,
                self.op, self.migration)
            self._pre_routes = None
            self._note_bytes()
        # "capacity" events don't touch keyed state

    def _snapshot_routes(self, grouper) -> Dict[int, Optional[int]]:
        routes: Dict[int, Optional[int]] = {}
        for pane in self._panes.values():
            for st in pane.stores.values():
                ks, _, _ = st.items()
                for k in ks.tolist():
                    if k not in routes:
                        routes[k] = grouper.probe_route(k)
        return routes

    # -- stream end -----------------------------------------------------------------
    def finalize(self) -> None:
        if self._finalized:
            return
        self.state_bytes_final = self._note_bytes()
        while self._next_window < self.idx:
            self._flush_window(self._next_window)
        self._finalized = True

    # -- outputs ---------------------------------------------------------------------
    def partial_entries(self) -> Tuple[np.ndarray, np.ndarray]:
        """The merge-stage input stream: (entry keys, entry last-index) —
        one tuple per state entry, released when its worker flushed."""
        if not self.partials:
            return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))
        ks = np.concatenate([p.keys for p in self.partials])
        last = np.concatenate([
            np.full(p.keys.shape[0], p.last_index, dtype=np.int64)
            for p in self.partials])
        return ks, last

    def report(self, stage: str) -> StateReport:
        from .merge import merge_partials

        if not self._finalized:
            self.finalize()
        n_workers = max(self._per_worker_peak, default=-1) + 1
        per_worker = [self._per_worker_peak.get(w, 0)
                      for w in range(n_workers)]
        return StateReport(
            stage=stage, agg=self.op.agg, backend=self.op.backend,
            migration_policy=self.op.migration,
            windows=len({p.window for p in self.partials}),
            partials=len(self.partials),
            partial_entries=int(sum(p.keys.shape[0] for p in self.partials)),
            state_keys=self._seen_count(),
            state_bytes_peak=int(self.state_bytes_peak),
            state_bytes_final=int(self.state_bytes_final),
            per_worker_bytes=per_worker,
            migration_bytes=int(self.migration.bytes_moved),
            migration_events=int(self.migration.events),
            tuples_replayed=int(self.migration.tuples_replayed),
            merged=merge_partials(self.partials, self.op),
        )
