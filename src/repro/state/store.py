"""Per-worker keyed state stores (ISSUE 4 tentpole).

A state store is the downstream operator's per-worker key→aggregate table —
the thing the paper's memory metric (Fig. 3/11/20) is actually *about*: SG
replicates every key's aggregation state on every worker, key grouping keeps
one copy, PKG/DC/WC/FISH split only hot keys at the cost of a downstream
merge.  Until this PR the repro only counted distinct keys per worker
(``Grouper.replicas``); these stores hold real windowed aggregation state so
state bytes, merge cost and migration cost are *measured*, not proxied.

Two interchangeable backends behind one interface:

* :class:`DictStateStore` — plain dict, the readable reference.
* :class:`ArrayStateStore` — vectorised open-addressing table (int key ids,
  Fibonacci hashing, linear probing, tombstone deletion) whose batch update
  is one ``np.unique`` + segment-reduce (``np.add.at``) per chunk, so the
  hot path stays batched like the PR-1 grouping engine.

Both accumulate an int64 ``value`` and an int64 ``count`` (tuples folded
into the entry — the replay cost of rebuilding it) per key, which makes
every aggregate order-independent: merged results are bit-identical no
matter how routing, churn or migration shuffled the partials.

Entry size accounting uses the logical wire size :data:`ENTRY_BYTES`
(int32 key + int64 value) for both backends so memory and migration bytes
are backend-independent and comparable across schemes.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

__all__ = [
    "ENTRY_BYTES",
    "DictStateStore",
    "ArrayStateStore",
    "DeviceStateStore",
    "STORE_BACKENDS",
    "make_store",
]

ENTRY_BYTES = 12  # logical bytes per entry: int32 key + int64 aggregate

_EMPTY = np.int64(-1)       # slot never used
_TOMB = np.int64(-2)        # slot deleted (probe chains continue through it)
_FIB = np.uint64(0x9E3779B97F4A7C15)  # Fibonacci-hash multiplier


class DictStateStore:
    """Reference backend: ``key -> [value, count]`` in a plain dict."""

    backend = "dict"

    def __init__(self) -> None:
        self._d: Dict[int, List[int]] = {}

    # -- interface ------------------------------------------------------------
    @property
    def num_entries(self) -> int:
        return len(self._d)

    def size_bytes(self) -> int:
        return len(self._d) * ENTRY_BYTES

    def update_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        d = self._d
        for k, v in zip(np.asarray(keys).tolist(),
                        np.asarray(values).tolist()):
            e = d.get(k)
            if e is None:
                d[k] = [int(v), 1]
            else:
                e[0] += int(v)
                e[1] += 1

    def merge_entries(self, keys: np.ndarray, values: np.ndarray,
                      counts: np.ndarray, own: bool = False) -> None:
        d = self._d
        for k, v, c in zip(keys.tolist(), values.tolist(), counts.tolist()):
            e = d.get(k)
            if e is None:
                d[k] = [int(v), int(c)]
            else:
                e[0] += int(v)
                e[1] += int(c)

    def take(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Remove ``keys`` (which must all be present) and return their
        (values, counts) — the migration extraction primitive."""
        vals = np.empty(keys.shape[0], dtype=np.int64)
        cnts = np.empty(keys.shape[0], dtype=np.int64)
        for i, k in enumerate(keys.tolist()):
            vals[i], cnts[i] = self._d.pop(k)
        return vals, cnts

    def items(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(keys, values, counts), sorted by key — the deterministic flush
        order shared by both backends."""
        ks = np.fromiter(self._d.keys(), dtype=np.int64, count=len(self._d))
        order = np.argsort(ks, kind="stable")
        ks = ks[order]
        vals = np.empty(ks.shape[0], dtype=np.int64)
        cnts = np.empty(ks.shape[0], dtype=np.int64)
        for i, k in enumerate(ks.tolist()):
            vals[i], cnts[i] = self._d[k]
        return ks, vals, cnts


class ArrayStateStore:
    """Vectorised open-addressing backend (ISSUE 4 tentpole).

    Power-of-two capacity, Fibonacci hashing, linear probing.  Batch update
    is fully vectorised: one ``np.unique`` over the chunk, one segment
    reduce per column, one bulk probe.  Deletion (migration ``take``)
    leaves tombstones that probe chains walk through; a rehash clears them.
    """

    backend = "array"

    def __init__(self, capacity: int = 64) -> None:
        cap = 1 << max(int(capacity) - 1, 1).bit_length()
        self._k = np.full(cap, _EMPTY, dtype=np.int64)
        self._v = np.zeros(cap, dtype=np.int64)
        self._c = np.zeros(cap, dtype=np.int64)
        self._n = 0      # live entries
        self._used = 0   # live entries + tombstones
        # sorted-unique single-merge fast path (fused pane flush): the
        # first merge into an empty table parks here and only builds the
        # hash table if the store is ever touched again
        self._lazy = None

    # -- hashing / probing ---------------------------------------------------------
    def _home(self, keys: np.ndarray) -> np.ndarray:
        cap = self._k.shape[0]
        shift = np.uint64(64 - int(cap).bit_length() + 1)
        h = (keys.astype(np.uint64) * _FIB) >> shift
        return h.astype(np.int64) & (cap - 1)

    def _probe(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Bulk lookup of unique ``keys``.  Returns (slot, first_free):
        ``slot[i]`` is the key's slot or -1 if absent; ``first_free[i]`` is
        the first tombstone/empty slot on its probe chain (the insertion
        point)."""
        cap = self._k.shape[0]
        mask = cap - 1
        idx = self._home(keys)
        slot = np.full(keys.shape[0], -1, dtype=np.int64)
        free = np.full(keys.shape[0], -1, dtype=np.int64)
        alive = np.arange(keys.shape[0], dtype=np.int64)
        for _ in range(cap):
            cur = idx[alive]
            slotk = self._k[cur]
            found = slotk == keys[alive]
            empty = slotk == _EMPTY
            is_free = empty | (slotk == _TOMB)
            record = is_free & (free[alive] == -1)
            free[alive[record]] = cur[record]
            slot[alive[found]] = cur[found]
            done = found | empty  # empty slot terminates the chain
            alive = alive[~done]
            if alive.shape[0] == 0:
                break
            idx[alive] = (idx[alive] + 1) & mask
        return slot, free

    def _insert_new(self, keys: np.ndarray) -> np.ndarray:
        """Insert unique, known-absent ``keys``; returns their slots.
        Distinct probe chains may race for the same free slot, so losers of
        each round re-probe — every round inserts at least one key."""
        out = np.full(keys.shape[0], -1, dtype=np.int64)
        pending = np.arange(keys.shape[0], dtype=np.int64)
        while pending.shape[0]:
            _, free = self._probe(keys[pending])
            _, first = np.unique(free, return_index=True)
            winners = np.zeros(free.shape[0], dtype=bool)
            winners[first] = True
            w = pending[winners]
            ws = free[winners]
            reused_tomb = self._k[ws] == _TOMB
            self._k[ws] = keys[w]
            self._v[ws] = 0
            self._c[ws] = 0
            out[w] = ws
            self._n += int(w.shape[0])
            self._used += int(w.shape[0] - reused_tomb.sum())
            pending = pending[~winners]
        return out

    def _slots_for(self, keys: np.ndarray, insert: bool) -> np.ndarray:
        slot, _ = self._probe(keys)
        absent = slot == -1
        if absent.any():
            if not insert:
                raise KeyError(
                    f"{int(absent.sum())} keys absent from ArrayStateStore")
            slot[absent] = self._insert_new(keys[absent])
        return slot

    def _maybe_grow(self, incoming: int) -> None:
        cap = self._k.shape[0]
        if (self._used + incoming) * 10 < cap * 6:
            return
        while (self._used + incoming) * 10 >= cap * 6:
            cap *= 2
        ks, vs, cs = self.items()
        self._k = np.full(cap, _EMPTY, dtype=np.int64)
        self._v = np.zeros(cap, dtype=np.int64)
        self._c = np.zeros(cap, dtype=np.int64)
        self._n = 0
        self._used = 0
        if ks.shape[0]:
            slots = self._insert_new(ks)
            self._v[slots] = vs
            self._c[slots] = cs

    def _materialize(self) -> None:
        """Fold a parked lazy merge into the hash table (first non-flush
        access only; the tumbling-pane hot path never gets here)."""
        if self._lazy is None:
            return
        ks, vs, cs = self._lazy
        self._lazy = None
        self._maybe_grow(ks.shape[0])
        if self._used == 0 and self._bulk_fill(ks, vs, cs):
            return
        slots = self._slots_for(ks, insert=True)
        self._v[slots] += vs
        self._c[slots] += cs

    # -- interface ------------------------------------------------------------
    @property
    def num_entries(self) -> int:
        if self._lazy is not None:
            return self._lazy[0].shape[0]
        return self._n

    def size_bytes(self) -> int:
        return self.num_entries * ENTRY_BYTES

    def update_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        self._materialize()
        uniq, inv = np.unique(np.asarray(keys, dtype=np.int64),
                              return_inverse=True)
        vsum = np.zeros(uniq.shape[0], dtype=np.int64)
        np.add.at(vsum, inv, np.asarray(values, dtype=np.int64))
        csum = np.bincount(inv, minlength=uniq.shape[0]).astype(np.int64)
        self._maybe_grow(uniq.shape[0])
        slots = self._slots_for(uniq, insert=True)
        self._v[slots] += vsum
        self._c[slots] += csum

    def merge_entries(self, keys: np.ndarray, values: np.ndarray,
                      counts: np.ndarray, own: bool = False) -> None:
        keys = np.asarray(keys, dtype=np.int64)
        if keys.shape[0] == 0:
            return
        if (self._lazy is None and self._n == 0 and self._used == 0
                and (keys.shape[0] == 1 or bool(np.all(keys[1:] > keys[:-1])))):
            vs = np.asarray(values, dtype=np.int64)
            cs = np.asarray(counts, dtype=np.int64)
            if not own:
                # defensive copies — the caller may mutate its arrays;
                # bulk producers (the fused pane flush) hand ownership
                # over instead and skip the ~MB of memcpy per flush
                keys, vs, cs = keys.copy(), vs.copy(), cs.copy()
            self._lazy = (keys, vs, cs)
            return
        self._materialize()
        self._maybe_grow(keys.shape[0])
        if self._used == 0 and self._bulk_fill(keys, values, counts):
            return
        slots = self._slots_for(keys, insert=True)
        self._v[slots] += np.asarray(values, dtype=np.int64)
        self._c[slots] += np.asarray(counts, dtype=np.int64)

    def _bulk_fill(self, keys: np.ndarray, values: np.ndarray,
                   counts: np.ndarray) -> bool:
        """One-pass placement of unique ``keys`` into an *empty* table —
        the fused engine's pane-flush hot path (each tumbling pane store
        receives exactly one merge).  Placing in home-slot order with a
        running ``max(home, prev + 1)`` yields the same contiguous probe
        chains as sequential insertion, so later lookups are unaffected.
        Bails (False) on the rare wrap past the table end."""
        n = keys.shape[0]
        hm = self._home(keys)
        order = np.argsort(hm, kind="stable")
        h = hm[order]
        ar = np.arange(n, dtype=np.int64)
        slots = np.maximum.accumulate(h - ar) + ar
        if slots[-1] >= self._k.shape[0]:
            return False
        self._k[slots] = keys[order]
        self._v[slots] = np.asarray(values, dtype=np.int64)[order]
        self._c[slots] = np.asarray(counts, dtype=np.int64)[order]
        self._n = self._used = n
        return True

    def take(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        self._materialize()
        keys = np.asarray(keys, dtype=np.int64)
        if keys.shape[0] == 0:
            return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))
        slots = self._slots_for(keys, insert=False)
        vals = self._v[slots].copy()
        cnts = self._c[slots].copy()
        self._k[slots] = _TOMB
        self._v[slots] = 0
        self._c[slots] = 0
        self._n -= int(keys.shape[0])
        return vals, cnts

    def items(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self._lazy is not None:
            return self._lazy
        live = np.flatnonzero(self._k >= 0)
        ks = self._k[live]
        order = np.argsort(ks, kind="stable")
        live = live[order]
        return ks[order], self._v[live].copy(), self._c[live].copy()


class DeviceStateStore:
    """Device-resident backend (ISSUE 6): the sorted slot table and int32
    (value, count) accumulators live as jax device arrays, and folding a
    reduced chunk is one probe/accumulate launch per column
    (:func:`repro.kernels.ops.store_probe` — the Pallas kernel on TPU, a
    ``searchsorted`` fallback elsewhere; two launches because the kernel
    accumulates one value column at a time).  A sorted host int64 key
    mirror keeps membership checks, sizing and ``items`` ordering
    off-device; inserting unseen keys rebuilds the device table around
    them (the open-addressing slow path — rare once the key set is warm).

    Accumulation is generational (ISSUE 10): the device arrays are an
    int32 *young generation* — the kernel's probe/accumulate domain, with
    inputs range-checked per merge — and a host int64 *lifetime base*
    (``_base_v``/``_base_c``) carries totals beyond int32.  A conservative
    running bound on the young generation's magnitude (the sum of per-merge
    chunk bounds) triggers a spill — read the young columns back, add into
    the base, zero the device arrays — strictly before any element could
    reach 2³¹−1, so lifetime aggregates are exact at the ROADMAP's
    10⁸-tuple scale (``repro.analysis.contracts.SCALE_TARGET``) without
    enabling x64 on device.  ``items``/``take`` return base + young."""

    backend = "device"

    def __init__(self) -> None:
        self._host_keys = np.empty(0, dtype=np.int64)  # sorted mirror
        self._keys = None  # device int32, sorted ascending (lazy)
        self._v = None     # device int32 young-gen value accumulators
        self._c = None     # device int32 young-gen count accumulators
        self._base_v = np.empty(0, dtype=np.int64)  # host lifetime base
        self._base_c = np.empty(0, dtype=np.int64)
        self._young_bound = 0  # ≥ max |young element|, per-merge accumulated

    # -- interface ------------------------------------------------------------
    @property
    def num_entries(self) -> int:
        return int(self._host_keys.shape[0])

    def size_bytes(self) -> int:
        return int(self._host_keys.shape[0]) * ENTRY_BYTES

    def update_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        uniq, inv = np.unique(np.asarray(keys, dtype=np.int64),
                              return_inverse=True)
        vsum = np.zeros(uniq.shape[0], dtype=np.int64)
        np.add.at(vsum, inv, np.asarray(values, dtype=np.int64))
        csum = np.bincount(inv, minlength=uniq.shape[0]).astype(np.int64)
        self._merge(uniq, vsum, csum)

    def merge_entries(self, keys: np.ndarray, values: np.ndarray,
                      counts: np.ndarray, own: bool = False) -> None:
        self._merge(np.asarray(keys, dtype=np.int64),
                    np.asarray(values, dtype=np.int64),
                    np.asarray(counts, dtype=np.int64))

    def _merge(self, uniq: np.ndarray, vsum: np.ndarray,
               csum: np.ndarray) -> None:
        """Fold per-key reduced (value, count) columns into the device
        table.  ``uniq`` must be sorted unique (both callers guarantee
        it)."""
        import jax.numpy as jnp

        from ..kernels import ops

        n = uniq.shape[0]
        if n == 0:
            return
        lim = 2 ** 31 - 1
        if uniq[0] < 0 or uniq[-1] > lim:
            raise ValueError(
                "DeviceStateStore keys must fit int32 (got range "
                f"[{uniq[0]}, {uniq[-1]}])")
        chunk_bound = int(max(np.abs(vsum).max(initial=0),
                              np.abs(csum).max(initial=0)))
        if chunk_bound > lim:
            raise ValueError(
                "DeviceStateStore accumulates in int32; chunk aggregates "
                "exceed its range")
        # spill young → base before this chunk could push any young
        # element past int32 (each merge adds ≤ chunk_bound per element)
        if self._young_bound + chunk_bound > lim:
            self._spill()
        pos = np.searchsorted(self._host_keys, uniq)
        k = self._host_keys.shape[0]
        posc = np.clip(pos, 0, max(k - 1, 0))
        present = ((pos < k) & (self._host_keys[posc] == uniq)) if k else (
            np.zeros(n, dtype=bool))
        missing = uniq[~present]
        if missing.shape[0]:
            union = np.sort(np.concatenate([self._host_keys, missing]))
            nv = jnp.zeros(union.shape[0], jnp.int32)
            nc = jnp.zeros(union.shape[0], jnp.int32)
            nbv = np.zeros(union.shape[0], dtype=np.int64)
            nbc = np.zeros(union.shape[0], dtype=np.int64)
            if k:
                old_pos = np.searchsorted(union, self._host_keys)
                nv = nv.at[jnp.asarray(old_pos)].set(self._v)
                nc = nc.at[jnp.asarray(old_pos)].set(self._c)
                nbv[old_pos] = self._base_v
                nbc[old_pos] = self._base_c
            self._host_keys = union
            self._keys = jnp.asarray(union.astype(np.int32))
            self._v = nv
            self._c = nc
            self._base_v = nbv
            self._base_c = nbc
        keys32 = jnp.asarray(uniq.astype(np.int32))
        vacc, _, _ = ops.store_probe(self._keys, keys32,
                                     jnp.asarray(vsum.astype(np.int32)))
        cacc, _, _ = ops.store_probe(self._keys, keys32,
                                     jnp.asarray(csum.astype(np.int32)))
        # int32-overflow(baselined): young-gen adds are bounded by the
        # _young_bound spill guard above — lifetime totals live in the
        # int64 base
        self._v = self._v + vacc
        self._c = self._c + cacc
        self._young_bound += chunk_bound

    def _spill(self) -> None:
        """Fold the int32 young generation into the int64 lifetime base
        and zero the device accumulators (one readback; amortized over
        ~2³¹/chunk_bound merges)."""
        import jax.numpy as jnp

        if self._v is not None and self._host_keys.shape[0]:
            self._base_v = self._base_v + np.asarray(self._v,
                                                     dtype=np.int64)
            self._base_c = self._base_c + np.asarray(self._c,
                                                     dtype=np.int64)
            self._v = jnp.zeros_like(self._v)
            self._c = jnp.zeros_like(self._c)
        self._young_bound = 0

    def take(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp

        keys = np.asarray(keys, dtype=np.int64)
        if keys.shape[0] == 0:
            return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))
        k = self._host_keys.shape[0]
        pos = np.searchsorted(self._host_keys, keys)
        posc = np.clip(pos, 0, max(k - 1, 0))
        ok = ((pos < k) & (self._host_keys[posc] == keys)) if k else (
            np.zeros(keys.shape[0], dtype=bool))
        if not ok.all():
            raise KeyError(
                f"{int((~ok).sum())} keys absent from DeviceStateStore")
        v = np.asarray(self._v, dtype=np.int64)
        c = np.asarray(self._c, dtype=np.int64)
        vals = (self._base_v[pos] + v[pos]).copy()
        cnts = (self._base_c[pos] + c[pos]).copy()
        keep = np.ones(k, dtype=bool)
        keep[pos] = False
        self._host_keys = self._host_keys[keep]
        self._keys = jnp.asarray(self._host_keys.astype(np.int32))
        self._v = jnp.asarray(v[keep].astype(np.int32))
        self._c = jnp.asarray(c[keep].astype(np.int32))
        self._base_v = self._base_v[keep]
        self._base_c = self._base_c[keep]
        return vals, cnts

    def items(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self._host_keys.shape[0] == 0:
            return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int64))
        return (self._host_keys.copy(),
                self._base_v + np.asarray(self._v, dtype=np.int64),
                self._base_c + np.asarray(self._c, dtype=np.int64))


STORE_BACKENDS = {"dict": DictStateStore, "array": ArrayStateStore,
                  "device": DeviceStateStore}


def make_store(backend: str):
    try:
        return STORE_BACKENDS[backend]()
    except KeyError:
        raise ValueError(f"unknown state-store backend {backend!r}; one of "
                         f"{sorted(STORE_BACKENDS)}")
