"""Keyed operator-state subsystem (ISSUE 4 tentpole).

Real downstream operator state for the grouped edges of a topology:
per-worker state stores in two backends (:mod:`.store`), windowed stateful
operators with split-key partials (:mod:`.window`), the downstream merge +
the routing-free oracle (:mod:`.merge`), and the state-migration protocol
under churn (:mod:`.migration`).

Attach a :class:`WindowOp` to a :class:`repro.topology.Stage` and both
topology engines maintain the state, account migration cost on membership
events, and emit partial aggregates into a downstream merge stage; see
DESIGN.md §9.
"""

from .merge import direct_aggregate, merge_partials, topk_cut
from .migration import MigrationStats, apply_membership_change
from .store import (ENTRY_BYTES, STORE_BACKENDS, ArrayStateStore,
                    DictStateStore, make_store)
from .window import (KeyedStateManager, StateReport, WindowOp, WindowPartial,
                     tuple_values)

__all__ = [
    "ENTRY_BYTES",
    "STORE_BACKENDS",
    "ArrayStateStore",
    "DictStateStore",
    "make_store",
    "WindowOp",
    "WindowPartial",
    "StateReport",
    "KeyedStateManager",
    "tuple_values",
    "merge_partials",
    "direct_aggregate",
    "topk_cut",
    "MigrationStats",
    "apply_membership_change",
]
