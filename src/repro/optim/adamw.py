"""AdamW (with optional Adafactor-style factored second moment), gradient
clipping, cosine schedule, and ZeRO-friendly state.

Optimizer states are elementwise (or factored) pytrees of the params, so
they inherit the params' sharding (including the ZeRO dp-dim sharding from
``models.sharding``).  Two memory levers for the 1T-param config (kimi-k2
would not fit fp32 m/v in 16 GB HBM — DESIGN.md §3):

* ``state_dtype='bfloat16'`` keeps m (and unfactored v) in bf16;
* ``factored_v=True`` replaces v with per-row/per-column accumulators for
  rank>=2 leaves (Adafactor, arXiv:1804.04235) — O(n+m) instead of O(nm).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "cosine_schedule", "global_norm", "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"
    factored_v: bool = False


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any   # per-leaf: array, or {"r": ..., "c": ...} when factored


def _is_factored(p, cfg: AdamWConfig) -> bool:
    return cfg.factored_v and p.ndim >= 2


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)

    def zeros_v(p):
        if _is_factored(p, cfg):
            return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, dt)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dt), params),
        v=jax.tree_util.tree_map(zeros_v, params),
    )


def opt_state_specs(params, pspecs, cfg: AdamWConfig):
    """PartitionSpec trees for (m, v) matching init_opt_state's structure."""
    from jax.sharding import PartitionSpec as P

    m_specs = pspecs

    def v_spec(p, spec):
        if _is_factored(p, cfg):
            parts = list(spec) + [None] * (p.ndim - len(spec))
            return {"r": P(*parts[:-1]),
                    "c": P(*(parts[:-2] + parts[-1:]))}
        return spec

    v_specs = jax.tree_util.tree_map(v_spec, params, pspecs)
    return m_specs, v_specs


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


_NO_DECAY = ("scale", "bias", "a_log", "dt_bias", "d_skip", "lambda",
             "norm", "b_in", "b_out", "bq", "bk", "bv", "bo")


def _decay_mask(params):
    def mask(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        return not any(name.endswith(s) or f"/{s}" in name for s in _NO_DECAY)

    return jax.tree_util.tree_map_with_path(mask, params)


def adamw_update(
    grads, state: OptState, params, cfg: AdamWConfig,
) -> Tuple[Any, OptState, dict]:
    """One AdamW / factored-AdamW step.  Returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    decay_mask = _decay_mask(params)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p, do_decay):
        gf = g.astype(jnp.float32) * clip
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        mhat = mf / b1c
        if _is_factored(p, cfg):
            g2 = jnp.square(gf) + 1e-30
            r = cfg.b2 * v["r"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            c = cfg.b2 * v["c"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            # Adafactor rank-1 reconstruction: V̂ = (R ⊗ C) / mean(R)
            rmean = jnp.mean(r, axis=-1, keepdims=True)
            vhat = (r / jnp.maximum(rmean, 1e-30))[..., None] * c[..., None, :]
            new_v = {"r": r, "c": c}
        else:
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
            vhat = vf / b2c
            new_v = vf.astype(sdt)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if do_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mf.astype(sdt), new_v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mask = treedef.flatten_up_to(decay_mask)
    out = [upd(g, m, v, p, dm) for g, m, v, p, dm in
           zip(flat_g, flat_m, flat_v, flat_p, flat_mask)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
