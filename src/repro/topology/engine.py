"""Topology engines — one protocol, two implementations (ISSUE 3 tentpole),
executed through incremental streaming sessions (ISSUE 5 tentpole).

:class:`Engine` is the protocol: ``open(topology) -> Session`` for
incremental record-batch execution, with ``run(topology, source, events) ->
TopologyReport`` kept as the one-shot convenience (open / advance / feed
every batch / close — feeding the whole stream as one batch is
bit-identical to ``run``).  A :class:`Session` carries per-edge state
across feeds: per-worker FIFO backlog (:class:`~repro.core.EdgeState`),
grouper epoch state, remap accountants and keyed-state managers all
survive between ``feed`` calls, so hot-key flips can straddle feed
boundaries exactly like they do in a long-running DSPE.  Events registered
via ``advance`` may address the stream by tuple index or by timestamp
(``at_time``) and fire when the addressed tuple is fed.  Implementations:

* :class:`SimulatorEngine` — the DSPE discrete-event simulator.  Each
  grouped edge runs through :func:`repro.core.stream.simulate_edge`
  (``mode="batched"``: segment-wise closed-form FIFO; ``mode="reference"``:
  the per-tuple oracle interpreter), and the *finish* times of one stage
  become the arrival times of the next — per-stage FIFO queues chained
  through the DAG.  Time is in seconds.
* :class:`ServingTopologyEngine` — the continuous-batching
  :class:`~repro.serving.engine.ServingEngine` adapter: every edge is a
  replica pool with slot-limited decode, each tuple a 1-token request keyed
  by its (session) key.  Time is in scheduler ticks.  The source stream is
  subsampled to ``max_requests`` (per-tick scheduling is Python-loop work).

Both return the same :class:`TopologyReport`: per-edge latency percentiles,
imbalance, memory overhead and remap accounting (one :class:`EdgeReport`
per edge) plus end-to-end source→sink latencies — replacing the three
ad-hoc metric shapes (``StreamMetrics`` rows, serving dicts, scenario
dicts) that predated the topology API.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from ..core.stream import (CapacityEvent, MembershipEvent, edge_metrics,
                           simulate_edge)
from ..obs.telemetry import get_telemetry
from ..state.migration import MigrationBiller
from ..state.window import KeyedStateManager, StateReport
from .configs import build_grouper
from .graph import (SOURCE, Edge, RecordBatch, ScopedEvent, Source, Stage,
                    Topology)

__all__ = [
    "EdgeReport",
    "FeedReceipt",
    "TopologyReport",
    "Engine",
    "Session",
    "RemapAccountant",
    "SimulatorEngine",
    "SimulatorSession",
    "ServingTopologyEngine",
    "ServingSession",
]


# ---------------------------------------------------------------------------
# unified reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EdgeReport:
    """One grouped edge's metrics — the same schema from either engine.

    Latency/throughput units are the engine's clock (seconds for the DSPE
    simulator, scheduler ticks for the serving engine); the normalised
    metrics (imbalance, memory_overhead_norm, remap_frac_mean) are unitless
    and comparable across engines.
    """

    edge: str
    src: str
    dst: str
    scheme: str
    workers: int
    n_tuples: int
    execution_time: float
    latency_avg: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    throughput: float
    memory_overhead: int
    memory_overhead_norm: float
    imbalance: float
    remap_events: List[Dict] = dataclasses.field(default_factory=list)
    remap_frac_mean: Optional[float] = None
    dropped: int = 0
    # host↔device launches this edge made across the session (ISSUE 6) —
    # the fused engine's "one dispatch per steady-state feed" evidence;
    # the host engines report 0
    dispatches: int = 0
    # keyed operator state (ISSUE 4) — populated when the destination stage
    # carries a WindowOp; state_bytes is the peak Σ_w store bytes (the
    # *measured* counterpart of the memory_overhead key-replica proxy)
    state_bytes: Optional[int] = None
    state_entries: Optional[int] = None
    partial_entries: Optional[int] = None
    migration_bytes: int = 0
    tuples_replayed: int = 0
    # ISSUE 8 observability: ingress-queue pressure + admission + the
    # engine-clock stall billed for migrated keyed state.  The serving
    # engine fills the queue/in-flight/shed columns (its ingress queues are
    # real); the virtual-time simulator reports 0 there but does bill
    # migration_stall (seconds added to destination workers' busy time).
    queue_depth_peak: int = 0
    in_flight_peak: int = 0
    shed: int = 0
    time_in_queue_avg: float = 0.0
    time_in_queue_p99: float = 0.0
    migration_stall: float = 0.0

    def row(self) -> Dict[str, float]:
        """The paper-metric columns (same keys as ``StreamMetrics.row``)."""
        return {
            "execution_time": self.execution_time,
            "latency_avg": self.latency_avg,
            "latency_p50": self.latency_p50,
            "latency_p95": self.latency_p95,
            "latency_p99": self.latency_p99,
            "throughput": self.throughput,
            "memory_overhead": self.memory_overhead,
            "memory_overhead_norm": self.memory_overhead_norm,
            "imbalance": self.imbalance,
        }

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TopologyReport:
    """Whole-topology outcome: per-edge reports + end-to-end latency of each
    sink tuple measured from its *root* source tuple's arrival."""

    engine: str
    topology: str
    n_source_tuples: int
    total_time: float
    e2e_latency_avg: float
    e2e_latency_p50: float
    e2e_latency_p95: float
    e2e_latency_p99: float
    edges: List[EdgeReport] = dataclasses.field(default_factory=list)
    # keyed operator state (ISSUE 4): per-operator-stage summaries (incl.
    # the merged per-window results) + topology-wide migration cost
    state: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    migration_bytes: int = 0
    tuples_replayed: int = 0
    # ISSUE 8 open-loop accounting.  ``shed`` / ``queue_depth_peak`` /
    # ``migration_stall`` aggregate the edge columns at close; the offered /
    # deferred / residual / time-in-queue / autoscale columns are stamped by
    # the open-loop driver (:mod:`repro.load`) — a closed-loop run reports
    # offered == n_source_tuples and zeros elsewhere.
    offered: int = 0
    shed: int = 0
    deferred: int = 0
    residual: int = 0
    queue_depth_peak: int = 0
    time_in_queue_avg: float = 0.0
    time_in_queue_p99: float = 0.0
    migration_stall: float = 0.0
    autoscale_events: List[Dict] = dataclasses.field(default_factory=list)
    # ISSUE 9 telemetry: the session's downsampled metric timeline +
    # metrics snapshot (``Telemetry.timeline_dict``).  ``None`` whenever
    # telemetry is disabled, and then *omitted* from ``to_dict`` — report
    # dicts stay bit-identical to pre-telemetry output.
    timeline: Optional[Dict] = None

    def edge(self, name: str) -> EdgeReport:
        """Lookup by full edge name (``"src->dst"``) or by dst stage."""
        for er in self.edges:
            if er.edge == name or er.dst == name:
                return er
        raise KeyError(f"no edge {name!r} in topology {self.topology!r}")

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        if d.get("timeline") is None:
            d.pop("timeline", None)
        return d


@dataclasses.dataclass
class FeedReceipt:
    """What ``Session.feed`` hands back per batch (ISSUE 8): the feedback
    channel an open-loop driver closes its control loops over — admission
    control watches ``backlog``/``queue_depth``, the p99 autoscaler watches
    ``latency_p99`` — without waiting for the close-time report.

    Units are the engine's clock (seconds for the DSPE simulator, scheduler
    ticks for the serving engine).  ``latencies`` holds this feed's raw
    per-tuple source-edge service latencies (serving: the latencies of
    requests that *finished* during this feed); ``backlog`` is how far the
    slowest source-edge worker's busy-until runs past the stream clock
    (serving: current total queued requests)."""

    n: int
    t_end: float
    latency_avg: float = 0.0
    latency_p99: float = 0.0
    backlog: float = 0.0
    latencies: Optional[np.ndarray] = None
    # serving-engine extras (the simulator reports 0: feeding is
    # instantaneous in virtual time, so nothing queues inside the engine)
    queue_depth: int = 0
    in_flight: int = 0
    done: int = 0
    shed: int = 0


@runtime_checkable
class Session(Protocol):
    """One streaming session: incremental execution of one topology.

    Lifecycle (ISSUE 5): ``Engine.open(topology)`` → any interleaving of
    ``feed(batch)`` (ingest the next :class:`RecordBatch`; batches must be
    time-ordered) and ``advance(events)`` (register membership/capacity
    events, addressed by per-stage tuple index or by ``at_time``) →
    ``close()`` (flush open windows, release operator partial streams
    through their downstream subtrees, and return the same
    :class:`TopologyReport` schema ``run`` produces).  All per-edge state —
    FIFO backlog, grouper epochs, keyed window state, remap accounting —
    carries across feeds.  ``feed`` returns a per-batch
    :class:`FeedReceipt` (``None`` for an empty batch) — ISSUE 8's
    open-loop feedback channel; closed-loop callers are free to ignore it.
    """

    def feed(self, batch: RecordBatch) -> Optional[FeedReceipt]:
        ...

    def advance(self, events: Sequence[ScopedEvent]) -> None:
        ...

    def close(self) -> TopologyReport:
        ...


@runtime_checkable
class Engine(Protocol):
    """One engine protocol: execute a topology against a source stream,
    either one-shot (``run``) or incrementally (``open`` → session)."""

    name: str

    def open(self, topology: Topology, *,
             arrival_rate: Optional[float] = None,
             telemetry: Optional[object] = None) -> Session:
        ...

    def run(self, topology: Topology, source: Source,
            events: Sequence[ScopedEvent] = ()) -> TopologyReport:
        ...


def _run_via_session(engine, topology: Topology, source: Source,
                     events: Sequence[ScopedEvent]) -> TopologyReport:
    """The one-shot path is literally a session: open, register the events,
    feed every batch, close.  With the array-form Source (one batch) this
    is bit-identical to the pre-session engines."""
    session = engine.open(topology, arrival_rate=source.arrival_rate)
    if events:
        session.advance(events)
    for batch in source.iter_batches():
        session.feed(batch)
    return session.close()


class _BaseSession:
    """Shared session mechanics — event registration, feed validation and
    close-time report assembly; everything engine-specific (how a feed
    executes, what state an edge carries) lives in the subclasses."""

    def __init__(self, engine, topology: Topology, telemetry=None):
        self.engine = engine
        self.topology = topology
        self._edges = topology.ordered_edges()
        self._sinks = set(topology.sinks())
        self._st: Dict[str, object] = {}
        self._pending: Dict[str, List] = {e.dst: [] for e in self._edges}
        self._n_source = 0
        self._last_ts = -np.inf
        self._total_time = 0.0
        self._e2e: List[np.ndarray] = []
        self._report: Optional[TopologyReport] = None
        # ISSUE 9: explicit bundle wins; otherwise the process default —
        # which, when disabled, hands each session a private no-op bundle
        self.telemetry = (telemetry if telemetry is not None
                          else get_telemetry().for_session())
        self._feed_idx = -1
        tel = self.telemetry
        self._c_feeds = tel.metrics.counter("session.feeds")
        self._c_mem_events = tel.metrics.counter("session.membership_events")
        self._c_cap_events = tel.metrics.counter("session.capacity_events")

    def _session_observer(self):
        """Event-observer stage stamping membership/capacity events into
        the telemetry bundle (counters always; trace instants when
        enabled).  Chained after the per-edge accountant/manager."""
        tel = self.telemetry
        tr = tel.tracer
        c_mem = self._c_mem_events
        c_cap = self._c_cap_events

        def call(kind, grouper, event):
            if kind == "post_membership":
                c_mem.add(1)
                tr.instant("event.membership", cat="session",
                           at=int(event.at), workers=len(event.workers))
            elif kind == "capacity":
                c_cap.add(1)
                tr.instant("event.capacity", cat="session",
                           at=int(event.at), workers=len(event.capacities))

        return call

    def advance(self, events: Sequence[ScopedEvent]) -> None:
        """Register membership/capacity events for subsequent feeds.  Each
        event addresses its stage's *input* stream by tuple index (``at``,
        stream-global) or timestamp (``at_time``); an index/timestamp the
        stream never reaches means the event never fires."""
        self._check_open()
        for se in events:
            if not isinstance(se, ScopedEvent):
                raise TypeError(
                    f"advance takes ScopedEvent(stage, event) wrappers, "
                    f"got {type(se).__name__}")
            if se.stage not in self._pending:
                raise ValueError(f"no stage named {se.stage!r} in topology "
                                 f"{self.topology.name!r}")
            ev = se.event
            if getattr(ev, "at_time", None) is None and ev.at < 0:
                # at=-1 is the "address me via at_time()" placeholder; an
                # event still carrying it was built but never addressed
                raise ValueError(
                    f"event for stage {se.stage!r} has no address: give "
                    f"at= (tuple index) or wrap with at_time(event, t)")
            self._pending[se.stage].append(ev)

    def close(self) -> TopologyReport:
        """Flush open windows, release operator partial streams through
        their downstream subtrees, and report (same schema as ``run``)."""
        self._check_open()
        close_span = self.telemetry.tracer.span(
            "session.close", cat="session", topology=self.topology.name)
        state: Dict[str, Dict] = {}
        self._close_pump(state)
        reports = [self._edge_report(e) for e in self._edges]
        lats = np.concatenate(self._e2e) if self._e2e else np.empty(0)
        avg, p50, p95, p99 = _percentiles(lats)
        self._report = TopologyReport(
            engine=self.engine.name, topology=self.topology.name,
            n_source_tuples=self._n_source, total_time=self._total_time,
            e2e_latency_avg=avg, e2e_latency_p50=p50, e2e_latency_p95=p95,
            e2e_latency_p99=p99, edges=reports, state=state,
            migration_bytes=sum(r.migration_bytes for r in reports),
            tuples_replayed=sum(r.tuples_replayed for r in reports),
            # closed-loop default: everything fed was offered; the open-loop
            # driver overwrites these with its admission accounting
            offered=self._n_source,
            shed=sum(r.shed for r in reports),
            queue_depth_peak=max((r.queue_depth_peak for r in reports),
                                 default=0),
            migration_stall=sum(r.migration_stall for r in reports),
            timeline=self.telemetry.timeline_dict(),
        )
        close_span.done()
        return self._report

    # -- shared internals ------------------------------------------------------
    def _check_open(self) -> None:
        if self._report is not None:
            raise RuntimeError("session is closed")

    def _check_batch(self, batch: RecordBatch) -> bool:
        """Validate a feed (type, emptiness, cross-feed time ordering) and
        advance the stream clock.  Returns False for an empty batch."""
        self._check_open()
        if not isinstance(batch, RecordBatch):
            raise TypeError(
                f"feed takes a RecordBatch, got {type(batch).__name__}")
        if len(batch) == 0:
            return False
        ts = batch.timestamps
        if float(ts[0]) < self._last_ts:
            raise ValueError(
                f"batches must be time-ordered: this feed starts at "
                f"t={float(ts[0]):g} but the stream is already at "
                f"t={self._last_ts:g}")
        self._last_ts = float(ts[-1])
        return True

    def _zero_report(self, edge: Edge, stage: Stage) -> EdgeReport:
        """The report row of an edge that never received a tuple."""
        return EdgeReport(
            edge=edge.name, src=edge.src, dst=edge.dst,
            scheme=edge.grouping.scheme, workers=stage.parallelism,
            n_tuples=0, execution_time=0.0, latency_avg=0.0,
            latency_p50=0.0, latency_p95=0.0, latency_p99=0.0,
            throughput=0.0, memory_overhead=0, memory_overhead_norm=0.0,
            imbalance=0.0)


def _due_events(pending: List, offset: int, times: np.ndarray):
    """Split a stage's pending events into the ones due within this feed's
    index window ``[offset, offset + len(times))`` — rewritten to feed-local
    indices — and the rest, which stay pending.  Time-addressed events
    resolve against this feed's input timestamps (first tuple at or after
    the timestamp); a timestamp that already slipped past (it fell between
    two feeds) fires at the feed's first tuple, and one past the fed stream
    stays pending (never firing if the stream ends first, mirroring an
    out-of-range index)."""
    n = int(times.shape[0])
    due, keep = [], []
    for e in pending:
        t = getattr(e, "at_time", None)
        if t is not None:
            if n == 0 or t > times[-1]:
                keep.append(e)
                continue
            at = offset + int(np.searchsorted(times, t, side="left"))
            e = dataclasses.replace(e, at=at, at_time=None)
        if e.at < offset + n:
            due.append(dataclasses.replace(e, at=max(e.at - offset, 0)))
        else:
            keep.append(e)
    return due, keep


# ---------------------------------------------------------------------------
# remap accounting (Fig. 17 "keys moved per membership event")
# ---------------------------------------------------------------------------


class RemapAccountant:
    """Event observer that probes a fixed key sample around each membership
    event and counts primary-route changes (works against any grouper via
    ``probe_route``; schemes with no key affinity report ``None``).

    ``offset`` rebases the recorded event position onto the stream-global
    index: sessions hand :func:`simulate_edge` feed-local events, so they
    set it to the feed's base index before each feed (0 for one-shot runs,
    keeping the reported rows identical to the pre-session engines).

    ``metrics`` (ISSUE 9): an optional :class:`repro.obs.MetricsRegistry`
    — the per-event rows stay the report source of truth, but the run
    totals (events seen, keys moved, keys sampled) are mirrored into
    ``remap.*`` counters so ``repro.obs summarize`` sees them without
    re-walking every report."""

    def __init__(self, sample_keys: Sequence, metrics=None):
        self.sample = list(sample_keys)
        self.offset = 0
        self.per_event: List[Dict] = []
        self._before: Optional[List[Optional[int]]] = None
        self._c_events = (metrics.counter("remap.events")
                          if metrics is not None else None)
        self._c_moved = (metrics.counter("remap.keys_moved")
                         if metrics is not None else None)
        self._c_sampled = (metrics.counter("remap.keys_sampled")
                           if metrics is not None else None)

    def extend_sample(self, keys: Sequence, cap: int) -> None:
        """Grow the probe sample with unseen keys (up to ``cap``): sessions
        call this per feed while events are outstanding, so keys that first
        appear in later feeds — a post-flip hot head — are probed too."""
        have = set(self.sample)
        for k in keys:
            if len(self.sample) >= cap:
                break
            if k not in have:
                have.add(k)
                self.sample.append(k)

    def __call__(self, kind: str, grouper, event) -> None:
        if kind == "pre_membership":
            self._before = [grouper.probe_route(k) for k in self.sample]
        elif kind == "post_membership":
            after = [grouper.probe_route(k) for k in self.sample]
            row = {"at": int(event.at) + self.offset,
                   "sampled": len(self.sample)}
            if self.sample and after[0] is not None:
                moved = sum(1 for a, b in zip(self._before, after) if a != b)
                row["moved"] = moved
                row["frac"] = moved / len(self.sample)
            else:  # scheme with no key affinity (SG)
                row["moved"] = None
                row["frac"] = None
            self.per_event.append(row)
            self._before = None
            if self._c_events is not None:
                self._c_events.add(1)
                self._c_sampled.add(row["sampled"])
                if row["moved"] is not None:
                    self._c_moved.add(row["moved"])

    def frac_mean(self) -> Optional[float]:
        fracs = [e["frac"] for e in self.per_event if e["frac"] is not None]
        return float(np.mean(fracs)) if fracs else None


def _sample_keys(keys: np.ndarray, cap: int) -> List[int]:
    uniq = np.unique(np.asarray(keys))
    if uniq.shape[0] > cap:
        uniq = uniq[np.linspace(0, uniq.shape[0] - 1, cap).astype(np.int64)]
    return [int(k) for k in uniq]


def _percentiles(lats: np.ndarray):
    if lats.size == 0:
        return 0.0, 0.0, 0.0, 0.0
    return (float(lats.mean()), float(np.percentile(lats, 50)),
            float(np.percentile(lats, 95)), float(np.percentile(lats, 99)))


def _imbalance(counts: np.ndarray) -> float:
    counts = counts.astype(np.float64)
    return float((counts.max() - counts.mean())
                 / max(counts.mean(), 1e-12)) if counts.size else 0.0


def _chain_observers(*observers):
    """Fan one event-observer callback out to several consumers (remap
    accountant + keyed-state manager)."""

    def call(kind, grouper, event):
        for o in observers:
            o(kind, grouper, event)

    return call


def _fish_epoch_observer(telemetry, grouper):
    """Per-epoch FISH telemetry for the host engines (ISSUE 9): hooked onto
    :attr:`EpochFrequencyTracker.epoch_observer`, fired at every
    TimeDecayingUpdate.  Emits the hot-set size, its churn vs the previous
    epoch, and per-worker imbalance — each stamped with the epoch index —
    plus a ``fish.epoch_decay`` trace instant.  (The fused engine emits the
    same series from the device-resident tracker after epoch-crossing
    segments.)"""
    tel = telemetry
    prev_hot: set = set()

    def on_epoch(tracker) -> None:
        epoch_idx = tracker.epochs_completed
        tel.ctx.epoch_idx = epoch_idx
        theta = tracker.params.theta(grouper.num_workers)
        hot = set(tracker.hot_keys(grouper.num_workers))
        churn = len(hot ^ prev_hot)
        tl = tel.timeline
        tl.point("fish.hot_set_size", len(hot), epoch_idx=epoch_idx)
        tl.point("fish.hot_set_churn", churn, epoch_idx=epoch_idx)
        counts = grouper.assigned_counts
        if counts.size and counts.sum() > 0:
            mean = counts.mean()
            tl.point("fish.worker_imbalance",
                     float(counts.max() / max(mean, 1e-12)),
                     epoch_idx=epoch_idx)
        tel.tracer.instant("fish.epoch_decay", cat="fish", epoch=epoch_idx,
                           hot_set=len(hot), theta=theta)
        prev_hot.clear()
        prev_hot.update(hot)

    return on_epoch


def _stage_manager(stage: Stage) -> Optional[KeyedStateManager]:
    return (KeyedStateManager(stage.operator)
            if stage.operator is not None else None)


def _state_extra(srep: Optional[StateReport]) -> Dict:
    """The EdgeReport state columns for an operator stage (ISSUE 4) —
    shared by both engines so the schema cannot drift."""
    if srep is None:
        return {}
    from ..state.store import ENTRY_BYTES

    return dict(state_bytes=srep.state_bytes_peak,
                state_entries=srep.state_bytes_peak // ENTRY_BYTES,
                partial_entries=srep.partial_entries,
                migration_bytes=srep.migration_bytes,
                tuples_replayed=srep.tuples_replayed)


def _emit_partials(partials, finishes: np.ndarray, in_roots: np.ndarray,
                   fallback_time: float):
    """The stream a batch of flushed window partials emits downstream: one
    partial-aggregate tuple per state entry, keyed by the aggregation key
    and released when its worker flushed the window (the finish time of
    that worker's last tuple in the window; ``fallback_time`` covers
    entries whose anchor tuple never finished — the serving engine's
    dropped requests).  Partial tuples carry no payload column.  Sessions
    call this per feed with the windows that closed during it (incremental
    emission — ISSUE 6 satellite) and once more at close with the
    remainder."""
    if not partials:
        return (np.empty(0, dtype=np.int64), np.empty(0),
                np.empty(0, dtype=np.int64), None)
    # release time and root are constant within a partial, so the stable
    # element sort collapses to a stable sort of the partials themselves
    last = np.array([p.last_index for p in partials], dtype=np.int64)
    t_p = finishes[last]
    t_p = np.where(t_p >= 0.0, t_p, fallback_time)
    roots_p = in_roots[last]
    sizes = np.array([p.keys.shape[0] for p in partials], dtype=np.int64)
    order = np.argsort(t_p, kind="stable")
    ks = np.concatenate([partials[i].keys for i in order.tolist()])
    return (ks, np.repeat(t_p[order], sizes[order]),
            np.repeat(roots_p[order], sizes[order]), None)


# ---------------------------------------------------------------------------
# DSPE simulator engine
# ---------------------------------------------------------------------------


class SimulatorEngine:
    """Discrete-event DSPE engine over a topology (paper §6.1 at every hop).

    mode="batched" is the production path (ISSUE 1 closed-form FIFO);
    mode="reference" is the per-tuple interpreter kept as the equivalence
    oracle — identical event/sampling discipline, so SG/FG/PKG topologies
    match it exactly and DC/WC/FISH stay within the DESIGN.md §6 bands.
    mode="fused" (ISSUE 6) runs each grouped edge as one jitted device
    launch per event-free segment — routing, closed-form FIFO, and keyed
    window state fused in :mod:`repro.kernels.feed_fused` — with operator
    windows flushed downstream incrementally at each feed's end.
    """

    def __init__(self, mode: str = "batched", utilization: float = 0.9,
                 sample_every: int = 5_000, sample_noise: float = 0.02,
                 seed: int = 0, remap_sample: int = 512,
                 migration_cost_per_byte: float = 0.0,
                 migration_cost_per_replay: float = 0.0):
        if mode not in ("batched", "reference", "fused"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode
        self.utilization = utilization
        self.sample_every = sample_every
        self.sample_noise = sample_noise
        self.seed = seed
        self.remap_sample = remap_sample
        # ISSUE 8 tick-billed migration: seconds of destination-worker stall
        # per migrated state byte (policy "migrate") / per replayed tuple
        # (policy "rebuild").  0 keeps migration free — the pre-ISSUE-8
        # behaviour, and bit-identical reports
        self.migration_cost_per_byte = migration_cost_per_byte
        self.migration_cost_per_replay = migration_cost_per_replay
        self.name = f"dspe-{mode}"

    def open(self, topology: Topology, *,
             arrival_rate: Optional[float] = None,
             telemetry: Optional[object] = None) -> "SimulatorSession":
        """Open an incremental streaming session on this simulator.
        ``arrival_rate`` is the capacity-planning hint for stages without
        an explicit cost (``None``: inferred from the first feed);
        ``telemetry`` is an explicit :class:`repro.obs.Telemetry` bundle
        (default: the process one — a no-op unless ``repro.obs.enable()``
        was called)."""
        return SimulatorSession(self, topology, arrival_rate=arrival_rate,
                                telemetry=telemetry)

    def run(self, topology: Topology, source: Source,
            events: Sequence[ScopedEvent] = ()) -> TopologyReport:
        return _run_via_session(self, topology, source, events)


class _SimEdge:
    """One grouped edge's carried session state (DSPE simulator)."""

    __slots__ = ("stage", "grouper", "caps", "state", "acct", "mgr",
                 "lats", "n", "seed", "dt_hint", "finishes", "roots", "srep",
                 "emitted", "dispatches", "biller")

    def __init__(self, stage: Stage, grouper, caps: np.ndarray, seed: int,
                 dt_hint: Optional[float], mgr: Optional[KeyedStateManager],
                 biller: Optional[MigrationBiller] = None, metrics=None):
        self.stage = stage
        self.grouper = grouper
        self.caps = caps
        self.state = None            # core.stream.EdgeState after 1st feed
        self.seed = seed
        self.dt_hint = dt_hint
        self.acct = RemapAccountant([], metrics=metrics)
        self.mgr = mgr
        self.lats: List[np.ndarray] = []
        self.n = 0
        self.finishes: List[np.ndarray] = []  # operator stages only
        self.roots: List[np.ndarray] = []     # operator stages only
        self.srep: Optional[StateReport] = None
        self.emitted = 0             # window partials already sent downstream
        self.dispatches = 0          # fused-mode device launches (ISSUE 6)
        self.biller = biller         # tick-billed migration (ISSUE 8)


class SimulatorSession(_BaseSession):
    """Incremental record-batch execution on the DSPE simulator.

    Every feed pushes one :class:`RecordBatch` through the whole topology
    subtree reachable via transform stages; the closed-form FIFO in
    :func:`repro.core.stream.simulate_edge` continues from the carried
    per-worker ``busy_until`` so queue backlog survives the feed boundary.
    Operator stages fold tuples into their keyed windows per feed and
    release the partial-aggregate stream through their downstream merge
    edges at :meth:`close` (when the final windows flush).

    Worker-capacity defaults for stages without an explicit ``cost`` /
    ``capacities`` are frozen at the edge's first feed (from the arrival
    rate observed there, or the ``arrival_rate`` hint for the source edge).
    """

    def __init__(self, engine: "SimulatorEngine", topology: Topology,
                 arrival_rate: Optional[float] = None, telemetry=None):
        super().__init__(engine, topology, telemetry=telemetry)
        self._rate = arrival_rate
        self._order = {e.name: i for i, e in enumerate(self._edges)}
        self._src_times: List[np.ndarray] = []

    # -- protocol --------------------------------------------------------------
    def feed(self, batch: RecordBatch) -> Optional[FeedReceipt]:
        """Ingest the next record batch and run it through the topology.
        Returns this feed's :class:`FeedReceipt` (source-edge latencies +
        engine backlog — the open-loop feedback channel)."""
        if not self._check_batch(batch):
            return None
        tel = self.telemetry
        self._feed_idx += 1
        tel.ctx.feed_idx = self._feed_idx
        self._c_feeds.add(1)
        n = len(batch)
        feed_span = tel.tracer.span("session.feed", cat="session", n=n,
                                    feed_idx=self._feed_idx)
        ts = batch.timestamps
        base = self._n_source
        roots = np.arange(base, base + n, dtype=np.int64)
        self._n_source += n
        self._src_times.append(ts)
        streams = {SOURCE: (batch.keys, ts, roots, batch.values)}
        self._pump(streams, lambda r: ts[r - base])
        receipt = self._feed_receipt(n, float(ts[-1]))
        tel.ctx.engine_clock = receipt.t_end
        tl = tel.timeline
        tl.point("session.backlog", receipt.backlog)
        tl.point("session.latency_p99", receipt.latency_p99)
        feed_span.done()
        return receipt

    def _feed_receipt(self, n: int, t_end: float) -> FeedReceipt:
        lats: List[np.ndarray] = []
        backlog = 0.0
        for e in self._edges:
            if e.src != SOURCE:
                continue
            st = self._st.get(e.name)
            if st is None or not st.lats:
                continue
            lats.append(st.lats[-1])
            if st.state is not None:
                backlog = max(backlog,
                              float(st.state.busy_until.max()) - t_end)
        arr = np.concatenate(lats) if lats else np.empty(0)
        avg, _, _, p99 = _percentiles(arr)
        return FeedReceipt(n=n, t_end=t_end, latency_avg=avg,
                           latency_p99=p99, backlog=max(backlog, 0.0),
                           latencies=arr)

    # -- internals -------------------------------------------------------------
    def _close_pump(self, state: Dict[str, Dict]) -> None:
        src_all = (np.concatenate(self._src_times) if self._src_times
                   else np.empty(0))
        self._pump({}, lambda r: src_all[r], state=state)

    def _pump(self, streams: Dict, src_arrival, state=None) -> None:
        """Push per-stage streams through the DAG in dataflow order.  With
        ``state`` set (close-time), operator stages finalize and release
        their remaining partials downstream."""
        for edge in self._edges:
            if edge.src in streams:
                emission = self._run_edge(edge, *streams[edge.src],
                                          src_arrival)
                if emission is not None:
                    streams[edge.dst] = emission
            if state is None:
                continue
            st = self._st.get(edge.name)
            if st is not None and st.mgr is not None:
                dev = (getattr(st.state, "device", None)
                       if st.state is not None else None)
                if dev is not None and hasattr(dev, "flush_pane"):
                    # fused mode: drain the device pane tables so the final
                    # (possibly partial) window reaches the manager before
                    # finalize() flushes it
                    dev.flush_pane(st.mgr)
                st.mgr.finalize()
                st.srep = st.mgr.report(st.stage.name)
                state[st.stage.name] = st.srep.summary()
                if st.stage.name not in self._sinks:
                    rest = st.mgr.partials[st.emitted:]
                    if rest or st.emitted == 0:
                        fin = (np.concatenate(st.finishes) if st.finishes
                               else np.empty(0))
                        roots = (np.concatenate(st.roots) if st.roots
                                 else np.empty(0, dtype=np.int64))
                        streams[st.stage.name] = _emit_partials(
                            rest, fin, roots,
                            float(fin.max()) if fin.size else 0.0)
                        st.emitted = len(st.mgr.partials)

    def _run_edge(self, edge: Edge, in_keys, in_times, in_roots, in_values,
                  src_arrival) -> Optional[tuple]:
        eng = self.engine
        st = self._st.get(edge.name)
        stage = self.topology.stage(edge.dst)
        m = int(in_keys.shape[0])
        if st is None:
            span = float(in_times[-1] - in_times[0]) if m > 1 else 0.0
            fallback = self._rate if self._rate else 10_000.0
            rate = (m - 1) / span if span > 0 else fallback
            idx = self._order[edge.name]
            # the grouper gets no oracle capacities: capacity-aware schemes
            # must *discover* the true P_w through the periodic (noisy)
            # sampling hook, exactly like the legacy single-hop engine
            mgr0 = _stage_manager(stage)
            biller = None
            if mgr0 is not None and (eng.migration_cost_per_byte
                                     or eng.migration_cost_per_replay):
                biller = MigrationBiller(mgr0.migration,
                                         eng.migration_cost_per_byte,
                                         eng.migration_cost_per_replay)
            st = self._st[edge.name] = _SimEdge(
                stage=stage,
                grouper=build_grouper(edge.grouping, stage.parallelism),
                caps=stage.worker_capacities(rate, eng.utilization),
                seed=eng.seed + 17 * idx,
                dt_hint=(1.0 / self._rate
                         if edge.src == SOURCE and self._rate else None),
                mgr=mgr0, biller=biller,
                metrics=self.telemetry.metrics)
            trk = getattr(st.grouper, "tracker", None)
            if self.telemetry.enabled and trk is not None:
                trk.epoch_observer = _fish_epoch_observer(
                    self.telemetry, st.grouper)
        due, keep = _due_events(self._pending[edge.dst], st.n, in_times)
        self._pending[edge.dst] = keep
        # probe sample only while membership events are outstanding —
        # _sample_keys is an O(m log m) unique over the edge stream; it
        # accumulates across feeds so late-arriving hot keys are probed too
        if due or keep:
            st.acct.extend_sample(_sample_keys(in_keys, eng.remap_sample),
                                  eng.remap_sample)
        st.acct.offset = st.n  # events below are feed-local; report global
        mgr = st.mgr
        fused = eng.mode == "fused"
        chain = [st.acct]
        if mgr is not None:
            chain.append(mgr.on_event)
            if st.biller is not None:
                # biller after the manager: the manager's post_membership
                # runs the migration protocol that leaves the per-target bill
                chain.append(st.biller.on_event)
        if due:  # telemetry last: it observes, never reshapes
            chain.append(self._session_observer())
        observer = chain[0] if len(chain) == 1 else _chain_observers(*chain)
        billed0 = st.biller.billed_total if st.biller is not None else 0.0
        res = simulate_edge(
            st.grouper, in_keys, times=in_times,
            arrival_rate=self._rate or 10_000.0, mode=eng.mode,
            capacities=st.caps if st.state is None else None,
            sample_every=eng.sample_every, sample_noise=eng.sample_noise,
            events=due, seed=st.seed,
            event_observer=observer,
            tuple_observer=(mgr.feed
                            if (mgr is not None and not fused) else None),
            state_sink=(mgr if (mgr is not None and fused) else None),
            values=in_values, state=st.state, dt=st.dt_hint,
            compute_metrics=False,  # aggregated once at close
            migration_biller=st.biller,
            telemetry=self.telemetry,
        )
        st.state = res.state
        st.lats.append(res.latencies)
        st.n += m
        st.dispatches += res.dispatches
        if st.biller is not None:
            billed1 = st.biller.billed_total
            if billed1 != billed0:
                self.telemetry.timeline.point("migration.stall_total",
                                              billed1)
        if m:
            self._total_time = max(self._total_time,
                                   float(res.finishes.max()))
        if stage.name in self._sinks:
            self._e2e.append(res.finishes - src_arrival(in_roots))
        elif mgr is not None:
            # operator stages flush closed windows downstream at the end of
            # each feed (incremental emission — ISSUE 6); the remainder goes
            # out at close().  Finish times anchor the partial stream.
            st.finishes.append(res.finishes)
            st.roots.append(np.asarray(in_roots))
            fresh = mgr.drain_partials(st.emitted)
            if fresh:
                st.emitted += len(fresh)
                fin = np.concatenate(st.finishes)
                roots = np.concatenate(st.roots)
                return _emit_partials(fresh, fin, roots, float(fin.max()))
        else:  # intermediate stage: release transformed tuples
            return _emit(stage, in_keys, res.finishes, in_roots, in_values)
        return None

    def _edge_report(self, edge: Edge) -> EdgeReport:
        st = self._st.get(edge.name)
        stage = self.topology.stage(edge.dst)
        if st is None:  # the edge never received a tuple
            return self._zero_report(edge, stage)
        dev = getattr(st.state, "device", None)
        if dev is not None and hasattr(dev, "host_sync"):
            # fused mode keeps replica sets device-resident between feeds;
            # memory_overhead needs them on the host grouper
            dev.host_sync(st.grouper)
        lats = np.concatenate(st.lats) if st.lats else np.empty(0)
        metrics = edge_metrics(st.grouper, st.state.busy_until, lats, st.n)
        return EdgeReport(edge=edge.name, src=edge.src, dst=edge.dst,
                          scheme=edge.grouping.scheme,
                          workers=stage.parallelism, n_tuples=st.n,
                          remap_events=st.acct.per_event,
                          remap_frac_mean=st.acct.frac_mean(),
                          dispatches=st.dispatches,
                          migration_stall=(st.biller.billed_total
                                           if st.biller else 0.0),
                          **metrics.row(), **_state_extra(st.srep))


def _emit(stage: Stage, in_keys: np.ndarray, finishes: np.ndarray,
          in_roots: np.ndarray, in_values: Optional[np.ndarray] = None):
    """The stream a stage emits: transformed keys released at each tuple's
    finish time, sorted into arrival order (stable — ties keep emission
    order, mirroring a FIFO merge of the per-worker output streams).  A
    payload column rides along: each emitted tuple inherits its parent's
    value (a split sentence's words carry the sentence's payload)."""
    t = stage.transform
    if t is not None:
        out_keys = t(in_keys)
        out_times = np.repeat(finishes, t.fanout)
        out_roots = np.repeat(in_roots, t.fanout)
        out_values = (None if in_values is None
                      else np.repeat(in_values, t.fanout))
    else:
        out_keys, out_times, out_roots = in_keys, finishes, in_roots
        out_values = in_values
    order = np.argsort(out_times, kind="stable")
    return (out_keys[order], out_times[order], out_roots[order],
            None if out_values is None else out_values[order])


# ---------------------------------------------------------------------------
# serving engine adapter
# ---------------------------------------------------------------------------


class ServingTopologyEngine:
    """Run a topology on the continuous-batching serving engine.

    Each edge is a :class:`~repro.serving.engine.ServingEngine` replica
    pool (slot-limited decode, inferred-backlog routing); each tuple is a
    1-token request whose session is the tuple key.  Membership events map
    to ``fail_replica``/``add_replica`` (new workers must extend the id
    range contiguously — replica ids are never reused); capacity events set
    replica speeds to ``1/seconds_per_tuple``.
    """

    name = "serving"

    def __init__(self, slots_per_replica: int = 4, max_requests: int = 256,
                 utilization: float = 0.8, max_ticks: int = 200_000,
                 remap_sample: int = 512, pacing: str = "drain",
                 ticks_per_second: float = 1.0,
                 max_queue_per_replica: Optional[int] = None,
                 migration_ticks_per_byte: float = 0.0,
                 migration_ticks_per_replay: float = 0.0):
        if pacing not in ("drain", "arrival"):
            raise ValueError(
                f"unknown pacing {pacing!r}; 'drain' (closed loop: each "
                f"feed runs until its requests finish) or 'arrival' (open "
                f"loop — ISSUE 8: each feed's requests are submitted at "
                f"their wall-clock arrival ticks and the engine only runs "
                f"up to the feed's last arrival; close() drains)")
        self.slots_per_replica = slots_per_replica
        self.max_requests = max_requests
        self.utilization = utilization
        self.max_ticks = max_ticks
        self.remap_sample = remap_sample
        # ISSUE 8 open-loop serving: arrival pacing maps source wall-clock
        # seconds onto the tick grid via ticks_per_second; a bounded ingress
        # queue sheds on overflow; migrated keyed state stalls the
        # destination replica for ticks ∝ bytes shipped / tuples replayed
        self.pacing = pacing
        self.ticks_per_second = ticks_per_second
        self.max_queue_per_replica = max_queue_per_replica
        self.migration_ticks_per_byte = migration_ticks_per_byte
        self.migration_ticks_per_replay = migration_ticks_per_replay

    def open(self, topology: Topology, *,
             arrival_rate: Optional[float] = None,
             telemetry: Optional[object] = None) -> "ServingSession":
        """Open an incremental streaming session on the serving engine
        (``arrival_rate`` is accepted for protocol symmetry; serving time
        is scheduler ticks, paced by the topology bottleneck)."""
        return ServingSession(self, topology, telemetry=telemetry)

    def run(self, topology: Topology, source: Source,
            events: Sequence[ScopedEvent] = ()) -> TopologyReport:
        return _run_via_session(self, topology, source, events)


class _ServingEdge:
    """One grouped edge's carried session state (serving engine)."""

    __slots__ = ("stage", "eng", "acct", "mgr", "reqs", "in_times", "n",
                 "tick", "roots", "srep", "emitted", "biller", "done_seen")

    def __init__(self, stage: Stage, eng,
                 mgr: Optional[KeyedStateManager],
                 biller: Optional[MigrationBiller] = None, metrics=None):
        self.stage = stage
        self.eng = eng
        self.acct = RemapAccountant([], metrics=metrics)
        self.mgr = mgr
        self.biller = biller  # tick-billed migration (ISSUE 8)
        self.reqs: List = []
        self.in_times: List[np.ndarray] = []
        self.n = 0
        self.tick = 0
        self.roots: List[np.ndarray] = []  # operator stages only
        self.srep: Optional[StateReport] = None
        self.emitted = 0  # window partials already sent downstream
        self.done_seen = 0  # eng.done cursor (per-feed finish latencies)


class ServingSession(_BaseSession):
    """Incremental record-batch execution on the continuous-batching
    serving engine: each feed's tuples become 1-token requests submitted
    onto the carried per-edge replica pools, and the per-edge tick loops
    resume where the previous feed left them (each feed drains before the
    next — backlogged replicas carry their queues across the boundary).

    Serving time is scheduler ticks: a feed's records arrive on the
    stream-global tick grid regardless of their wall-clock timestamps.
    ``at_time`` events therefore resolve against the *source* wall-clock
    timestamps and scale onto each stage's input stream by the cumulative
    transform fanout.  Feeds larger than ``max_requests`` are subsampled
    (per feed — per-tick scheduling is Python-loop work).
    """

    def __init__(self, engine: "ServingTopologyEngine", topology: Topology,
                 telemetry=None):
        super().__init__(engine, topology, telemetry=telemetry)
        # bottleneck-feasible pacing: source tuples per tick such that every
        # stage sees at most `utilization` of its token capacity
        per_tick = engine.utilization * min(
            topology.stage(e.dst).parallelism / topology.fanout_to(e.dst)
            for e in topology.edges
        )
        self._dt = 1.0 / max(per_tick, 1e-9)
        # per-feed source-edge finish latencies (FeedReceipt channel)
        self._feed_lats: List[np.ndarray] = []

    # -- protocol --------------------------------------------------------------
    def feed(self, batch: RecordBatch) -> Optional[FeedReceipt]:
        """Ingest the next record batch (subsampled to ``max_requests``).
        With ``pacing="drain"`` (closed loop) records arrive on the
        bottleneck-paced tick grid and the feed runs until they finish;
        with ``pacing="arrival"`` (open loop — ISSUE 8) they arrive at
        their wall-clock timestamps × ``ticks_per_second`` and the engine
        only ticks up to the feed's last arrival — queues grow under
        overload and ``close()`` drains the backlog."""
        if not self._check_batch(batch):
            return None
        tel = self.telemetry
        self._feed_idx += 1
        tel.ctx.feed_idx = self._feed_idx
        self._c_feeds.add(1)
        feed_span = tel.tracer.span("session.feed", cat="session",
                                    n=len(batch), feed_idx=self._feed_idx)
        keys, ts, vals = batch.keys, batch.timestamps, batch.values
        if keys.shape[0] > self.engine.max_requests:
            pick = np.linspace(0, keys.shape[0] - 1,
                               self.engine.max_requests).astype(np.int64)
            keys, ts = keys[pick], ts[pick]
            vals = None if vals is None else vals[pick]
        n = int(keys.shape[0])
        base = self._n_source
        self._n_source += n
        self._resolve_at_time(ts, base)
        if self.engine.pacing == "arrival":
            src_ticks = np.asarray(ts, dtype=np.float64) \
                * self.engine.ticks_per_second
        else:
            src_ticks = np.arange(base, base + n, dtype=np.float64) \
                * self._dt
        streams = {SOURCE: (keys, src_ticks,
                            np.arange(base, base + n, dtype=np.int64),
                            vals)}
        done0, shed0 = self._done_shed()
        lat0 = len(self._feed_lats)
        self._pump(streams)
        done1, shed1 = self._done_shed()
        arr = (np.concatenate(self._feed_lats[lat0:])
               if len(self._feed_lats) > lat0 else np.empty(0))
        avg, _, _, p99 = _percentiles(arr)
        depth = in_flight = 0
        for st in self._st.values():
            depth += sum(len(q) for q in st.eng.queues)
            in_flight += sum(len(st.eng.slots[r].active)
                             for r in st.eng.alive)
        receipt = FeedReceipt(n=n, t_end=float(src_ticks[-1]),
                              latency_avg=avg, latency_p99=p99,
                              backlog=float(depth), latencies=arr,
                              queue_depth=depth, in_flight=in_flight,
                              done=done1 - done0, shed=shed1 - shed0)
        tel.ctx.engine_clock = receipt.t_end  # scheduler ticks
        tl = tel.timeline
        tl.point("session.queue_depth", depth)
        tl.point("session.in_flight", in_flight)
        tl.point("session.latency_p99", p99)
        tl.point("session.shed_total", shed1)
        feed_span.done()
        return receipt

    def _done_shed(self):
        done = sum(len(st.eng.done) for st in self._st.values())
        shed = sum(st.eng.shed for st in self._st.values())
        return done, shed

    # -- internals -------------------------------------------------------------
    def _close_pump(self, state: Dict[str, Dict]) -> None:
        if self.engine.pacing == "arrival":
            self._drain()
        self._pump({}, state=state)

    def _drain(self) -> None:
        """Open-loop close: tick every edge's engine until each submitted
        request is accounted for (finished or shed), then collect the
        deferred sink e2e latencies (measured from each request's arrival
        tick — for the single-edge open-loop topologies source arrival and
        edge arrival coincide)."""
        for edge in self._edges:
            st = self._st.get(edge.name)
            if st is None:
                continue
            eng = st.eng
            while (len(eng.done) + eng.shed < st.n
                   and st.tick < self.engine.max_ticks):
                eng.tick()
                st.tick += 1
            self._total_time = max(self._total_time, float(eng.now))
            if edge.dst in self._sinks:
                fins = np.array([r.finished for r in st.reqs])
                arrs = np.array([r.arrival for r in st.reqs])
                done = fins >= 0
                self._e2e.append((fins - arrs)[done])

    def _submit(self, st, req, in_keys, in_values, i) -> None:
        """Admit one request; keyed state is fed only for admitted requests
        (a shed request touches no operator state — honest accounting)."""
        replica = st.eng.submit(req)
        if replica < 0:  # shed by the bounded ingress queue
            return
        if st.mgr is not None:  # routed exactly once, at ingress
            st.mgr.feed(in_keys[i:i + 1], np.array([replica]),
                        None if in_values is None
                        else in_values[i:i + 1])

    def _resolve_at_time(self, ts: np.ndarray, base: int) -> None:
        """Lower time-addressed events onto stage-input tuple indices: the
        first (subsampled) source record at or after the timestamp, scaled
        by the stage's cumulative transform fanout."""
        for stage, pending in self._pending.items():
            if not any(getattr(e, "at_time", None) is not None
                       for e in pending):
                continue
            fan = self.topology.fanout_to(stage)
            out = []
            for e in pending:
                t = getattr(e, "at_time", None)
                if t is not None and ts.shape[0] and t <= float(ts[-1]):
                    src_idx = base + int(np.searchsorted(ts, t, side="left"))
                    e = dataclasses.replace(e, at=src_idx * fan,
                                            at_time=None)
                out.append(e)
            self._pending[stage] = out

    def _pump(self, streams: Dict, state=None) -> None:
        for edge in self._edges:
            if edge.src in streams:
                emission = self._run_edge(edge, *streams[edge.src])
                if emission is not None:
                    streams[edge.dst] = emission
            if state is None:
                continue
            st = self._st.get(edge.name)
            if st is not None and st.mgr is not None:
                st.mgr.finalize()
                st.srep = st.mgr.report(st.stage.name)
                state[st.stage.name] = st.srep.summary()
                if st.stage.name not in self._sinks:
                    rest = st.mgr.partials[st.emitted:]
                    if rest or st.emitted == 0:
                        fins = np.array([r.finished for r in st.reqs])
                        roots = (np.concatenate(st.roots) if st.roots
                                 else np.empty(0, dtype=np.int64))
                        streams[st.stage.name] = _emit_partials(
                            rest, fins, roots, float(st.eng.now))
                        st.emitted = len(st.mgr.partials)

    def _run_edge(self, edge: Edge, in_keys, in_times, in_roots,
                  in_values) -> Optional[tuple]:
        from ..serving.engine import Request, ServingEngine

        cfg = self.engine
        st = self._st.get(edge.name)
        stage = self.topology.stage(edge.dst)
        m = int(in_keys.shape[0])
        if st is None:
            caps = stage.worker_capacities(1.0)  # relative speeds only
            speeds = (1.0 / caps) / (1.0 / caps).mean()
            mgr0 = _stage_manager(stage)
            biller = None
            if mgr0 is not None and (cfg.migration_ticks_per_byte
                                     or cfg.migration_ticks_per_replay):
                biller = MigrationBiller(mgr0.migration,
                                         cfg.migration_ticks_per_byte,
                                         cfg.migration_ticks_per_replay)
            st = self._st[edge.name] = _ServingEdge(
                stage=stage,
                eng=ServingEngine(
                    stage.parallelism,
                    slots_per_replica=cfg.slots_per_replica,
                    tokens_per_tick=speeds,
                    grouping=edge.grouping,
                    max_queue_per_replica=cfg.max_queue_per_replica,
                    metrics=self.telemetry.metrics),
                mgr=mgr0, biller=biller,
                metrics=self.telemetry.metrics)
            trk = getattr(st.eng.router, "tracker", None)
            if self.telemetry.enabled and trk is not None:
                trk.epoch_observer = _fish_epoch_observer(
                    self.telemetry, st.eng.router)
        pending = self._pending[edge.dst]
        hi = st.n + m
        due = sorted((e for e in pending
                      if e.at_time is None and e.at < hi),
                     key=lambda e: e.at)
        self._pending[edge.dst] = [e for e in pending
                                   if e.at_time is not None or e.at >= hi]
        if due or self._pending[edge.dst]:
            st.acct.extend_sample(_sample_keys(in_keys, cfg.remap_sample),
                                  cfg.remap_sample)
        mgr = st.mgr
        chain = [st.acct]
        if mgr is not None:
            chain.append(mgr.on_event)
            if st.biller is not None:
                # biller after the manager: the manager's post_membership
                # runs the migration protocol that leaves the per-target bill
                chain.append(st.biller.on_event)
        if due:  # telemetry last: it observes, never reshapes
            chain.append(self._session_observer())
        observer = chain[0] if len(chain) == 1 else _chain_observers(*chain)
        reqs_f = [Request(st.n + i, int(k), arrival=float(t),
                          target_tokens=1)
                  for i, (k, t) in enumerate(zip(in_keys.tolist(),
                                                 in_times.tolist()))]
        st.reqs.extend(reqs_f)
        st.in_times.append(np.asarray(in_times, dtype=np.float64))
        if mgr is not None:
            st.roots.append(np.asarray(in_roots))
        eng = st.eng
        tick = st.tick
        nxt = 0
        if cfg.pacing == "arrival":
            # open loop (ISSUE 8): submit at arrival ticks, run the engine
            # only up to this feed's last arrival — no waiting for
            # completions, so overload piles up in the ingress queues
            end_tick = int(np.ceil(float(in_times[-1])))
            while (nxt < m or tick < end_tick) and tick < cfg.max_ticks:
                while due and due[0].at <= st.n + nxt:
                    self._apply_event(st, due.pop(0), observer)
                while nxt < m and in_times[nxt] <= tick:
                    self._submit(st, reqs_f[nxt], in_keys, in_values, nxt)
                    nxt += 1
                eng.tick()
                tick += 1
            # arrivals sitting exactly on the final tick boundary
            while nxt < m:
                self._submit(st, reqs_f[nxt], in_keys, in_values, nxt)
                nxt += 1
        else:
            target = len(eng.done) + eng.shed + m
            while len(eng.done) + eng.shed < target \
                    and tick < cfg.max_ticks:
                while due and due[0].at <= st.n + nxt:
                    self._apply_event(st, due.pop(0), observer)
                while nxt < m and in_times[nxt] <= tick:
                    self._submit(st, reqs_f[nxt], in_keys, in_values, nxt)
                    nxt += 1
                eng.tick()
                tick += 1
        st.tick = tick
        st.n += m
        if edge.src == SOURCE:
            new_done = eng.done[st.done_seen:]
            st.done_seen = len(eng.done)
            self._feed_lats.append(np.array(
                [r.finished - r.arrival for r in new_done]))
        finishes = np.array([r.finished for r in reqs_f])
        done = finishes >= 0
        if done.any():
            self._total_time = max(self._total_time,
                                   float(finishes[done].max()))
        if stage.name in self._sinks:
            if cfg.pacing == "arrival":
                # open loop: most of this feed's requests are still queued;
                # e2e is collected once at close, after the drain
                pass
            else:
                self._e2e.append((finishes - in_roots * self._dt)[done])
        elif mgr is not None:
            # windows that closed during this feed go downstream now; the
            # remainder is released at close() (incremental emission)
            fresh = mgr.drain_partials(st.emitted)
            if fresh:
                st.emitted += len(fresh)
                all_fins = np.array([r.finished for r in st.reqs])
                roots = np.concatenate(st.roots)
                return _emit_partials(fresh, all_fins, roots,
                                      float(st.eng.now))
        else:  # intermediate stage: release transformed tuples
            return _emit(stage, in_keys[done], finishes[done],
                         in_roots[done],
                         None if in_values is None else in_values[done])
        return None

    def _edge_report(self, edge: Edge) -> EdgeReport:
        st = self._st.get(edge.name)
        stage = self.topology.stage(edge.dst)
        if st is None:  # the edge never received a tuple
            return self._zero_report(edge, stage)
        finishes = np.array([r.finished for r in st.reqs])
        in_times = np.concatenate(st.in_times)
        done = finishes >= 0
        lats = (finishes - in_times)[done]
        avg, p50, p95, p99 = _percentiles(lats)
        router = st.eng.router
        em = st.eng.metrics()
        return EdgeReport(
            edge=edge.name, src=edge.src, dst=edge.dst,
            scheme=edge.grouping.scheme, workers=stage.parallelism,
            n_tuples=st.n, execution_time=float(st.eng.now),
            latency_avg=avg, latency_p50=p50, latency_p95=p95,
            latency_p99=p99,
            throughput=st.eng.total_tokens / max(st.eng.now, 1.0),
            memory_overhead=router.memory_overhead(),
            memory_overhead_norm=router.memory_overhead_normalized(),
            imbalance=_imbalance(router.assigned_counts),
            remap_events=st.acct.per_event,
            remap_frac_mean=st.acct.frac_mean(),
            dropped=int(st.n - done.sum()),
            queue_depth_peak=em.queue_depth_peak,
            in_flight_peak=em.in_flight_peak,
            shed=em.shed,
            time_in_queue_avg=em.time_in_queue_avg,
            time_in_queue_p99=em.time_in_queue_p99,
            migration_stall=(st.biller.billed_total if st.biller else 0.0),
            **_state_extra(st.srep))

    def _apply_event(self, st, event, observer) -> None:
        eng = st.eng
        if isinstance(event, MembershipEvent):
            observer("pre_membership", eng.router, event)
            target = {int(w) for w in event.workers}
            for dead in [r for r in eng.alive if r not in target]:
                eng.fail_replica(dead)
            for new in sorted(target - set(eng.alive)):
                if new != eng.num_replicas:
                    raise ValueError(
                        f"serving engine cannot add replica {new}: replica "
                        f"ids are never reused and must extend the range "
                        f"contiguously (next id is {eng.num_replicas})")
                eng.add_replica(speed=1.0,
                                slots=self.engine.slots_per_replica)
            observer("post_membership", eng.router, event)
            if st.biller is not None:
                # tick-billed migration (ISSUE 8): the keyed state this
                # event shipped stalls its destination replicas — they
                # neither admit nor decode while ingesting it
                for wk, ticks in st.biller.pop_charges().items():
                    eng.stall_replica(wk, ticks)
        elif isinstance(event, CapacityEvent):
            for wk, cap in event.capacities.items():
                eng.set_replica_speed(int(wk), 1.0 / max(float(cap), 1e-9))
            observer("capacity", eng.router, event)
        else:  # pragma: no cover - ScopedEvent validates on construction
            raise TypeError(f"unknown event type {type(event).__name__}")
