"""Topology engines — one protocol, two implementations (ISSUE 3 tentpole).

:class:`Engine` is the protocol: ``run(topology, source, events) ->
TopologyReport``.  Implementations:

* :class:`SimulatorEngine` — the DSPE discrete-event simulator.  Each
  grouped edge runs through :func:`repro.core.stream.simulate_edge`
  (``mode="batched"``: segment-wise closed-form FIFO; ``mode="reference"``:
  the per-tuple oracle interpreter), and the *finish* times of one stage
  become the arrival times of the next — per-stage FIFO queues chained
  through the DAG.  Time is in seconds.
* :class:`ServingTopologyEngine` — the continuous-batching
  :class:`~repro.serving.engine.ServingEngine` adapter: every edge is a
  replica pool with slot-limited decode, each tuple a 1-token request keyed
  by its (session) key.  Time is in scheduler ticks.  The source stream is
  subsampled to ``max_requests`` (per-tick scheduling is Python-loop work).

Both return the same :class:`TopologyReport`: per-edge latency percentiles,
imbalance, memory overhead and remap accounting (one :class:`EdgeReport`
per edge) plus end-to-end source→sink latencies — replacing the three
ad-hoc metric shapes (``StreamMetrics`` rows, serving dicts, scenario
dicts) that predated the topology API.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from ..core.stream import (CapacityEvent, MembershipEvent, StreamMetrics,
                           simulate_edge)
from ..state.window import KeyedStateManager, StateReport
from .configs import build_grouper
from .graph import SOURCE, Edge, ScopedEvent, Source, Stage, Topology, scoped

__all__ = [
    "EdgeReport",
    "TopologyReport",
    "Engine",
    "RemapAccountant",
    "SimulatorEngine",
    "ServingTopologyEngine",
]


# ---------------------------------------------------------------------------
# unified reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EdgeReport:
    """One grouped edge's metrics — the same schema from either engine.

    Latency/throughput units are the engine's clock (seconds for the DSPE
    simulator, scheduler ticks for the serving engine); the normalised
    metrics (imbalance, memory_overhead_norm, remap_frac_mean) are unitless
    and comparable across engines.
    """

    edge: str
    src: str
    dst: str
    scheme: str
    workers: int
    n_tuples: int
    execution_time: float
    latency_avg: float
    latency_p50: float
    latency_p95: float
    latency_p99: float
    throughput: float
    memory_overhead: int
    memory_overhead_norm: float
    imbalance: float
    remap_events: List[Dict] = dataclasses.field(default_factory=list)
    remap_frac_mean: Optional[float] = None
    dropped: int = 0
    # keyed operator state (ISSUE 4) — populated when the destination stage
    # carries a WindowOp; state_bytes is the peak Σ_w store bytes (the
    # *measured* counterpart of the memory_overhead key-replica proxy)
    state_bytes: Optional[int] = None
    state_entries: Optional[int] = None
    partial_entries: Optional[int] = None
    migration_bytes: int = 0
    tuples_replayed: int = 0

    def row(self) -> Dict[str, float]:
        """The paper-metric columns (same keys as ``StreamMetrics.row``)."""
        return {
            "execution_time": self.execution_time,
            "latency_avg": self.latency_avg,
            "latency_p50": self.latency_p50,
            "latency_p95": self.latency_p95,
            "latency_p99": self.latency_p99,
            "throughput": self.throughput,
            "memory_overhead": self.memory_overhead,
            "memory_overhead_norm": self.memory_overhead_norm,
            "imbalance": self.imbalance,
        }

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TopologyReport:
    """Whole-topology outcome: per-edge reports + end-to-end latency of each
    sink tuple measured from its *root* source tuple's arrival."""

    engine: str
    topology: str
    n_source_tuples: int
    total_time: float
    e2e_latency_avg: float
    e2e_latency_p50: float
    e2e_latency_p95: float
    e2e_latency_p99: float
    edges: List[EdgeReport] = dataclasses.field(default_factory=list)
    # keyed operator state (ISSUE 4): per-operator-stage summaries (incl.
    # the merged per-window results) + topology-wide migration cost
    state: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    migration_bytes: int = 0
    tuples_replayed: int = 0

    def edge(self, name: str) -> EdgeReport:
        """Lookup by full edge name (``"src->dst"``) or by dst stage."""
        for er in self.edges:
            if er.edge == name or er.dst == name:
                return er
        raise KeyError(f"no edge {name!r} in topology {self.topology!r}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@runtime_checkable
class Engine(Protocol):
    """One engine protocol: execute a topology against a source stream."""

    name: str

    def run(self, topology: Topology, source: Source,
            events: Sequence[ScopedEvent] = ()) -> TopologyReport:
        ...


# ---------------------------------------------------------------------------
# remap accounting (Fig. 17 "keys moved per membership event")
# ---------------------------------------------------------------------------


class RemapAccountant:
    """Event observer that probes a fixed key sample around each membership
    event and counts primary-route changes (works against any grouper via
    ``probe_route``; schemes with no key affinity report ``None``)."""

    def __init__(self, sample_keys: Sequence):
        self.sample = list(sample_keys)
        self.per_event: List[Dict] = []
        self._before: Optional[List[Optional[int]]] = None

    def __call__(self, kind: str, grouper, event) -> None:
        if kind == "pre_membership":
            self._before = [grouper.probe_route(k) for k in self.sample]
        elif kind == "post_membership":
            after = [grouper.probe_route(k) for k in self.sample]
            row = {"at": int(event.at), "sampled": len(self.sample)}
            if self.sample and after[0] is not None:
                moved = sum(1 for a, b in zip(self._before, after) if a != b)
                row["moved"] = moved
                row["frac"] = moved / len(self.sample)
            else:  # scheme with no key affinity (SG)
                row["moved"] = None
                row["frac"] = None
            self.per_event.append(row)
            self._before = None

    def frac_mean(self) -> Optional[float]:
        fracs = [e["frac"] for e in self.per_event if e["frac"] is not None]
        return float(np.mean(fracs)) if fracs else None


def _sample_keys(keys: np.ndarray, cap: int) -> List[int]:
    uniq = np.unique(np.asarray(keys))
    if uniq.shape[0] > cap:
        uniq = uniq[np.linspace(0, uniq.shape[0] - 1, cap).astype(np.int64)]
    return [int(k) for k in uniq]


def _percentiles(lats: np.ndarray):
    if lats.size == 0:
        return 0.0, 0.0, 0.0, 0.0
    return (float(lats.mean()), float(np.percentile(lats, 50)),
            float(np.percentile(lats, 95)), float(np.percentile(lats, 99)))


def _imbalance(counts: np.ndarray) -> float:
    counts = counts.astype(np.float64)
    return float((counts.max() - counts.mean())
                 / max(counts.mean(), 1e-12)) if counts.size else 0.0


def _chain_observers(*observers):
    """Fan one event-observer callback out to several consumers (remap
    accountant + keyed-state manager)."""

    def call(kind, grouper, event):
        for o in observers:
            o(kind, grouper, event)

    return call


def _stage_manager(stage: Stage) -> Optional[KeyedStateManager]:
    return (KeyedStateManager(stage.operator)
            if stage.operator is not None else None)


def _state_extra(srep: Optional[StateReport]) -> Dict:
    """The EdgeReport state columns for an operator stage (ISSUE 4) —
    shared by both engines so the schema cannot drift."""
    if srep is None:
        return {}
    from ..state.store import ENTRY_BYTES

    return dict(state_bytes=srep.state_bytes_peak,
                state_entries=srep.state_bytes_peak // ENTRY_BYTES,
                partial_entries=srep.partial_entries,
                migration_bytes=srep.migration_bytes,
                tuples_replayed=srep.tuples_replayed)


def _emit_state(mgr: KeyedStateManager, finishes: np.ndarray,
                in_roots: np.ndarray, fallback_time: float):
    """The stream an operator stage emits: one partial-aggregate tuple per
    state entry, keyed by the aggregation key and released when its worker
    flushed the window (the finish time of that worker's last tuple in the
    window; ``fallback_time`` covers entries whose anchor tuple never
    finished — the serving engine's dropped requests)."""
    ks, last = mgr.partial_entries()
    t = finishes[last]
    t = np.where(t >= 0.0, t, fallback_time)
    roots = in_roots[last]
    order = np.argsort(t, kind="stable")
    return ks[order], t[order], roots[order]


# ---------------------------------------------------------------------------
# DSPE simulator engine
# ---------------------------------------------------------------------------


class SimulatorEngine:
    """Discrete-event DSPE engine over a topology (paper §6.1 at every hop).

    mode="batched" is the production path (ISSUE 1 closed-form FIFO);
    mode="reference" is the per-tuple interpreter kept as the equivalence
    oracle — identical event/sampling discipline, so SG/FG/PKG topologies
    match it exactly and DC/WC/FISH stay within the DESIGN.md §6 bands.
    """

    def __init__(self, mode: str = "batched", utilization: float = 0.9,
                 sample_every: int = 5_000, sample_noise: float = 0.02,
                 seed: int = 0, remap_sample: int = 512):
        if mode not in ("batched", "reference"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode
        self.utilization = utilization
        self.sample_every = sample_every
        self.sample_noise = sample_noise
        self.seed = seed
        self.remap_sample = remap_sample
        self.name = f"dspe-{mode}"

    def run(self, topology: Topology, source: Source,
            events: Sequence[ScopedEvent] = ()) -> TopologyReport:
        keys = np.asarray(source.keys)
        n = int(keys.shape[0])
        dt = 1.0 / source.arrival_rate
        # per-stage streams: (keys, arrival times, root source index)
        streams = {SOURCE: (keys, np.arange(n, dtype=np.float64) * dt,
                            np.arange(n, dtype=np.int64))}
        sinks = set(topology.sinks())
        reports: List[EdgeReport] = []
        e2e: List[np.ndarray] = []
        state: Dict[str, Dict] = {}
        total_time = 0.0

        for idx, edge in enumerate(topology.ordered_edges()):
            in_keys, in_times, in_roots = streams[edge.src]
            stage = topology.stage(edge.dst)
            m = int(in_keys.shape[0])
            span = float(in_times[-1] - in_times[0]) if m > 1 else 0.0
            rate = (m - 1) / span if span > 0 else source.arrival_rate
            caps = stage.worker_capacities(rate, self.utilization)
            # the grouper gets no oracle capacities: capacity-aware schemes
            # must *discover* the true P_w through the periodic (noisy)
            # sampling hook, exactly like the legacy single-hop engine
            grouper = build_grouper(edge.grouping, stage.parallelism)
            sub_events = scoped(events, edge.dst)
            # probe sample only when a membership event can actually fire —
            # _sample_keys is an O(m log m) unique over the edge stream
            acct = RemapAccountant(
                _sample_keys(in_keys, self.remap_sample) if sub_events
                else [])
            mgr = _stage_manager(stage)
            res = simulate_edge(
                grouper, in_keys,
                # the source stream is uniform by construction: taking the
                # times=None fast path keeps this bit-identical to the
                # legacy single-hop engine
                times=None if edge.src == SOURCE else in_times,
                arrival_rate=source.arrival_rate,
                mode=self.mode, capacities=caps,
                sample_every=self.sample_every,
                sample_noise=self.sample_noise,
                events=sub_events,
                seed=self.seed + 17 * idx,
                event_observer=(acct if mgr is None
                                else _chain_observers(acct, mgr.on_event)),
                tuple_observer=mgr.feed if mgr is not None else None,
            )
            srep = None
            if mgr is not None:
                mgr.finalize()
                srep = mgr.report(stage.name)
                state[stage.name] = srep.summary()
            reports.append(self._edge_report(edge, stage, res.metrics, m,
                                             acct, srep))
            if m:
                total_time = max(total_time, float(res.finishes.max()))
            if stage.name in sinks:
                e2e.append(res.finishes - in_roots * dt)
            elif mgr is not None:  # operator stages emit their partials
                streams[edge.dst] = _emit_state(
                    mgr, res.finishes, in_roots,
                    float(res.finishes.max()) if m else 0.0)
            else:  # intermediate stage: release transformed tuples
                streams[edge.dst] = _emit(stage, in_keys, res.finishes,
                                          in_roots)

        lats = np.concatenate(e2e) if e2e else np.empty(0)
        avg, p50, p95, p99 = _percentiles(lats)
        return TopologyReport(
            engine=self.name, topology=topology.name, n_source_tuples=n,
            total_time=total_time, e2e_latency_avg=avg, e2e_latency_p50=p50,
            e2e_latency_p95=p95, e2e_latency_p99=p99, edges=reports,
            state=state,
            migration_bytes=sum(r.migration_bytes for r in reports),
            tuples_replayed=sum(r.tuples_replayed for r in reports),
        )

    @staticmethod
    def _edge_report(edge: Edge, stage: Stage, metrics: StreamMetrics,
                     n_tuples: int, acct: RemapAccountant,
                     srep: Optional[StateReport] = None) -> EdgeReport:
        extra = _state_extra(srep)
        return EdgeReport(
            edge=edge.name, src=edge.src, dst=edge.dst,
            scheme=edge.grouping.scheme, workers=stage.parallelism,
            n_tuples=n_tuples, remap_events=acct.per_event,
            remap_frac_mean=acct.frac_mean(), **metrics.row(), **extra,
        )


def _emit(stage: Stage, in_keys: np.ndarray, finishes: np.ndarray,
          in_roots: np.ndarray):
    """The stream a stage emits: transformed keys released at each tuple's
    finish time, sorted into arrival order (stable — ties keep emission
    order, mirroring a FIFO merge of the per-worker output streams)."""
    t = stage.transform
    if t is not None:
        out_keys = t(in_keys)
        out_times = np.repeat(finishes, t.fanout)
        out_roots = np.repeat(in_roots, t.fanout)
    else:
        out_keys, out_times, out_roots = in_keys, finishes, in_roots
    order = np.argsort(out_times, kind="stable")
    return out_keys[order], out_times[order], out_roots[order]


# ---------------------------------------------------------------------------
# serving engine adapter
# ---------------------------------------------------------------------------


class ServingTopologyEngine:
    """Run a topology on the continuous-batching serving engine.

    Each edge is a :class:`~repro.serving.engine.ServingEngine` replica
    pool (slot-limited decode, inferred-backlog routing); each tuple is a
    1-token request whose session is the tuple key.  Membership events map
    to ``fail_replica``/``add_replica`` (new workers must extend the id
    range contiguously — replica ids are never reused); capacity events set
    replica speeds to ``1/seconds_per_tuple``.
    """

    name = "serving"

    def __init__(self, slots_per_replica: int = 4, max_requests: int = 256,
                 utilization: float = 0.8, max_ticks: int = 200_000,
                 remap_sample: int = 512):
        self.slots_per_replica = slots_per_replica
        self.max_requests = max_requests
        self.utilization = utilization
        self.max_ticks = max_ticks
        self.remap_sample = remap_sample

    def run(self, topology: Topology, source: Source,
            events: Sequence[ScopedEvent] = ()) -> TopologyReport:
        from ..serving.engine import Request, ServingEngine

        keys = np.asarray(source.keys)
        if keys.shape[0] > self.max_requests:
            pick = np.linspace(0, keys.shape[0] - 1,
                               self.max_requests).astype(np.int64)
            keys = keys[pick]
        n = int(keys.shape[0])
        # bottleneck-feasible pacing: source tuples per tick such that every
        # stage sees at most `utilization` of its token capacity
        per_tick = self.utilization * min(
            topology.stage(e.dst).parallelism / topology.fanout_to(e.dst)
            for e in topology.edges
        )
        dt = 1.0 / max(per_tick, 1e-9)
        src_times = np.arange(n, dtype=np.float64) * dt
        streams = {SOURCE: (keys, src_times,
                            np.arange(n, dtype=np.int64))}
        sinks = set(topology.sinks())
        reports: List[EdgeReport] = []
        e2e: List[np.ndarray] = []
        state: Dict[str, Dict] = {}
        total_time = 0.0

        for edge in topology.ordered_edges():
            in_keys, in_times, in_roots = streams[edge.src]
            stage = topology.stage(edge.dst)
            m = int(in_keys.shape[0])
            caps = stage.worker_capacities(1.0)  # relative speeds only
            speeds = (1.0 / caps) / (1.0 / caps).mean()
            eng = ServingEngine(stage.parallelism,
                                slots_per_replica=self.slots_per_replica,
                                tokens_per_tick=speeds,
                                grouping=edge.grouping)
            pending = sorted(scoped(events, edge.dst), key=lambda e: e.at)
            acct = RemapAccountant(
                _sample_keys(in_keys, self.remap_sample) if pending else [])
            mgr = _stage_manager(stage)
            observer = (acct if mgr is None
                        else _chain_observers(acct, mgr.on_event))
            reqs = [Request(i, int(k), arrival=float(t), target_tokens=1)
                    for i, (k, t) in enumerate(zip(in_keys.tolist(),
                                                   in_times.tolist()))]
            tick = 0
            nxt = 0
            while len(eng.done) < m and tick < self.max_ticks:
                while pending and pending[0].at <= nxt:
                    self._apply_event(eng, pending.pop(0), observer)
                while nxt < m and in_times[nxt] <= tick:
                    eng.submit(reqs[nxt])
                    if mgr is not None:  # routed exactly once, at ingress
                        mgr.feed(in_keys[nxt:nxt + 1],
                                 np.array([reqs[nxt].replica]))
                    nxt += 1
                eng.tick()
                tick += 1

            srep = None
            if mgr is not None:
                mgr.finalize()
                srep = mgr.report(stage.name)
                state[stage.name] = srep.summary()
            finishes = np.array([r.finished for r in reqs])
            done = finishes >= 0
            lats = (finishes - in_times)[done]
            avg, p50, p95, p99 = _percentiles(lats)
            router = eng.router
            reports.append(EdgeReport(
                edge=edge.name, src=edge.src, dst=edge.dst,
                scheme=edge.grouping.scheme, workers=stage.parallelism,
                n_tuples=m, execution_time=float(eng.now),
                latency_avg=avg, latency_p50=p50, latency_p95=p95,
                latency_p99=p99,
                throughput=eng.total_tokens / max(eng.now, 1.0),
                memory_overhead=router.memory_overhead(),
                memory_overhead_norm=router.memory_overhead_normalized(),
                imbalance=_imbalance(router.assigned_counts),
                remap_events=acct.per_event,
                remap_frac_mean=acct.frac_mean(),
                dropped=int(m - done.sum()),
                **_state_extra(srep),
            ))
            if done.any():
                total_time = max(total_time, float(finishes[done].max()))
            if stage.name in sinks:
                e2e.append((finishes - in_roots * dt)[done])
            elif mgr is not None:  # operator stages emit their partials
                streams[edge.dst] = _emit_state(mgr, finishes, in_roots,
                                                float(eng.now))
            else:  # intermediate stage: release transformed tuples
                streams[edge.dst] = _emit(stage, in_keys[done],
                                          finishes[done], in_roots[done])

        lats = np.concatenate(e2e) if e2e else np.empty(0)
        avg, p50, p95, p99 = _percentiles(lats)
        return TopologyReport(
            engine=self.name, topology=topology.name, n_source_tuples=n,
            total_time=total_time, e2e_latency_avg=avg, e2e_latency_p50=p50,
            e2e_latency_p95=p95, e2e_latency_p99=p99, edges=reports,
            state=state,
            migration_bytes=sum(r.migration_bytes for r in reports),
            tuples_replayed=sum(r.tuples_replayed for r in reports),
        )

    def _apply_event(self, eng, event, observer) -> None:
        if isinstance(event, MembershipEvent):
            observer("pre_membership", eng.router, event)
            target = {int(w) for w in event.workers}
            for dead in [r for r in eng.alive if r not in target]:
                eng.fail_replica(dead)
            for new in sorted(target - set(eng.alive)):
                if new != eng.num_replicas:
                    raise ValueError(
                        f"serving engine cannot add replica {new}: replica "
                        f"ids are never reused and must extend the range "
                        f"contiguously (next id is {eng.num_replicas})")
                eng.add_replica(speed=1.0, slots=self.slots_per_replica)
            observer("post_membership", eng.router, event)
        elif isinstance(event, CapacityEvent):
            for wk, cap in event.capacities.items():
                eng.set_replica_speed(int(wk), 1.0 / max(float(cap), 1e-9))
            observer("capacity", eng.router, event)
        else:  # pragma: no cover - ScopedEvent validates on construction
            raise TypeError(f"unknown event type {type(event).__name__}")
