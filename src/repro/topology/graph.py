"""Declarative dataflow topologies — named stages, grouped edges (ISSUE 3).

The paper evaluates grouping schemes *per edge* inside Storm topologies
(DAGs of operators — the classic split→count word-count pipeline).  This
module is the declarative half of that API:

* :class:`Stage` — a named operator: ``parallelism`` workers, a per-tuple
  processing cost, and an optional vectorised :class:`KeyTransform` that
  maps each processed tuple onto ``fanout`` downstream tuples (a sentence
  splitting into words).
* :class:`Edge` — connects two stages (or the reserved ``"source"``) and
  carries a typed :class:`~repro.topology.configs.SchemeConfig`: the
  grouping applied to tuples crossing the edge.
* :class:`Topology` — the validated DAG.  Supported shape: a tree rooted at
  the source (every stage has exactly one inbound grouped edge; a stage may
  broadcast its output along several outbound edges).  That covers the
  paper's pipelines (chains) and fan-out trees; fan-in (shared worker pools
  fed by several grouped edges) is out of scope and rejected eagerly.
* :class:`RecordBatch` — a frozen columnar chunk of the input stream
  (int keys + optional float64 payload ``values`` + explicit nondecreasing
  ``timestamps``): the unit a session ingests (ISSUE 5).
* :class:`Source` — the keyed input stream: an array one-batch convenience
  form, or an iterable of record batches.
* :class:`ScopedEvent` — a membership/capacity event targeted at one
  stage's worker pool, with ``at`` indexing that edge's input stream (or
  ``at_time`` addressing it by stream timestamp).

Engines that execute a topology live in :mod:`repro.topology.engine`.
"""

from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

import numpy as np

from ..core.stream import CapacityEvent, MembershipEvent
from ..state.window import WindowOp
from .configs import SchemeConfig

__all__ = [
    "SOURCE",
    "KeyTransform",
    "hashed_fanout",
    "project_mod",
    "Stage",
    "Edge",
    "Topology",
    "RecordBatch",
    "Source",
    "ScopedEvent",
]

SOURCE = "source"  # reserved name: the topology's input stream endpoint


# ---------------------------------------------------------------------------
# key transforms (what a stage emits downstream)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KeyTransform:
    """Vectorised tuple emission: ``fn(keys) -> (n * fanout,)`` int array.

    The ``fanout`` outputs of input tuple ``i`` occupy the contiguous block
    ``out[i*fanout : (i+1)*fanout]`` and are released when tuple ``i``
    finishes at the emitting stage.  Must be deterministic — both engines
    and the reference oracle replay it.
    """

    fanout: int
    fn: Callable[[np.ndarray], np.ndarray]
    label: str = "custom"

    def __post_init__(self) -> None:
        if self.fanout < 1:
            raise ValueError(f"fanout must be >= 1, got {self.fanout}")

    def __call__(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys)
        out = np.asarray(self.fn(keys))
        if out.shape != (keys.shape[0] * self.fanout,):
            raise ValueError(
                f"transform {self.label!r} returned shape {out.shape}, "
                f"expected ({keys.shape[0] * self.fanout},)")
        return out


_MIX = np.int64(2654435761)  # Knuth multiplicative-hash constant


def hashed_fanout(fanout: int, vocab: int, salt: int = 0x9E37) -> KeyTransform:
    """Word-split-style transform: key ``k`` always emits the same ``fanout``
    pseudo-random "word" ids in ``[0, vocab)``.

    Because the word set is a deterministic function of the sentence key, a
    hot upstream key fans into hot downstream keys — the multi-hop skew the
    topology API exists to study (a hot partition feeding a hot partition).
    """
    if vocab < 1:
        raise ValueError(f"vocab must be >= 1, got {vocab}")

    def fn(keys: np.ndarray) -> np.ndarray:
        k = keys.astype(np.int64)[:, None]
        j = np.arange(fanout, dtype=np.int64)[None, :]
        h = (k * _MIX + (j + 1) * np.int64(salt)) & np.int64(0x7FFFFFFF)
        return (h % vocab).reshape(-1)

    return KeyTransform(fanout, fn, label=f"hashed_fanout({fanout},{vocab})")


def project_mod(vocab: int) -> KeyTransform:
    """1→1 projection onto a smaller key space (aggregation-style rekeying):
    many upstream keys collapse onto each downstream key."""
    if vocab < 1:
        raise ValueError(f"vocab must be >= 1, got {vocab}")
    return KeyTransform(
        1, lambda keys: keys.astype(np.int64) % vocab,
        label=f"project_mod({vocab})")


# ---------------------------------------------------------------------------
# stages / edges / topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """A named operator: ``parallelism`` FIFO workers processing one tuple in
    ``cost`` seconds each (or per-worker ``capacities``, cycled over the
    pool — the Fig. 7 fast/slow mix), optionally emitting downstream tuples
    via ``transform`` *or* running a windowed keyed aggregation via
    ``operator`` (ISSUE 4).

    An ``operator`` stage holds per-worker keyed state (DESIGN.md §9): the
    engines maintain its window stores, account migration cost on churn,
    and — if the stage has a downstream edge — emit one partial-aggregate
    tuple per state entry at window close, keyed by the aggregation key
    (the merge stage's input).  ``transform`` and ``operator`` are mutually
    exclusive: an operator's emission *is* its partial stream.
    """

    name: str
    parallelism: int
    cost: Optional[float] = None          # uniform seconds/tuple
    capacities: Tuple[float, ...] = ()    # per-worker override (cycled)
    transform: Optional[KeyTransform] = None
    operator: Optional[WindowOp] = None

    def __post_init__(self) -> None:
        if not self.name or self.name == SOURCE:
            raise ValueError(f"invalid stage name {self.name!r} "
                             f"({SOURCE!r} is reserved)")
        if self.parallelism < 1:
            raise ValueError(f"stage {self.name!r}: parallelism must be "
                             f">= 1, got {self.parallelism}")
        if self.cost is not None and self.cost <= 0.0:
            raise ValueError(f"stage {self.name!r}: cost must be positive")
        if self.cost is not None and self.capacities:
            raise ValueError(f"stage {self.name!r}: give cost or "
                             f"capacities, not both")
        if any(c <= 0.0 for c in self.capacities):
            raise ValueError(f"stage {self.name!r}: capacities must be "
                             f"positive")
        if self.operator is not None:
            if not isinstance(self.operator, WindowOp):
                raise TypeError(f"stage {self.name!r}: operator must be a "
                                f"repro.state.WindowOp, got "
                                f"{type(self.operator).__name__}")
            if self.transform is not None:
                raise ValueError(f"stage {self.name!r}: transform and "
                                 f"operator are mutually exclusive (an "
                                 f"operator emits its partial aggregates)")

    @property
    def fanout(self) -> int:
        return self.transform.fanout if self.transform else 1

    def worker_capacities(self, arrival_rate: float,
                          utilization: float = 0.9) -> np.ndarray:
        """Seconds/tuple per worker.  Defaults to a feasible pool at
        ``utilization`` for the given input rate (the simulator's
        ``0.9 · W / λ`` convention)."""
        if self.capacities:
            pat = np.asarray(self.capacities, dtype=np.float64)
            return pat[np.arange(self.parallelism) % pat.shape[0]]
        if self.cost is not None:
            return np.full(self.parallelism, float(self.cost))
        return np.full(self.parallelism,
                       utilization * self.parallelism / arrival_rate)


@dataclasses.dataclass(frozen=True)
class Edge:
    """A grouped connection ``src → dst``; ``src`` may be ``"source"``."""

    src: str
    dst: str
    grouping: SchemeConfig

    def __post_init__(self) -> None:
        if self.dst == SOURCE:
            raise ValueError("an edge cannot point at the source")
        if self.src == self.dst:
            raise ValueError(f"self-edge on stage {self.src!r}")
        if not isinstance(self.grouping, SchemeConfig):
            raise TypeError(
                f"edge {self.src}->{self.dst}: grouping must be a "
                f"SchemeConfig, got {type(self.grouping).__name__} "
                f"(use repro.topology.configs.config_for(name))")

    @property
    def name(self) -> str:
        return f"{self.src}->{self.dst}"


@dataclasses.dataclass(frozen=True)
class Topology:
    """A validated dataflow DAG: a tree of stages rooted at the source."""

    name: str
    stages: Tuple[Stage, ...]
    edges: Tuple[Edge, ...]

    def __post_init__(self) -> None:
        if not self.stages:
            raise ValueError("topology needs at least one stage")
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names in {names}")
        known = set(names)
        indeg: Dict[str, int] = {n: 0 for n in names}
        for e in self.edges:
            if e.src != SOURCE and e.src not in known:
                raise ValueError(f"edge {e.name}: unknown src {e.src!r}")
            if e.dst not in known:
                raise ValueError(f"edge {e.name}: unknown dst {e.dst!r}")
            indeg[e.dst] += 1
        for n, d in indeg.items():
            if d == 0:
                raise ValueError(f"stage {n!r} has no inbound edge "
                                 f"(unreachable)")
            if d > 1:
                raise ValueError(
                    f"stage {n!r} has {d} inbound edges; fan-in onto a "
                    f"shared worker pool is not supported — split it into "
                    f"separate stages")
        # in-degree exactly 1 everywhere ⇒ the edge set is a forest of
        # trees; reachability from the source makes it a single tree (and
        # therefore acyclic) — verify by walking the BFS order
        if len(self.ordered_edges()) != len(self.edges):
            raise ValueError("topology is not connected to the source "
                             "(cycle or disconnected component)")

    # -- lookups ---------------------------------------------------------------
    def stage(self, name: str) -> Stage:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"no stage named {name!r}")

    def ordered_edges(self) -> List[Edge]:
        """Edges in dataflow (BFS-from-source) order."""
        out: List[Edge] = []
        frontier = [SOURCE]
        remaining = list(self.edges)
        while frontier:
            nxt: List[str] = []
            keep: List[Edge] = []
            for e in remaining:
                if e.src in frontier:
                    out.append(e)
                    nxt.append(e.dst)
                else:
                    keep.append(e)
            remaining = keep
            frontier = nxt
        return out

    def sinks(self) -> List[str]:
        srcs = {e.src for e in self.edges}
        return [s.name for s in self.stages if s.name not in srcs]

    def fanout_to(self, name: str) -> int:
        """Cumulative source→stage tuple multiplication (transform fanouts
        along the unique path from the source)."""
        parent = {e.dst: e.src for e in self.edges}
        f = 1
        node = parent[name]
        while node != SOURCE:
            f *= self.stage(node).fanout
            node = parent[node]
        return f


def _frozen_column(arr: Optional[np.ndarray], dtype=None) -> Optional[np.ndarray]:
    """A read-only copy-on-write view of one batch column: callers keep
    their arrays writable; the batch's view can never mutate mid-session."""
    if arr is None:
        return None
    out = np.asarray(arr) if dtype is None else np.asarray(arr, dtype=dtype)
    if out.flags.writeable:
        out = out.copy()
        out.setflags(write=False)
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class RecordBatch:
    """A frozen columnar chunk of a keyed stream (ISSUE 5) — the unit a
    :class:`~repro.topology.engine.Session` ingests via ``feed``:

    * ``keys`` — 1-D interned integer key ids (int32 preferred: the batched
      grouping engine routes without hashing Python objects);
    * ``timestamps`` — float64 per-record arrival times in seconds,
      nondecreasing within the batch (and across the batches of one
      session);
    * ``values`` — optional float64 payload column (the real tuple values a
      ``WindowOp(value="payload")`` aggregates instead of the pseudo-payload).

    Columns are copied read-only on construction, so a batch can be fed to
    several sessions (or replayed) without aliasing hazards.
    """

    keys: np.ndarray
    timestamps: np.ndarray
    values: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        keys = np.asarray(self.keys)
        if keys.ndim != 1 or keys.dtype.kind not in "iu":
            raise TypeError(
                f"RecordBatch keys must be a 1-D integer array, got "
                f"dtype={keys.dtype} shape={keys.shape} (intern via "
                f"repro.data.synthetic.intern_keys)")
        ts = np.asarray(self.timestamps, dtype=np.float64)
        if ts.shape != keys.shape:
            raise ValueError(
                f"timestamps shape {ts.shape} != keys shape {keys.shape}")
        if ts.shape[0] > 1 and np.any(np.diff(ts) < 0.0):
            raise ValueError("timestamps must be nondecreasing")
        vals = self.values
        if vals is not None:
            vals = np.asarray(vals, dtype=np.float64)
            if vals.shape != keys.shape:
                raise ValueError(
                    f"values shape {vals.shape} != keys shape {keys.shape}")
        object.__setattr__(self, "keys", _frozen_column(keys))
        object.__setattr__(self, "timestamps", _frozen_column(ts))
        object.__setattr__(self, "values", _frozen_column(vals))

    def __len__(self) -> int:
        return int(self.keys.shape[0])


@dataclasses.dataclass(frozen=True, eq=False)
class Source:
    """The topology's input stream, in either of two forms:

    * **array form** (the one-batch convenience): ``Source(keys,
      arrival_rate=...)`` — interned integer keys at ``arrival_rate``
      tuples/second (tuple ``i`` arrives at ``i / arrival_rate``), with
      optional per-tuple ``values`` payload and explicit ``timestamps``
      overriding the uniform grid;
    * **batch form** (ISSUE 5): ``Source(batches=<iterable of
      RecordBatch>)`` — an incremental stream whose batches a session feeds
      one at a time.  ``arrival_rate`` remains the capacity-planning hint
      for stages without an explicit cost.

    A Source wrapping a generator is single-use (the generator is consumed
    by ``iter_batches``); the array form is reusable.
    """

    keys: Optional[np.ndarray] = None
    arrival_rate: float = 10_000.0
    values: Optional[np.ndarray] = None
    timestamps: Optional[np.ndarray] = None
    batches: Optional[Iterable[RecordBatch]] = None

    def __post_init__(self) -> None:
        if self.arrival_rate <= 0.0:
            raise ValueError("arrival_rate must be positive")
        if (self.keys is None) == (self.batches is None):
            raise ValueError("give exactly one of keys= (array form) or "
                             "batches= (record-batch form)")
        if self.batches is not None and (self.values is not None
                                         or self.timestamps is not None):
            raise ValueError("values/timestamps columns belong inside each "
                             "RecordBatch in batch form")

    def iter_batches(self, batch_size: Optional[int] = None
                     ) -> Iterator[RecordBatch]:
        """The stream as :class:`RecordBatch` chunks.  Array form yields one
        batch (or uniform-grid chunks of ``batch_size`` — the session-API
        replay of a materialized stream); batch form yields the wrapped
        iterable as-is (``batch_size`` must be ``None``)."""
        if batch_size is not None and batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        if self.batches is not None:
            if batch_size is not None:
                raise ValueError("batch_size only applies to the array form")
            for b in self.batches:
                if not isinstance(b, RecordBatch):
                    raise TypeError(f"batches must yield RecordBatch, got "
                                    f"{type(b).__name__}")
                yield b
            return
        keys = np.asarray(self.keys)
        n = int(keys.shape[0])
        if self.timestamps is not None:
            ts = np.asarray(self.timestamps, dtype=np.float64)
        else:
            ts = np.arange(n, dtype=np.float64) * (1.0 / self.arrival_rate)
        vals = self.values
        if batch_size is None:
            batch_size = max(n, 1)
        for lo in range(0, n, batch_size):
            hi = min(lo + batch_size, n)
            yield RecordBatch(
                keys[lo:hi], ts[lo:hi],
                None if vals is None else np.asarray(vals)[lo:hi])


@dataclasses.dataclass(frozen=True)
class ScopedEvent:
    """A membership/capacity event on one stage's worker pool; the wrapped
    event's ``at`` indexes that stage's *input* stream (tuples crossing its
    inbound edge)."""

    stage: str
    event: object

    def __post_init__(self) -> None:
        if not isinstance(self.event, (MembershipEvent, CapacityEvent)):
            raise TypeError(
                f"ScopedEvent wraps MembershipEvent or CapacityEvent, got "
                f"{type(self.event).__name__}")
