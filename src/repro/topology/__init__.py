"""Unified dataflow topology API (ISSUE 3) — the front door to the system.

Typed per-scheme configs (:mod:`.configs`), declarative multi-stage
topologies (:mod:`.graph`), and one engine protocol with a DSPE simulator
and a serving-engine adapter behind it (:mod:`.engine`)::

    from repro.topology import (Edge, FishConfig, ShuffleConfig,
                                SimulatorEngine, Source, Stage, Topology,
                                hashed_fanout)

    topo = Topology(
        name="word_count",
        stages=(Stage("split", parallelism=4,
                      transform=hashed_fanout(4, vocab=1_000)),
                Stage("count", parallelism=8)),
        edges=(Edge("source", "split", ShuffleConfig()),
               Edge("split", "count", FishConfig())),
    )
    report = SimulatorEngine().run(topo, Source(keys, arrival_rate=2e4))
    print(report.edge("count").latency_p99)
"""

from ..state.window import WindowOp  # keyed operator state on a Stage
from .configs import (SCHEME_CONFIGS, DChoicesConfig, FieldConfig,
                      FishConfig, PKGConfig, SchemeConfig, ShuffleConfig,
                      WChoicesConfig, build_grouper, config_for)
from .engine import (EdgeReport, Engine, RemapAccountant, ServingSession,
                     ServingTopologyEngine, Session, SimulatorEngine,
                     SimulatorSession, TopologyReport)
from .graph import (SOURCE, Edge, KeyTransform, RecordBatch, ScopedEvent,
                    Source, Stage, Topology, hashed_fanout, project_mod)

__all__ = [
    "SCHEME_CONFIGS",
    "SchemeConfig",
    "ShuffleConfig",
    "FieldConfig",
    "PKGConfig",
    "DChoicesConfig",
    "WChoicesConfig",
    "FishConfig",
    "config_for",
    "build_grouper",
    "SOURCE",
    "KeyTransform",
    "hashed_fanout",
    "project_mod",
    "Stage",
    "Edge",
    "Topology",
    "RecordBatch",
    "Source",
    "ScopedEvent",
    "WindowOp",
    "Engine",
    "Session",
    "EdgeReport",
    "TopologyReport",
    "RemapAccountant",
    "SimulatorEngine",
    "SimulatorSession",
    "ServingTopologyEngine",
    "ServingSession",
]
