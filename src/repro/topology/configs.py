"""Typed grouping-scheme configs — the declarative face of the registry.

One frozen dataclass per scheme (paper §2.2 baselines + FISH), each with
eager validation and a ``build(num_workers)`` method that constructs the
matching :class:`~repro.core.baselines.Grouper`.  An :class:`Edge` in a
:class:`~repro.topology.graph.Topology` carries one of these configs, so a
whole dataflow DAG is a plain, hashable, printable value — no stringly-typed
``make_grouper(name, **kwargs)`` plumbing.

The registry here is the single source of truth for scheme names.  The
legacy ``repro.core.baselines.make_grouper`` entry point is a shim over
:func:`legacy_build` and emits a :class:`DeprecationWarning`; internal code
uses :func:`build_grouper` (accepts a name or a config) or the configs
directly.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Type

import numpy as np

from ..core.baselines import (DChoices, FieldGrouping, FishGrouper, Grouper,
                              PartialKeyGrouping, ShuffleGrouping, WChoices)
from ..core.fish import FishParams

__all__ = [
    "SchemeConfig",
    "ShuffleConfig",
    "FieldConfig",
    "PKGConfig",
    "DChoicesConfig",
    "WChoicesConfig",
    "FishConfig",
    "SCHEME_CONFIGS",
    "config_for",
    "build_grouper",
    "legacy_build",
]


def _check_positive_int(name: str, value: int) -> None:
    if not isinstance(value, int) or value < 1:
        raise ValueError(f"{name} must be a positive int, got {value!r}")


@dataclasses.dataclass(frozen=True)
class SchemeConfig:
    """Base class for per-scheme typed configs.

    Subclasses set ``scheme`` (the registry name) and override
    :meth:`build`.  Configs are frozen values: reusable across edges and
    topologies; ``build`` always returns a *fresh* grouper.
    """

    scheme: ClassVar[str] = "base"

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        """Construct a fresh grouper for ``num_workers`` workers.

        ``capacities`` (seconds/tuple per worker) is honored by
        capacity-aware schemes (FISH) and ignored by the rest.
        """
        raise NotImplementedError

    def _check_workers(self, num_workers: int) -> None:
        _check_positive_int("num_workers", num_workers)


@dataclasses.dataclass(frozen=True)
class ShuffleConfig(SchemeConfig):
    """SG — round-robin over the live worker set; ignores the key."""

    scheme: ClassVar[str] = "sg"

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        self._check_workers(num_workers)
        return ShuffleGrouping(num_workers)


@dataclasses.dataclass(frozen=True)
class FieldConfig(SchemeConfig):
    """FG — single owner per key (nearest live worker on the ring)."""

    scheme: ClassVar[str] = "fg"
    virtual_nodes: int = 64

    def __post_init__(self) -> None:
        _check_positive_int("virtual_nodes", self.virtual_nodes)

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        self._check_workers(num_workers)
        return FieldGrouping(num_workers, virtual_nodes=self.virtual_nodes)


@dataclasses.dataclass(frozen=True)
class PKGConfig(SchemeConfig):
    """PKG — power-of-two-choices between the first 2 ring candidates."""

    scheme: ClassVar[str] = "pkg"
    virtual_nodes: int = 64

    def __post_init__(self) -> None:
        _check_positive_int("virtual_nodes", self.virtual_nodes)

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        self._check_workers(num_workers)
        return PartialKeyGrouping(num_workers,
                                  virtual_nodes=self.virtual_nodes)


@dataclasses.dataclass(frozen=True)
class DChoicesConfig(SchemeConfig):
    """D-Choices — lifetime heavy hitters get d ring candidates."""

    scheme: ClassVar[str] = "dc"
    k_max: int = 1000
    theta_frac: float = 0.25

    def __post_init__(self) -> None:
        _check_positive_int("k_max", self.k_max)
        if self.theta_frac <= 0.0:
            # theta = theta_frac / W; the paper sweeps up to 2/n (Fig. 13)
            raise ValueError(f"theta_frac must be positive, got "
                             f"{self.theta_frac!r}")

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        self._check_workers(num_workers)
        return DChoices(num_workers, k_max=self.k_max,
                        theta_frac=self.theta_frac)


@dataclasses.dataclass(frozen=True)
class WChoicesConfig(DChoicesConfig):
    """W-Choices — heavy hitters may use the entire live worker set."""

    scheme: ClassVar[str] = "wc"

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        self._check_workers(num_workers)
        return WChoices(num_workers, k_max=self.k_max,
                        theta_frac=self.theta_frac)


@dataclasses.dataclass(frozen=True)
class FishConfig(SchemeConfig):
    """FISH — Alg. 1 epoch decay + Alg. 2 CHK + Alg. 3 assignment over
    consistent-hash candidates (the paper's grouper, Table 1 defaults)."""

    scheme: ClassVar[str] = "fish"
    alpha: float = 0.2
    epoch: int = 1000
    k_max: int = 1000
    theta_frac: float = 0.25
    d_min: int = 2
    interval: float = 10.0
    virtual_nodes: int = 64
    use_consistent_hash: bool = True

    def __post_init__(self) -> None:
        if not 0.0 <= self.alpha <= 1.0:
            raise ValueError(f"alpha must be in [0, 1], got {self.alpha!r}")
        _check_positive_int("epoch", self.epoch)
        _check_positive_int("k_max", self.k_max)
        _check_positive_int("d_min", self.d_min)
        _check_positive_int("virtual_nodes", self.virtual_nodes)
        if self.theta_frac <= 0.0:
            # theta = theta_frac / W; the paper sweeps up to 2/n (Fig. 13)
            raise ValueError(f"theta_frac must be positive, got "
                             f"{self.theta_frac!r}")
        if self.interval <= 0.0:
            raise ValueError(f"interval must be positive, got "
                             f"{self.interval!r}")

    def to_params(self) -> FishParams:
        return FishParams(alpha=self.alpha, epoch=self.epoch,
                          k_max=self.k_max, theta_frac=self.theta_frac,
                          d_min=self.d_min)

    @classmethod
    def from_params(cls, params: FishParams, **overrides) -> "FishConfig":
        return cls(alpha=params.alpha, epoch=params.epoch,
                   k_max=params.k_max, theta_frac=params.theta_frac,
                   d_min=params.d_min, **overrides)

    def build(self, num_workers: int,
              capacities: Optional[np.ndarray] = None) -> Grouper:
        self._check_workers(num_workers)
        return FishGrouper(
            num_workers,
            params=self.to_params(),
            capacities=capacities,
            interval=self.interval,
            virtual_nodes=self.virtual_nodes,
            use_consistent_hash=self.use_consistent_hash,
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

SCHEME_CONFIGS: Dict[str, Type[SchemeConfig]] = {
    c.scheme: c for c in (ShuffleConfig, FieldConfig, PKGConfig,
                          DChoicesConfig, WChoicesConfig, FishConfig)
}

# grouper classes keyed by scheme name — the legacy **kwargs constructor path
_GROUPER_CLASSES: Dict[str, Type[Grouper]] = {
    "sg": ShuffleGrouping,
    "fg": FieldGrouping,
    "pkg": PartialKeyGrouping,
    "dc": DChoices,
    "wc": WChoices,
    "fish": FishGrouper,
}


def config_for(scheme: str, **overrides) -> SchemeConfig:
    """Default typed config for ``scheme``, with field overrides."""
    try:
        cls = SCHEME_CONFIGS[scheme.lower()]
    except KeyError:
        raise ValueError(f"unknown grouping scheme {scheme!r}; one of "
                         f"{sorted(SCHEME_CONFIGS)}")
    return cls(**overrides)


def build_grouper(spec, num_workers: int,
                  capacities: Optional[np.ndarray] = None) -> Grouper:
    """Build a grouper from a :class:`SchemeConfig` or a scheme name.

    The non-deprecated internal entry point: string specs resolve to the
    default config for that scheme.
    """
    if isinstance(spec, SchemeConfig):
        return spec.build(num_workers, capacities=capacities)
    if isinstance(spec, str):
        return config_for(spec).build(num_workers, capacities=capacities)
    raise TypeError(f"grouping spec must be a SchemeConfig or scheme name, "
                    f"got {type(spec).__name__}")


def legacy_build(name: str, num_workers: int, **kwargs) -> Grouper:
    """Construct a grouper class directly with legacy ``**kwargs`` — the
    implementation behind the deprecated ``make_grouper`` shim."""
    try:
        cls = _GROUPER_CLASSES[name.lower()]
    except KeyError:
        raise ValueError(f"unknown grouping scheme {name!r}; one of "
                         f"{sorted(_GROUPER_CLASSES)}")
    return cls(num_workers, **kwargs)
