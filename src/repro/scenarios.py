"""Declarative time-evolving scenario subsystem (ISSUE 2 tentpole).

The paper's whole argument is behavior under *time-evolving* conditions
(§5, RQ4, Figs. 7/17): hot-key drift, heterogeneous/straggling workers, and
elastic membership.  A :class:`Scenario` composes those three orthogonal
axes declaratively:

* **workload** — the key distribution over time (:class:`WorkloadSpec`):
  the §6.1 ZF hot-key flip or piecewise-Zipf hot-set drift.
* **capacity** — static heterogeneity (Fig. 7 fast/slow worker mix) plus a
  straggler onset/recovery episode (:class:`CapacitySpec`).
* **churn** — membership ops over the stream (:class:`ChurnOp`):
  scale-out/in and failures.

A scenario compiles to a single-edge :class:`~repro.topology.Topology`
plus :class:`~repro.topology.ScopedEvent` records and runs through the
unified engine protocol (ISSUE 3): :func:`run_dspe_scenario` drives
:class:`~repro.topology.SimulatorEngine` (batched or per-tuple reference
mode) and returns the flattened :class:`~repro.topology.EdgeReport` row;
:func:`run_serving_scenario` drives the continuous-batching
:class:`~repro.serving.engine.ServingEngine` with the full runtime control
plane in the loop: failures are *detected* by
:class:`~repro.runtime.fault.HeartbeatMonitor`, adjudicated by
:class:`~repro.runtime.fault.RestartPolicy` (elastic-continue vs restart),
remap cost is accounted by :class:`~repro.runtime.elastic.ElasticPool`,
and stragglers are observed by
:class:`~repro.runtime.stragglers.StragglerMitigator`.

``benchmarks/bench_scenarios.py`` runs every grouping scheme through the
default scenario suite and emits ``artifacts/BENCH_scenarios.json``
(RQ4/Fig. 17 analogues: latency, throughput, memory overhead, and tuples
remapped per membership event).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .core import CapacityEvent, MembershipEvent
from .data.synthetic import piecewise_zipf, zipf_time_evolving
from .load import (ArrivalProcess, ConstantRate, DiurnalRate, FlashCrowd,
                   FlipZipfKeys, IngressQueue, OpenLoopDriver, P99Autoscaler,
                   ZipfKeys)
from .runtime.elastic import ElasticPool
from .runtime.fault import HeartbeatMonitor, RestartPolicy
from .runtime.stragglers import StragglerMitigator
from .serving.engine import Request, ServingEngine
from .state import KeyedStateManager, WindowOp, direct_aggregate
from .topology import (Edge, EdgeReport, RemapAccountant, ScopedEvent,
                       ServingTopologyEngine, SimulatorEngine, Source, Stage,
                       Topology, config_for)
from .topology.engine import _imbalance, _percentiles

__all__ = [
    "WorkloadSpec",
    "StragglerSpec",
    "CapacitySpec",
    "ChurnOp",
    "Scenario",
    "OpenLoopScenario",
    "RemapAccountant",  # re-exported from repro.topology.engine
    "build_keys",
    "compile_events",
    "base_capacities",
    "scenario_topology",
    "open_loop_topology",
    "run_dspe_scenario",
    "run_serving_scenario",
    "run_open_loop_scenario",
    "default_scenarios",
    "default_open_loop_scenarios",
]


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Key distribution over time.  ``zf_flip`` is the paper's §6.1 ZF
    generator (hot head flips at 0.8·N); ``piecewise`` rotates the hot set
    every N/phases tuples (the MemeTracker/Amazon-Movie proxy)."""

    kind: str = "zf_flip"  # "zf_flip" | "piecewise"
    num_tuples: int = 24_000
    num_keys: int = 2_400
    z: float = 1.2
    phases: int = 6  # piecewise only
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class StragglerSpec:
    """One worker slows down by ``slowdown``× at ``onset`` (stream fraction)
    and recovers at ``recovery``; ``recovery >= 1.0`` never recovers."""

    worker: int = 0
    onset: float = 0.3
    recovery: float = 0.7
    slowdown: float = 4.0


@dataclasses.dataclass(frozen=True)
class CapacitySpec:
    """``hetero`` lists relative worker speeds, cycled over the worker set
    (paper Fig. 7 fast/slow mix); empty means homogeneous."""

    hetero: Tuple[float, ...] = ()
    straggler: Optional[StragglerSpec] = None


@dataclasses.dataclass(frozen=True)
class ChurnOp:
    """Membership op at stream fraction ``at``: ``remove`` (failure /
    scale-in) or ``add`` (scale-out) of ``worker``."""

    at: float
    op: str  # "remove" | "add"
    worker: int


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    workers: int = 8
    arrival_rate: float = 20_000.0
    utilization: float = 0.9
    workload: WorkloadSpec = WorkloadSpec()
    capacity: CapacitySpec = CapacitySpec()
    churn: Tuple[ChurnOp, ...] = ()


# ---------------------------------------------------------------------------
# compilation: scenario -> (keys, events, capacities)
# ---------------------------------------------------------------------------


def build_keys(w: WorkloadSpec) -> np.ndarray:
    if w.kind == "zf_flip":
        return zipf_time_evolving(w.num_tuples, num_keys=w.num_keys, z=w.z,
                                  flip_head=max(w.num_keys // 3, 1),
                                  seed=w.seed)
    if w.kind == "piecewise":
        return piecewise_zipf(w.num_tuples, w.num_keys, z=w.z,
                              phases=w.phases, seed=w.seed)
    raise ValueError(f"unknown workload kind {w.kind!r}")


def relative_speeds(s: Scenario) -> np.ndarray:
    rel = np.ones(s.workers)
    if s.capacity.hetero:
        pat = np.asarray(s.capacity.hetero, dtype=np.float64)
        rel = pat[np.arange(s.workers) % pat.shape[0]]
    return rel


def base_capacities(s: Scenario) -> np.ndarray:
    """True seconds/tuple per worker such that aggregate utilisation is
    ``s.utilization`` at ``s.arrival_rate`` (matches the simulator's
    homogeneous convention ``0.9·W/λ`` when ``hetero`` is empty)."""
    rel = relative_speeds(s)
    return s.utilization * float(rel.sum()) / (s.arrival_rate * rel)


def compile_events(s: Scenario, n: int) -> List[object]:
    """Lower churn + straggler specs onto tuple-index event records."""
    caps0 = base_capacities(s)
    mean_cap = float(caps0.mean())
    events: List[object] = []
    live = set(range(s.workers))
    for op in sorted(s.churn, key=lambda o: o.at):
        at = int(op.at * n)
        if op.op == "remove":
            live.discard(op.worker)
        elif op.op == "add":
            live.add(op.worker)
            # newcomers get the mean base capacity unless a straggler spec
            # or later CapacityEvent says otherwise
            events.append(CapacityEvent(at=at,
                                        capacities={op.worker: mean_cap}))
        else:
            raise ValueError(f"unknown churn op {op.op!r}")
        events.append(MembershipEvent(at=at, workers=tuple(sorted(live))))
    st = s.capacity.straggler
    if st is not None:
        base = float(caps0[st.worker]) if st.worker < s.workers else mean_cap
        events.append(CapacityEvent(at=int(st.onset * n),
                                    capacities={st.worker: base * st.slowdown}))
        if st.recovery < 1.0:
            events.append(CapacityEvent(at=int(st.recovery * n),
                                        capacities={st.worker: base}))
    return events


# ---------------------------------------------------------------------------
# runners (through the unified topology engine protocol — ISSUE 3)
# ---------------------------------------------------------------------------

_STAGE = "worker"  # the single-hop scenario stage name


def scenario_topology(scenario: Scenario, scheme: str,
                      window: Optional[WindowOp] = None) -> Topology:
    """The scenario as a one-edge topology: source → grouped worker pool
    with the scenario's heterogeneous base capacities.  ``window`` attaches
    a keyed windowed aggregation to the worker stage (ISSUE 4): churn then
    exercises the state-migration protocol and the runner reports its cost
    and post-merge exactness."""
    return Topology(
        name=scenario.name,
        stages=(Stage(_STAGE, parallelism=scenario.workers,
                      capacities=tuple(base_capacities(scenario)),
                      operator=window),),
        edges=(Edge("source", _STAGE, config_for(scheme)),),
    )


def _state_row(summary: Dict, oracle: Dict) -> Dict:
    """Flatten a per-stage state summary + exactness vs the routing-free
    oracle into the scenario-report shape."""
    return {
        "migration_bytes": summary["migration_bytes"],
        "migration_events": summary["migration_events"],
        "tuples_replayed": summary["tuples_replayed"],
        "state_bytes_peak": summary["state_bytes_peak"],
        "partial_entries": summary["partial_entries"],
        "windows": summary["windows"],
        "exact": summary["merged"] == oracle,
    }


def run_dspe_scenario(
    scenario: Scenario,
    scheme: str,
    engine: str = "batched",
    sample_remap: int = 512,
    window: Optional[WindowOp] = None,
    feeds: int = 1,
) -> Dict:
    """Route the scenario's stream through ``scheme`` in the DSPE simulator
    and return the paper metrics plus per-event remap accounting.  With a
    ``window``, the worker stage runs the keyed aggregation and the report
    gains a ``state`` row: migration cost + post-merge exactness against
    the no-churn oracle (:func:`repro.state.direct_aggregate`).

    ``feeds`` > 1 replays the scenario through the streaming session API
    (ISSUE 5): the stream is cut into that many record batches fed
    incrementally, with all churn/straggler events registered up front —
    the long-running-DSPE execution mode (``feeds=1`` is the one-shot
    ``run()``, bit-identical to feeding a single batch)."""
    keys = build_keys(scenario.workload)
    n = int(keys.shape[0])
    events = [ScopedEvent(_STAGE, e) for e in compile_events(scenario, n)]
    sim = SimulatorEngine(mode=engine, remap_sample=sample_remap)
    topo = scenario_topology(scenario, scheme, window)
    source = Source(keys, arrival_rate=scenario.arrival_rate)
    if feeds <= 1:
        rep = sim.run(topo, source, events)
    else:
        session = sim.open(topo, arrival_rate=scenario.arrival_rate)
        session.advance(events)
        for batch in source.iter_batches(batch_size=-(-n // feeds)):
            session.feed(batch)
        rep = session.close()
    er = rep.edge(_STAGE)
    out = {"scheme": scheme, "engine": engine, "n_tuples": n,
           "feeds": feeds}
    out.update(er.row())
    out["remap_events"] = er.remap_events
    out["remap_frac_mean"] = er.remap_frac_mean
    if window is not None:
        out["state"] = _state_row(rep.state[_STAGE],
                                  direct_aggregate(keys, window))
    return out


def run_serving_scenario(
    scenario: Scenario,
    scheme: str,
    num_requests: int = 160,
    slots_per_replica: int = 4,
    heartbeat_timeout: float = 3.0,
    max_ticks: int = 50_000,
    seed: int = 0,
    window: Optional[WindowOp] = None,
) -> Dict:
    """Drive the ServingEngine through the scenario with the runtime control
    plane in the loop.

    Requests carry session keys drawn from the scenario workload (so session
    popularity is time-evolving).  Churn ``remove`` ops silence a replica's
    heartbeat: the HeartbeatMonitor declares it dead, the RestartPolicy
    chooses elastic-continue, and ``ServingEngine.fail_replica`` requeues the
    orphans; the ElasticPool accounts session remap cost.  ``add`` ops scale
    the engine out.  A straggler episode changes the replica's true speed
    mid-run; the StragglerMitigator must finger it from speed samples alone.

    With a ``window`` (ISSUE 4), per-replica keyed session state is
    maintained alongside the engine: each request folds into its session's
    window entry on the replica it was routed to, replica failure/scale-out
    runs the state-migration protocol, and the report gains a ``state`` row
    (migration cost + post-merge exactness vs the routing-free oracle).
    """
    rng = np.random.default_rng(seed)
    keys = build_keys(scenario.workload)
    sessions = keys[np.linspace(0, keys.shape[0] - 1, num_requests)
                    .astype(np.int64)]
    rel = relative_speeds(scenario)

    # the scheme name (not config_for(scheme)) keeps the engine's serving
    # default of a 4-tick FISH estimator interval
    eng = ServingEngine(scenario.workers,
                        slots_per_replica=slots_per_replica,
                        tokens_per_tick=rel, grouping=scheme)
    pool = ElasticPool(range(scenario.workers))
    mon = HeartbeatMonitor(range(scenario.workers),
                           timeout=heartbeat_timeout)
    mit = StragglerMitigator(scenario.workers, interval=4.0)
    for r in range(scenario.workers):
        mit.record_step_time(r, 1.0 / rel[r])

    stats = {"rerouted": 0, "remap_fracs": [], "policy_outcomes": [],
             "straggler_detected": False}
    sample_sessions = [int(k) for k in np.unique(sessions)]
    mgr = KeyedStateManager(window) if window is not None else None
    fed_keys: List[int] = []  # oracle input: sessions actually submitted

    def on_rescale(alive: List[int]) -> None:
        for dead in [r for r in eng.alive if r not in alive]:
            if mgr is not None:
                mgr.on_event("pre_membership", eng.router, None)
            stats["rerouted"] += eng.fail_replica(dead)
            if mgr is not None:
                mgr.on_event("post_membership", eng.router, None)
            if dead in pool.ring:
                moved = pool.remove_host(dead, sample_sessions)
                stats["remap_fracs"].append(moved / max(len(sample_sessions), 1))

    policy = RestartPolicy(total_hosts=scenario.workers,
                           max_lost_frac=0.49, on_rescale=on_rescale)

    # request arrivals spread over ~60% of the nominal decode horizon
    tokens = rng.integers(4, 12, num_requests)
    horizon = max(int(1.7 * tokens.sum() / max(rel.sum(), 1e-9)), num_requests)
    arrive_at = np.linspace(0, int(0.6 * horizon), num_requests).astype(int)
    reqs = [Request(i, int(s), arrival=float(a), target_tokens=int(t))
            for i, (s, a, t) in enumerate(zip(sessions, arrive_at, tokens))]

    silenced: set = set()
    prev_routed = eng.router.assigned_counts.copy()
    pending_ops = sorted(
        [(int(op.at * 0.6 * horizon), op) for op in scenario.churn],
        key=lambda x: x[0])
    st = scenario.capacity.straggler
    straggle_at = int(st.onset * 0.6 * horizon) if st else None
    recover_at = (int(st.recovery * 0.6 * horizon)
                  if st and st.recovery < 1.0 else None)

    next_req = 0
    t = 0
    while len(eng.done) < num_requests and t < max_ticks:
        now = eng.now
        while next_req < num_requests and arrive_at[next_req] <= t:
            eng.submit(reqs[next_req])
            if mgr is not None:  # fold into keyed state exactly once
                mgr.feed(sessions[next_req:next_req + 1],
                         np.array([reqs[next_req].replica]))
                fed_keys.append(int(sessions[next_req]))
            next_req += 1
        while pending_ops and pending_ops[0][0] <= t:
            _, op = pending_ops.pop(0)
            if op.op == "remove":
                # crash: decodes nothing from now on and goes silent; the
                # router keeps black-holing requests at it until the
                # heartbeat monitor notices and fail_replica requeues them
                silenced.add(op.worker)
                eng.speeds[op.worker] = 0.0
            elif op.op == "add":
                if mgr is not None:
                    mgr.on_event("pre_membership", eng.router, None)
                r = eng.add_replica(speed=1.0, slots=slots_per_replica)
                if mgr is not None:
                    mgr.on_event("post_membership", eng.router, None)
                policy.total = eng.num_replicas
                mon.heartbeat(r, now)
                pool.add_host(r, sample_sessions)
                mit.ensure_hosts(eng.num_replicas)
                mit.record_step_time(r, 1.0)
        if straggle_at is not None and t == straggle_at:
            eng.set_replica_speed(st.worker, float(rel[st.worker]) / st.slowdown)
        if recover_at is not None and t == recover_at:
            eng.set_replica_speed(st.worker, float(rel[st.worker]))
        # Eq. 1 bookkeeping: work *sent* per replica since the last tick is
        # the router's assigned-count delta (arrays grow on scale-out)
        routed = eng.router.assigned_counts
        if routed.shape[0] > prev_routed.shape[0]:
            prev_routed = np.concatenate(
                [prev_routed,
                 np.zeros(routed.shape[0] - prev_routed.shape[0],
                          dtype=prev_routed.dtype)])
        delta = routed - prev_routed
        prev_routed = routed.copy()
        for r in eng.alive:
            if r not in silenced:  # a dead host emits no samples
                mon.heartbeat(r, now)
                mit.record_step_time(r, 1.0 / max(float(eng.speeds[r]), 1e-9))
                mit.record_assigned(r, int(delta[r]))
        mit.tick(now)
        if mon.check(now):
            stats["policy_outcomes"].append(policy.handle(mon, now))
        if st and t > (straggle_at or 0) and mit.slowest() == st.worker:
            stats["straggler_detected"] = True
        eng.tick()
        t += 1

    m = eng.metrics()
    lats = np.array([r.finished - r.arrival for r in eng.done
                     if r.finished >= 0])
    avg, p50, p95, p99 = _percentiles(lats)
    report = EdgeReport(  # the unified per-edge schema (TopologyReport rows)
        edge=f"source->{_STAGE}", src="source", dst=_STAGE, scheme=scheme,
        workers=eng.num_replicas, n_tuples=num_requests,
        execution_time=float(eng.now), latency_avg=avg, latency_p50=p50,
        latency_p95=p95, latency_p99=p99,
        throughput=m.throughput_tokens,
        memory_overhead=eng.router.memory_overhead(),
        memory_overhead_norm=m.session_replicas_norm,
        imbalance=_imbalance(eng.router.assigned_counts),
        remap_frac_mean=(float(np.mean(stats["remap_fracs"]))
                         if stats["remap_fracs"] else None),
        dropped=num_requests - len(eng.done),
    )
    state_row = None
    if mgr is not None:
        mgr.finalize()
        state_row = _state_row(
            mgr.report(_STAGE).summary(),
            direct_aggregate(np.asarray(fed_keys, dtype=np.int64), window))
    return {
        "scheme": scheme,
        "completed": len(eng.done),
        "submitted": num_requests,
        "state": state_row,
        "ticks": t,
        "latency_avg": m.latency_avg,
        "latency_p50": m.latency_p50,
        "latency_p99": m.latency_p99,
        "throughput_tokens": m.throughput_tokens,
        "session_replicas": m.session_replicas,
        "session_replicas_norm": m.session_replicas_norm,
        "rerouted": stats["rerouted"],
        "remap_fracs": stats["remap_fracs"],
        "policy_outcomes": stats["policy_outcomes"],
        "straggler_detected": stats["straggler_detected"],
        "report": report.to_dict(),
    }


# ---------------------------------------------------------------------------
# default suite (the bench + CI smoke surface)
# ---------------------------------------------------------------------------


def default_scenarios(num_tuples: int = 24_000, num_keys: int = 2_400,
                      workers: int = 8) -> List[Scenario]:
    """The RQ4 scenario suite: hot-key flip, straggler onset/recovery on a
    heterogeneous pool, scale-out, failure with elastic continue, and a
    composite churn storm."""
    return [
        Scenario(
            "hot_key_flip", workers=workers,
            workload=WorkloadSpec("zf_flip", num_tuples, num_keys, z=1.4),
        ),
        Scenario(
            "straggler_recovery", workers=workers,
            workload=WorkloadSpec("piecewise", num_tuples, num_keys,
                                  z=1.2, phases=6),
            capacity=CapacitySpec(
                hetero=(2.0, 1.0),  # Fig. 7 fast/slow mix
                straggler=StragglerSpec(worker=1, onset=0.25, recovery=0.65,
                                        slowdown=4.0),
            ),
        ),
        Scenario(
            "scale_out", workers=workers,
            workload=WorkloadSpec("piecewise", num_tuples, num_keys,
                                  z=1.2, phases=4),
            churn=(ChurnOp(0.5, "add", workers),),
        ),
        Scenario(
            "failure_elastic", workers=workers,
            workload=WorkloadSpec("zf_flip", num_tuples, num_keys, z=1.2),
            churn=(ChurnOp(0.4, "remove", workers - 1),),
        ),
        Scenario(
            "churn_storm", workers=workers,
            workload=WorkloadSpec("piecewise", num_tuples, num_keys,
                                  z=1.3, phases=8),
            capacity=CapacitySpec(
                straggler=StragglerSpec(worker=0, onset=0.5, recovery=0.8,
                                        slowdown=3.0),
            ),
            churn=(ChurnOp(0.3, "remove", workers - 1),
                   ChurnOp(0.6, "add", workers)),
        ),
    ]


# ---------------------------------------------------------------------------
# open-loop scenarios (ISSUE 8): arrival-schedule-driven runs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpenLoopScenario:
    """A scenario driven by an *arrival process* instead of a pre-built
    stream: records arrive on a wall-clock tick grid whether or not the
    engine keeps up, pass through a bounded ingress queue under an
    admission ``policy``, and overload shows up as queueing delay / shed —
    not as a silently stretched input schedule.

    Worker capacity is **load-independent**: ``cost()`` is calibrated so
    the pool runs at ``utilization`` when offered exactly ``rate``; the
    diurnal/flash modulation then moves the *actual* utilisation around
    that operating point.  ``slo_p99`` (seconds, total latency) arms the
    :class:`~repro.load.P99Autoscaler` between ``workers`` and
    ``max_workers``."""

    name: str
    workers: int = 4
    rate: float = 2_000.0        # mean offered tuples/s
    horizon: float = 4.0         # seconds of arrivals
    tick: float = 0.05           # arrival tick (s); one feed per tick
    num_keys: int = 512
    z: float = 1.2
    utilization: float = 0.8     # pool utilisation at the mean rate
    diurnal_amplitude: float = 0.0       # 0: constant base rate
    diurnal_period: Optional[float] = None  # default: one cycle per horizon
    flash: Optional[Tuple[float, float, float]] = None  # (at, dur, magnitude)
    flip_time: Optional[float] = None    # hot-key flip instant (FlipZipfKeys)
    queue_capacity: int = 4_096
    policy: str = "shed"
    backpressure: Optional[float] = 0.5  # engine-backlog threshold (s)
    slo_p99: Optional[float] = None      # arm the autoscaler when set
    max_workers: int = 16
    seed: int = 0

    def cost(self) -> float:
        """Seconds/tuple per worker: ``utilization · W / rate``, fixed
        regardless of the instantaneous offered load."""
        return self.utilization * self.workers / self.rate

    def rate_fn(self):
        fn = ConstantRate(self.rate)
        if self.diurnal_amplitude > 0.0:
            fn = fn * DiurnalRate(amplitude=self.diurnal_amplitude,
                                  period=self.diurnal_period or self.horizon)
        if self.flash is not None:
            at, duration, magnitude = self.flash
            fn = fn * FlashCrowd(at=at, duration=duration,
                                 magnitude=magnitude,
                                 ramp=min(duration / 4.0, 2 * self.tick))
        return fn

    def key_fn(self):
        if self.flip_time is not None:
            return FlipZipfKeys(self.num_keys, z=self.z,
                                flip_time=self.flip_time)
        return ZipfKeys(self.num_keys, z=self.z)

    def arrivals(self) -> ArrivalProcess:
        """A fresh (deterministically seeded) arrival process per call."""
        return ArrivalProcess(self.rate_fn(), self.key_fn(),
                              tick=self.tick, seed=self.seed)


def open_loop_topology(ol: OpenLoopScenario, scheme: str,
                       window: Optional[WindowOp] = None) -> Topology:
    """One-edge topology with *fixed* per-worker cost (unlike
    :func:`scenario_topology`, capacity must not depend on offered load —
    the load sweep is the whole point).  ``window`` attaches keyed state,
    so autoscaler membership events incur tick-billed state migration."""
    return Topology(
        name=ol.name,
        stages=(Stage(_STAGE, parallelism=ol.workers, cost=ol.cost(),
                      operator=window),),
        edges=(Edge("source", _STAGE, config_for(scheme)),),
    )


def run_open_loop_scenario(
    ol: OpenLoopScenario,
    scheme: str,
    engine: str = "batched",
    drain: bool = True,
    ticks_per_second: float = 1_000.0,
    slots_per_replica: int = 4,
    max_queue_per_replica: Optional[int] = 64,
    migration_cost_per_byte: float = 0.0,
    window: Optional[WindowOp] = None,
) -> Dict:
    """Drive the scenario open loop and return a flattened report row.

    ``engine`` is a simulator mode (``batched``/``reference``/``fused``)
    or ``"serving"`` (arrival-paced continuous batching; engine ticks are
    mapped to arrival seconds via ``ticks_per_second``, and the bounded
    replica queues add an engine-side shed level below the ingress
    queue's).  The returned row carries the two-level admission identity
    fields (``offered == fed + shed_ingress + residual``)."""
    arrivals = ol.arrivals()
    topo = open_loop_topology(ol, scheme, window)
    if engine == "serving":
        eng = ServingTopologyEngine(
            slots_per_replica=slots_per_replica,
            pacing="arrival", ticks_per_second=ticks_per_second,
            max_queue_per_replica=max_queue_per_replica,
            migration_ticks_per_byte=migration_cost_per_byte)
        session = eng.open(topo, arrival_rate=ol.rate)
    else:
        sim = SimulatorEngine(mode=engine,
                              migration_cost_per_byte=migration_cost_per_byte)
        session = sim.open(topo, arrival_rate=ol.rate)
    serving = engine == "serving"
    autoscaler = None
    if ol.slo_p99 is not None:
        # receipt latencies are engine-clock (simulator: seconds; serving:
        # ticks); window/cooldown compare driver seconds and need no scaling
        slo = ol.slo_p99 * (ticks_per_second if serving else 1.0)
        autoscaler = P99Autoscaler(
            _STAGE, slo_p99=slo, workers=range(ol.workers),
            max_workers=ol.max_workers,
            window=max(10 * ol.tick, 0.5),
            cooldown=max(10 * ol.tick, 0.5),
            sample_keys=range(ol.num_keys))
    # the serving receipt's backlog is queued *requests*; a threshold of
    # `backpressure` seconds of work corresponds to rate·backpressure of
    # them, and the pool drains them at about the provisioned rate
    driver = OpenLoopDriver(
        session, IngressQueue(ol.queue_capacity, policy=ol.policy,
                              seed=ol.seed),
        backpressure=(None if ol.backpressure is None else
                      ol.backpressure * (ol.rate if serving else 1.0)),
        backlog_decay=ol.rate if serving else 1.0,
        autoscaler=autoscaler)
    rep = driver.run(arrivals, 0.0, ol.horizon, drain=drain)
    er = rep.topology.edge(_STAGE)
    out = {"scenario": ol.name, "scheme": scheme, "engine": engine,
           "policy": ol.policy,
           "offered": rep.offered, "fed": rep.fed, "shed": rep.shed,
           "shed_ingress": rep.shed_ingress, "shed_engine": rep.shed_engine,
           "deferred": rep.deferred, "residual": rep.residual,
           "identity_ok": driver.queue.check_identity(),
           "queue_depth_peak": rep.queue_depth_peak,
           "queue_delay_avg": rep.queue_delay_avg,
           "queue_delay_p99": rep.queue_delay_p99,
           "total_latency_avg": rep.total_latency_avg,
           "total_latency_p99": rep.total_latency_p99,
           "autoscale_events": rep.autoscale_events,
           "workers_final": (autoscaler.workers if autoscaler is not None
                             else list(range(ol.workers))),
           "migration_stall": rep.topology.migration_stall}
    out.update(er.row())
    return out


def default_open_loop_scenarios(rate: float = 2_000.0, horizon: float = 4.0,
                                workers: int = 4,
                                num_keys: int = 512) -> List[OpenLoopScenario]:
    """The two ISSUE-8 open-loop scenarios: a flash crowd over a steady
    Zipf workload (overload → bounded queue + shed), and a diurnal rate
    with a mid-run hot-key flip (drift under time-varying load, deferred
    admission so nothing is lost)."""
    return [
        OpenLoopScenario(
            "flash_crowd", workers=workers, rate=rate, horizon=horizon,
            num_keys=num_keys, z=1.2,
            flash=(0.4 * horizon, 0.25 * horizon, 3.0),
            queue_capacity=max(int(0.05 * rate * horizon), 64),
            policy="shed", backpressure=0.25,
        ),
        OpenLoopScenario(
            "diurnal_hot_key_flip", workers=workers, rate=rate,
            horizon=horizon, num_keys=num_keys, z=1.4,
            diurnal_amplitude=0.5, flip_time=0.5 * horizon,
            queue_capacity=max(int(0.05 * rate * horizon), 64),
            policy="defer", backpressure=0.5,
        ),
    ]
