"""AST lint engine with JAX-aware rules (ISSUE 7, layer 1).

Rules (see DESIGN.md §12 for the catalog with rationale):

==================== =========================================================
rule id              fires on
==================== =========================================================
host-sync-in-jit     ``np.asarray``/``np.array``/``.item()``/``.tolist()``/
                     ``float()``/``bool()`` applied to non-constant values
                     inside jit-traced code — each forces a device→host sync
                     (or, on statics, work that belongs before the jit
                     boundary) in the middle of a fused launch.
retrace-hazard       jit signatures that recompile per call: float-annotated
                     or mutable-default ``static_argnames``, and ``jax.jit``
                     invoked inside a function body without a signature cache.
np-jnp-mixing        ``np.*`` ops or module-level ``np.*`` constants
                     referenced inside traced code — constant-folds host
                     arrays into device programs and breaks dtype discipline.
frozen-mutation      writes to ``RecordBatch`` columns or frozen-dataclass
                     fields (``object.__setattr__`` outside ``__post_init__``,
                     column element stores, column rebinds).
deprecated-shim      call sites of ``make_grouper`` / ``simulate_stream`` /
                     ``simulate_stream_reference`` — runtime
                     DeprecationWarnings promoted to review-time findings.
unordered-iteration  ``for``/comprehension iteration directly over set-valued
                     expressions — hash-seed order feeding routing, scatter,
                     or ring mutation order.
exactness-contract   local redefinitions of ``EXACT_SCHEMES`` /
                     ``BANDED_SCHEMES`` / ``DRIFT_SCHEMES`` / ``EXACTNESS``
                     instead of importing :mod:`repro.analysis.contracts`.
topology-config      literal ``config_for``/``Stage``/``Edge``/``Topology``
                     constructs that the runtime validators would reject —
                     the build error, promoted to before the run.
registry-counter-    direct stores to registry-backed counters (ISSUE 9):
mutation             ``TRACE_COUNT``/``dispatches`` through an imported-module
                     alias, or ``self.shed``/``queue_depth_peak``/
                     ``in_flight_peak``/``dispatches`` inside an Engine/Runner
                     class — writes that bypass the MetricsRegistry cell.
int32-overflow       narrow-int accumulators whose magnitude scales with
                     stream length — wrap past 2³¹ at ``SCALE_TARGET``
                     (:mod:`repro.analysis.numerics`).
unseeded-rng         global-state ``np.random.*`` / stdlib ``random`` calls
                     and seedless Generator construction — destroys seeded
                     replay (:mod:`repro.analysis.determinism`).
wall-clock-leak      ``time.*``/``datetime.now`` values escaping a function
                     outside the declared obs stamp points.
unbounded-signature  jit caches keyed by tuples with statically unbounded
                     elements — recompile per distinct value.
interproc-unordered- ``for``/comprehension over a *call* to a set-returning
iteration            function, same-module or imported
                     (:mod:`repro.analysis.callgraph`).
==================== =========================================================

The engine is a two-pass design: pass 1 builds a :class:`ModuleInfo`
(scopes, function defs, jit roots, the traced-set closure, numpy aliases);
pass 2 runs each rule over the annotated tree.  The traced set is the
transitive closure of jit roots over same-module references, including
free-variable aliases (``fifo = _fifo_scan if ... else _fifo_assoc``) and
nested defs, so rules see exactly the code that runs under ``jax.jit``.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding

__all__ = ["RULES", "lint_file", "lint_paths", "iter_python_files"]

RULES: Tuple[str, ...] = (
    "host-sync-in-jit",
    "retrace-hazard",
    "np-jnp-mixing",
    "frozen-mutation",
    "deprecated-shim",
    "unordered-iteration",
    "exactness-contract",
    "topology-config",
    "registry-counter-mutation",
    # ISSUE 10: numerics + determinism (see numerics.py / determinism.py /
    # callgraph.py; registered below through late-import wrappers)
    "int32-overflow",
    "unseeded-rng",
    "wall-clock-leak",
    "unbounded-signature",
    "interproc-unordered-iteration",
)

_SHIMS = {
    "make_grouper": "build_grouper(config_for(scheme)) from repro.topology",
    "simulate_stream": "StreamSession or repro.core.stream.simulate_edge",
    "simulate_stream_reference":
        "simulate_edge(..., mode='reference') or a reference StreamSession",
}

_HOST_SYNC_BUILTINS = {"float", "bool"}
_HOST_SYNC_METHODS = {"item", "tolist"}
_NP_HOST_FUNCS = {"asarray", "array"}
_RECORDBATCH_COLS = {"keys", "values", "timestamps"}
_CONTRACT_NAMES = {"EXACT_SCHEMES", "BANDED_SCHEMES", "DRIFT_SCHEMES",
                   "EXACTNESS"}
_SET_METHODS = {"difference", "union", "intersection",
                "symmetric_difference"}
_ORDER_NEUTRAL_SINKS = {"sorted", "set", "frozenset", "len", "sum", "min",
                        "max", "any", "all"}


# ---------------------------------------------------------------------------
# pass 1: module annotation
# ---------------------------------------------------------------------------


def _annotate(tree: ast.Module) -> None:
    """Attach ``_parent`` and ``_scope`` (enclosing qualname) to every node."""

    def walk(node: ast.AST, parent: Optional[ast.AST], scope: str) -> None:
        node._parent = parent          # type: ignore[attr-defined]
        node._scope = scope            # type: ignore[attr-defined]
        child_scope = scope
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            child_scope = (node.name if scope == "<module>"
                           else f"{scope}.{node.name}")
            node._scope = child_scope  # the def itself fingerprints inward
        for child in ast.iter_child_nodes(node):
            walk(child, node, child_scope)

    walk(tree, None, "<module>")


def _is_jit_ref(node: ast.AST) -> bool:
    """``jit`` / ``jax.jit`` (any attribute path ending in ``.jit``)."""
    if isinstance(node, ast.Name):
        return node.id == "jit"
    return isinstance(node, ast.Attribute) and node.attr == "jit"


def _is_partial_ref(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id == "partial"
    return isinstance(node, ast.Attribute) and node.attr == "partial"


def _literal(node: ast.AST):
    """(True, value) when the node is a pure literal, else (False, None)."""
    try:
        return True, ast.literal_eval(node)
    except (ValueError, TypeError, SyntaxError, MemoryError):
        return False, None


class ModuleInfo:
    def __init__(self, path: Path, rel: str, tree: ast.Module) -> None:
        self.path = path
        self.rel = rel
        self.tree = tree
        _annotate(tree)

        # every function def, by bare name (nested included; last wins)
        self.funcs: Dict[str, ast.AST] = {}
        # names of callables aliased through plain / conditional assignment
        self.aliases: Dict[str, Set[str]] = {}
        # numpy import aliases in this module
        self.np_aliases: Set[str] = set()
        # module-level names whose value is built from numpy
        self.np_globals: Dict[str, int] = {}
        # jit call sites: (call node, resolved target def or None)
        self.jit_calls: List[Tuple[ast.Call, Optional[ast.AST]]] = []

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.funcs[node.name] = node
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.np_aliases.add(a.asname or "numpy")
            elif isinstance(node, ast.Assign):
                self._record_assign(node)

        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_jit_ref(node.func):
                target = None
                if node.args and isinstance(node.args[0], ast.Name):
                    target = self.funcs.get(node.args[0].id)
                self.jit_calls.append((node, target))

        self.traced_roots = self._traced_roots()
        self.traced = self._traced_closure(self.traced_roots)

    # -- assignment bookkeeping -------------------------------------------

    def _record_assign(self, node: ast.Assign) -> None:
        names = [t.id for t in node.targets if isinstance(t, ast.Name)]
        if not names:
            return
        referenced = self._callable_refs(node.value)
        for n in names:
            if referenced:
                self.aliases.setdefault(n, set()).update(referenced)
            if (node._scope == "<module>"  # type: ignore[attr-defined]
                    and self._uses_numpy(node.value)):
                self.np_globals[n] = node.lineno

    def _callable_refs(self, value: ast.AST) -> Set[str]:
        """Function names a value expression could evaluate to (plain name
        or conditional expression over names)."""
        if isinstance(value, ast.Name):
            return {value.id}
        if isinstance(value, ast.IfExp):
            return self._callable_refs(value.body) | \
                self._callable_refs(value.orelse)
        return set()

    def _uses_numpy(self, value: ast.AST) -> bool:
        for sub in ast.walk(value):
            if isinstance(sub, ast.Name) and sub.id in self.np_aliases:
                return True
        return False

    # -- the traced set ----------------------------------------------------

    def _traced_roots(self) -> List[ast.AST]:
        roots: List[ast.AST] = []
        for fn in sorted(set(self.funcs.values()), key=lambda f: f.lineno):
            for dec in getattr(fn, "decorator_list", []):
                if _is_jit_ref(dec):
                    roots.append(fn)
                elif (isinstance(dec, ast.Call)
                      and (_is_jit_ref(dec.func)
                           or (_is_partial_ref(dec.func) and dec.args
                               and _is_jit_ref(dec.args[0])))):
                    roots.append(fn)
        for _, target in self.jit_calls:
            if target is not None:
                roots.append(target)
        return roots

    def _traced_closure(self, roots: Sequence[ast.AST]) -> Set[ast.AST]:
        traced: Set[ast.AST] = set()
        work = list(roots)
        while work:
            fn = work.pop()
            if fn in traced:
                continue
            traced.add(fn)
            for sub in ast.walk(fn):
                if not (isinstance(sub, ast.Name)
                        and isinstance(sub.ctx, ast.Load)):
                    continue
                for name in (sub.id,
                             *sorted(self.aliases.get(sub.id, ()))):
                    ref = self.funcs.get(name)
                    if ref is not None and ref not in traced:
                        work.append(ref)
        return traced

    def traced_walk(self):
        """Yield every node inside traced code, visiting each subtree once
        (skipping traced functions nested inside other traced functions)."""
        tops = [fn for fn in sorted(self.traced, key=lambda f: f.lineno)
                if not any(p in self.traced for p in _ancestors(fn))]
        for fn in tops:
            yield from ast.walk(fn)

    def finding(self, rule: str, node: ast.AST, severity: str,
                message: str, hint: str) -> Finding:
        return Finding(
            rule=rule, path=self.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            severity=severity, message=message, hint=hint,
            scope=getattr(node, "_scope", "<module>"))


def _ancestors(node: ast.AST):
    cur = getattr(node, "_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_parent", None)


def _call_name(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


# ---------------------------------------------------------------------------
# pass 2: the rules
# ---------------------------------------------------------------------------


def _rule_host_sync_in_jit(mod: ModuleInfo) -> List[Finding]:
    out = []
    for node in mod.traced_walk():
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if (isinstance(f, ast.Name) and f.id in _HOST_SYNC_BUILTINS
                and node.args
                and not all(isinstance(a, ast.Constant) for a in node.args)):
            out.append(mod.finding(
                "host-sync-in-jit", node, "error",
                f"`{f.id}(...)` on a non-constant inside jit-traced code "
                f"forces a trace-time concretization (host sync on traced "
                f"values)",
                f"convert before the jit boundary, or use "
                f"jnp.float32/jnp.asarray inside the trace"))
        elif isinstance(f, ast.Attribute) and f.attr in _HOST_SYNC_METHODS:
            out.append(mod.finding(
                "host-sync-in-jit", node, "error",
                f"`.{f.attr}()` inside jit-traced code is a device→host "
                f"sync",
                "return the array and read it at a sanctioned sync point "
                "(pane flush / host_sync)"))
        elif (isinstance(f, ast.Attribute)
              and isinstance(f.value, ast.Name)
              and f.value.id in mod.np_aliases
              and f.attr in _NP_HOST_FUNCS):
            out.append(mod.finding(
                "host-sync-in-jit", node, "error",
                f"`{f.value.id}.{f.attr}(...)` inside jit-traced code pulls "
                f"the operand to the host",
                "use jnp.asarray / keep the value device-resident"))
    return out


def _rule_np_jnp_mixing(mod: ModuleInfo) -> List[Finding]:
    out = []
    seen_globals: Set[Tuple[str, str]] = set()
    for node in mod.traced_walk():
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in mod.np_aliases
                and node.func.attr not in _NP_HOST_FUNCS):
            out.append(mod.finding(
                "np-jnp-mixing", node, "error",
                f"`{node.func.value.id}.{node.func.attr}(...)` inside "
                f"jit-traced code mixes host numpy into a device program",
                "use the jnp equivalent so the op stays on device"))
        elif (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
              and node.id in mod.np_globals):
            key = (node._scope, node.id)  # type: ignore[attr-defined]
            if key not in seen_globals:
                seen_globals.add(key)
                out.append(mod.finding(
                    "np-jnp-mixing", node, "error",
                    f"module-level numpy value `{node.id}` (defined at line "
                    f"{mod.np_globals[node.id]}) is referenced inside "
                    f"jit-traced code",
                    f"define `{node.id}` with jnp (device dtype) so traced "
                    f"code never closes over host arrays"))
    return out


def _static_argnames(call: ast.Call) -> List[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            ok, val = _literal(kw.value)
            if ok:
                if isinstance(val, str):
                    return [val]
                return [v for v in val if isinstance(v, str)]
    return []


def _jit_decorator_calls(mod: ModuleInfo):
    """(call-like node carrying jit kwargs, target def) for decorators."""
    for fn in sorted(set(mod.funcs.values()), key=lambda f: f.lineno):
        for dec in getattr(fn, "decorator_list", []):
            if not isinstance(dec, ast.Call):
                continue
            if _is_jit_ref(dec.func):
                yield dec, fn
            elif (_is_partial_ref(dec.func) and dec.args
                  and _is_jit_ref(dec.args[0])):
                yield dec, fn


def _rule_retrace_hazard(mod: ModuleInfo) -> List[Finding]:
    out = []
    sites = list(_jit_decorator_calls(mod)) + mod.jit_calls
    for call, target in sites:
        statics = _static_argnames(call)
        if not statics or target is None:
            continue
        params = {a.arg: a for a in
                  list(target.args.posonlyargs) + list(target.args.args)
                  + list(target.args.kwonlyargs)}
        defaults = _param_defaults(target)
        for name in statics:
            arg = params.get(name)
            if arg is None:
                continue
            ann = getattr(arg, "annotation", None)
            ann_name = ann.id if isinstance(ann, ast.Name) else None
            if ann_name == "float":
                out.append(mod.finding(
                    "retrace-hazard", call, "warn",
                    f"static_argnames includes float-valued `{name}` "
                    f"(annotated float) on `{target.name}` — every distinct "
                    f"value is a fresh trace",
                    f"pass `{name}` as a traced jnp scalar, or document the "
                    f"bounded value set feeding it"))
            elif ann_name in ("list", "dict", "set") or isinstance(
                    defaults.get(name), (ast.List, ast.Dict, ast.Set)):
                out.append(mod.finding(
                    "retrace-hazard", call, "error",
                    f"static_argnames includes unhashable `{name}` on "
                    f"`{target.name}` — jit statics must be hashable",
                    f"use a tuple / frozen value for `{name}`"))
    # jax.jit(f)(x) immediately invoked inside a function body: the jitted
    # callable (and its trace cache) is rebuilt on every call of the
    # enclosing function — the classic retrace storm.  A jit assigned to a
    # name and reused, or one cached by signature, is fine.
    for call, _ in mod.jit_calls:
        scope = getattr(call, "_scope", "<module>")
        if scope == "<module>":
            continue
        parent = getattr(call, "_parent", None)
        if isinstance(parent, ast.Call) and parent.func is call:
            out.append(mod.finding(
                "retrace-hazard", call, "warn",
                f"jax.jit(...)(...) immediately invoked inside `{scope}` "
                f"rebuilds the compiled callable — and retraces — on "
                f"every call",
                "hoist the jitted fn to module level, or cache it keyed "
                "by the static signature (see feed_fused._SEG_CACHE)"))
    return out


def _param_defaults(fn: ast.AST) -> Dict[str, ast.AST]:
    args = fn.args
    pos = list(args.posonlyargs) + list(args.args)
    out: Dict[str, ast.AST] = {}
    for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        out[a.arg] = d
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            out[a.arg] = d
    return out


def _rule_frozen_mutation(mod: ModuleInfo) -> List[Finding]:
    out = []
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "__setattr__"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "object"):
            scope = getattr(node, "_scope", "<module>")
            in_post_init = scope.split(".")[-1] == "__post_init__"
            out.append(mod.finding(
                "frozen-mutation", node,
                "note" if in_post_init else "error",
                "object.__setattr__ bypasses the frozen-dataclass contract"
                + (" (inside __post_init__: the sanctioned freeze "
                   "escape hatch)" if in_post_init else ""),
                "keep frozen instances immutable; use dataclasses.replace "
                "for derived values"
                if not in_post_init else
                "acceptable only for canonicalization during construction"))
            continue
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            attr = None
            if isinstance(t, ast.Attribute):
                attr = t
                kind = "rebinds"
            elif (isinstance(t, ast.Subscript)
                  and isinstance(t.value, ast.Attribute)):
                attr = t.value
                kind = "writes into"
            else:
                continue
            obj = attr.value
            if (attr.attr in _RECORDBATCH_COLS
                    and isinstance(obj, ast.Name) and obj.id != "self"):
                out.append(mod.finding(
                    "frozen-mutation", node, "error",
                    f"{kind} `{obj.id}.{attr.attr}` — RecordBatch columns "
                    f"are frozen (copy-on-write, writeable=False)",
                    "build a new RecordBatch (dataclasses.replace / "
                    "with_columns) instead of mutating columns"))
    return out


def _rule_deprecated_shim(mod: ModuleInfo) -> List[Finding]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in _SHIMS and name not in mod.funcs:
            out.append(mod.finding(
                "deprecated-shim", node, "error",
                f"call to deprecated shim `{name}` (a runtime "
                f"DeprecationWarning, promoted to error by pyproject "
                f"filterwarnings)",
                f"use {_SHIMS[name]}"))
    return out


class _SetTracker(ast.NodeVisitor):
    """Track local names bound to set-valued expressions, per scope."""

    def __init__(self, mod: ModuleInfo) -> None:
        self.mod = mod
        self.findings: List[Finding] = []
        self.set_vars: Set[Tuple[str, str]] = set()  # (scope, name)

    def _is_set_expr(self, node: ast.AST, scope: str) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id in (
                    "set", "frozenset"):
                return True
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SET_METHODS
                    and self._is_set_expr(node.func.value, scope)):
                return True
            return False
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Sub, ast.BitOr, ast.BitAnd, ast.BitXor)):
            return (self._is_set_expr(node.left, scope)
                    or self._is_set_expr(node.right, scope))
        if isinstance(node, ast.Name):
            return (scope, node.id) in self.set_vars
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        scope = getattr(node, "_scope", "<module>")
        is_set = self._is_set_expr(node.value, scope)
        for t in node.targets:
            if isinstance(t, ast.Name):
                key = (scope, t.id)
                if is_set:
                    self.set_vars.add(key)
                else:
                    self.set_vars.discard(key)
        self.generic_visit(node)

    def _flag(self, iter_node: ast.AST, where: str) -> None:
        self.findings.append(self.mod.finding(
            "unordered-iteration", iter_node, "warn",
            f"{where} iterates a set — hash-seed order leaks into whatever "
            f"this loop builds or mutates (routing, scatter, ring ops)",
            "iterate sorted(...) (or an insertion-ordered dict) when "
            "downstream effects are order-sensitive"))

    def visit_For(self, node: ast.For) -> None:
        scope = getattr(node, "_scope", "<module>")
        if self._is_set_expr(node.iter, scope):
            self._flag(node.iter, "for-loop")
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        scope = getattr(node, "_scope", "<module>")
        order_sensitive = not isinstance(node, (ast.SetComp, ast.DictComp))
        parent = getattr(node, "_parent", None)
        if (isinstance(parent, ast.Call)
                and isinstance(parent.func, ast.Name)
                and parent.func.id in _ORDER_NEUTRAL_SINKS):
            order_sensitive = False
        if order_sensitive:
            for gen in node.generators:
                if self._is_set_expr(gen.iter, scope):
                    self._flag(gen.iter, "comprehension")
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_GeneratorExp = _visit_comp


def _rule_unordered_iteration(mod: ModuleInfo) -> List[Finding]:
    tracker = _SetTracker(mod)
    tracker.visit(mod.tree)
    return tracker.findings


def _rule_exactness_contract(mod: ModuleInfo) -> List[Finding]:
    if mod.rel.replace("\\", "/").endswith("repro/analysis/contracts.py"):
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        for t in node.targets:
            if (isinstance(t, ast.Name) and t.id in _CONTRACT_NAMES
                    and isinstance(node.value,
                                   (ast.Tuple, ast.List, ast.Dict))):
                out.append(mod.finding(
                    "exactness-contract", node, "error",
                    f"local redefinition of `{t.id}` shadows the exactness "
                    f"contract — a test asserting the wrong contract "
                    f"becomes a flake instead of a lint finding",
                    f"from repro.analysis.contracts import {t.id}"))
    return out


def _kwarg_map(call: ast.Call) -> Optional[Dict[str, object]]:
    """Literal kwargs of a call, or None when any is non-literal/starred."""
    out: Dict[str, object] = {}
    for kw in call.keywords:
        if kw.arg is None:
            return None
        ok, val = _literal(kw.value)
        if not ok:
            return None
        out[kw.arg] = val
    return out


def _pos_literal(call: ast.Call, i: int):
    if i < len(call.args) and not isinstance(call.args[i], ast.Starred):
        return _literal(call.args[i])
    return False, None


def _rule_topology_config(mod: ModuleInfo) -> List[Finding]:
    from . import contracts

    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name == "config_for" and name not in mod.funcs:
            ok, scheme = _pos_literal(node, 0)
            if not ok or not isinstance(scheme, str):
                continue
            if scheme not in contracts.SCHEMES:
                out.append(mod.finding(
                    "topology-config", node, "error",
                    f"unknown scheme {scheme!r} — config_for raises at "
                    f"runtime",
                    f"one of {', '.join(contracts.SCHEMES)}"))
                continue
            kwargs = _kwarg_map(node)
            if kwargs is not None and len(node.args) == 1:
                err = contracts.validate_config_literal(scheme, kwargs)
                if err:
                    out.append(mod.finding(
                        "topology-config", node, "error",
                        f"config_for({scheme!r}, ...) rejects these "
                        f"arguments at build time: {err}",
                        "fix the literal config (the typed SchemeConfig "
                        "validates eagerly)"))
        elif name == "Stage" and name not in mod.funcs:
            okn, sname = _pos_literal(node, 0)
            okp, par = _pos_literal(node, 1)
            kwargs = _kwarg_map(node) or {}
            if not okn and "name" in kwargs:
                okn, sname = True, kwargs["name"]
            if not okp and "parallelism" in kwargs:
                okp, par = True, kwargs["parallelism"]
            if okn or okp:
                err = contracts.validate_stage_literal(
                    sname if okn else "?", par if okp else 1,
                    cost=kwargs.get("cost"),
                    capacities=kwargs.get("capacities"))
                if err:
                    out.append(mod.finding(
                        "topology-config", node, "error",
                        f"Stage(...) rejects this at build time: {err}",
                        "fix the stage literal"))
        elif name == "Edge" and name not in mod.funcs:
            oks, src = _pos_literal(node, 0)
            okd, dst = _pos_literal(node, 1)
            grouping_is_config: Optional[bool] = None
            g = node.args[2] if len(node.args) > 2 else next(
                (kw.value for kw in node.keywords if kw.arg == "grouping"),
                None)
            if g is not None and _literal(g)[0]:
                grouping_is_config = False  # a bare literal is never a config
            if oks and okd:
                err = contracts.validate_edge_literal(
                    src, dst, grouping_is_config)
                if err:
                    out.append(mod.finding(
                        "topology-config", node, "error",
                        f"Edge(...) rejects this at build time: {err}",
                        "fix the edge literal"))
        elif name == "Topology" and name not in mod.funcs:
            extracted = _extract_topology(node)
            if extracted is not None:
                stage_names, edge_pairs = extracted
                for err in contracts.validate_topology_literal(
                        stage_names, edge_pairs):
                    out.append(mod.finding(
                        "topology-config", node, "error",
                        f"Topology(...) rejects this at build time: {err}",
                        "fix the stage/edge wiring"))
    return out


def _extract_topology(call: ast.Call
                      ) -> Optional[Tuple[List[str], List[Tuple[str, str]]]]:
    """Stage names + (src, dst) pairs from a fully literal Topology call."""
    stages_node = call.args[0] if len(call.args) > 0 else next(
        (kw.value for kw in call.keywords if kw.arg == "stages"), None)
    edges_node = call.args[1] if len(call.args) > 1 else next(
        (kw.value for kw in call.keywords if kw.arg == "edges"), None)
    if not isinstance(stages_node, (ast.List, ast.Tuple)) or \
            not isinstance(edges_node, (ast.List, ast.Tuple)):
        return None
    names: List[str] = []
    for el in stages_node.elts:
        if not (isinstance(el, ast.Call) and _call_name(el) == "Stage"):
            return None
        ok, v = _pos_literal(el, 0)
        if not ok and el.keywords:
            kw = next((k.value for k in el.keywords if k.arg == "name"),
                      None)
            if kw is not None:
                ok, v = _literal(kw)
        if not ok or not isinstance(v, str):
            return None
        names.append(v)
    pairs: List[Tuple[str, str]] = []
    for el in edges_node.elts:
        if not (isinstance(el, ast.Call) and _call_name(el) == "Edge"):
            return None
        oks, s = _pos_literal(el, 0)
        okd, d = _pos_literal(el, 1)
        if not (oks and okd and isinstance(s, str) and isinstance(d, str)):
            return None
        pairs.append((s, d))
    return names, pairs


# ISSUE 9: counters whose single source of truth is a MetricsRegistry cell.
# The legacy attribute names survive as properties (read) / setters (external
# write-compat); *internal* mutation must go through the cell, or enabled and
# disabled runs drift apart.
_REGISTRY_BACKED = {"TRACE_COUNT", "shed", "queue_depth_peak",
                    "in_flight_peak", "dispatches"}
_REGISTRY_CLASS_MARKERS = ("Engine", "Runner")


def _rule_registry_counter_mutation(mod: ModuleInfo) -> List[Finding]:
    # names this module imported — a store through one of them reaches into
    # another module's registry-backed counter from the outside
    imported: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imported.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name != "*":
                    imported.add(a.asname or a.name)
    out = []
    for node in ast.walk(mod.tree):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            if not (isinstance(t, ast.Attribute)
                    and t.attr in _REGISTRY_BACKED
                    and isinstance(t.value, ast.Name)):
                continue
            base = t.value.id
            scope = getattr(node, "_scope", "<module>")
            if base == "self":
                # only Engine/Runner classes hold registry-backed cells;
                # `self.dispatches` on a plain report row is a data field
                if not any(m in part for part in scope.split(".")
                           for m in _REGISTRY_CLASS_MARKERS):
                    continue
                out.append(mod.finding(
                    "registry-counter-mutation", node, "error",
                    f"direct store to registry-backed `self.{t.attr}` in "
                    f"`{scope}` bypasses the MetricsRegistry cell — enabled "
                    f"and disabled telemetry runs would disagree",
                    f"mutate through the cell (`self._m_*.add/.set/.peak`); "
                    f"the `{t.attr}` attribute is a read property"))
            elif base in imported and t.attr in ("TRACE_COUNT", "dispatches"):
                out.append(mod.finding(
                    "registry-counter-mutation", node, "error",
                    f"store to `{base}.{t.attr}` mutates another module's "
                    f"registry-backed counter from the outside",
                    "use the owning registry's cell (or the sanctioned "
                    "reset helper) instead of assigning the attribute"))
    return out


# ISSUE 10 rules live in sibling modules that import helpers from this one;
# late-import wrappers keep the registration cycle-free in both import orders.


def _rule_int32_overflow(mod: ModuleInfo) -> List[Finding]:
    from .numerics import rule_int32_overflow
    return rule_int32_overflow(mod)


def _rule_unseeded_rng(mod: ModuleInfo) -> List[Finding]:
    from .determinism import rule_unseeded_rng
    return rule_unseeded_rng(mod)


def _rule_wall_clock_leak(mod: ModuleInfo) -> List[Finding]:
    from .determinism import rule_wall_clock_leak
    return rule_wall_clock_leak(mod)


def _rule_unbounded_signature(mod: ModuleInfo) -> List[Finding]:
    from .determinism import rule_unbounded_signature
    return rule_unbounded_signature(mod)


def _rule_interproc_unordered(mod: ModuleInfo) -> List[Finding]:
    from .callgraph import single_module_interproc
    return single_module_interproc(mod)


_RULE_FNS = {
    "host-sync-in-jit": _rule_host_sync_in_jit,
    "retrace-hazard": _rule_retrace_hazard,
    "np-jnp-mixing": _rule_np_jnp_mixing,
    "frozen-mutation": _rule_frozen_mutation,
    "deprecated-shim": _rule_deprecated_shim,
    "unordered-iteration": _rule_unordered_iteration,
    "exactness-contract": _rule_exactness_contract,
    "topology-config": _rule_topology_config,
    "registry-counter-mutation": _rule_registry_counter_mutation,
    "int32-overflow": _rule_int32_overflow,
    "unseeded-rng": _rule_unseeded_rng,
    "wall-clock-leak": _rule_wall_clock_leak,
    "unbounded-signature": _rule_unbounded_signature,
    "interproc-unordered-iteration": _rule_interproc_unordered,
}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def lint_file(path: Path, root: Path,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    src = Path(path).read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Finding(
            rule="syntax", path=_rel(path, root), line=e.lineno or 1,
            col=e.offset or 0, severity="error",
            message=f"cannot parse: {e.msg}", hint="fix the syntax error")]
    mod = ModuleInfo(Path(path), _rel(path, root), tree)
    out: List[Finding] = []
    for rule in rules or RULES:
        out.extend(_RULE_FNS[rule](mod))
    return out


def _rel(path: Path, root: Path) -> str:
    try:
        return Path(path).resolve().relative_to(
            Path(root).resolve()).as_posix()
    except ValueError:
        return Path(path).as_posix()


_DEFAULT_EXCLUDES = ("analysis_fixtures",)


def iter_python_files(paths: Sequence[Path],
                      excludes: Sequence[str] = _DEFAULT_EXCLUDES
                      ) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
    return [f for f in files
            if not any(part in excludes for part in f.parts)]


def lint_paths(paths: Sequence[Path], root: Path,
               rules: Optional[Sequence[str]] = None,
               excludes: Sequence[str] = _DEFAULT_EXCLUDES
               ) -> List[Finding]:
    out: List[Finding] = []
    for f in iter_python_files(paths, excludes):
        out.extend(lint_file(f, root, rules))
    return out
