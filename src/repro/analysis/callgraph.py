"""Whole-program call graph over ``src/repro/`` (ISSUE 10 tentpole, part 1).

PR 7's lint is intra-module: the jit traced-set closure
(:meth:`ModuleInfo._traced_closure`) follows same-module references only,
so a ``@jax.jit`` root in ``kernels/feed_fused.py`` calling a helper that
lives in ``kernels/ops.py`` leaves the helper invisible to
``host-sync-in-jit`` / ``np-jnp-mixing`` — exactly where a stray
``np.asarray`` would silently serialize a fused launch.

This module builds a :class:`Program` over many :class:`ModuleInfo`\\ s and
closes the gap in three steps:

1. **Import resolution.**  Each module gets an import table: module
   aliases (``import numpy.random as npr``, ``from .. import kernels``,
   plain ``import repro.kernels.ops``) and from-imported names
   (``from ..kernels.ops import segment_feed``), with relative levels
   resolved against the module's own dotted path.  Names that resolve to
   files in the program become cross-module edges; everything else
   (stdlib, third-party) resolves to nothing — fail-safe, no guessed
   edges.  Bare imports in single-directory trees (the test fixtures)
   fall back to a unique-stem match.

2. **Cross-module traced closure.**  Starting from every module's jit
   roots, referenced names are resolved through the import tables to
   top-level functions of other modules; each target is expanded through
   its *own* module's intra-module closure, to a fixpoint.  Modules whose
   traced set grew are re-linted under the enlarged set for the traced
   rules (``host-sync-in-jit``, ``np-jnp-mixing``), deduplicated against
   the intra-module pass — PR 7's rules, retrofitted interprocedurally
   with zero changes to the rules themselves.

3. **Interprocedural unordered-iteration.**  PR 7's rule sees ``for x in
   build() - set(done)`` but not ``for x in candidate_workers()`` where
   the callee returns a set.  Here set-*returning* functions are computed
   per module (direct set-valued returns, then a fixpoint over functions
   returning other set-returning calls), and every ``for``/comprehension
   iterating such a call — same-module or imported — is flagged, with the
   same order-neutral-sink exemptions as the local rule.

:func:`lint_program` is the whole-program entry point the CLI and the
repo-gate test use; :func:`single_module_interproc` backs the
``interproc-unordered-iteration`` entry in :data:`repro.analysis.lint.RULES`
so per-file scans still see same-module violations.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from . import lint as _lint
from .lint import (ModuleInfo, _DEFAULT_EXCLUDES, _ORDER_NEUTRAL_SINKS,
                   _SetTracker, _rel, iter_python_files)

__all__ = ["Program", "build_program", "lint_program",
           "single_module_interproc"]

#: The traced rules re-run under the cross-module-enlarged traced set.
_RETROFIT_RULES = ("host-sync-in-jit", "np-jnp-mixing")


def _module_name(rel: str) -> str:
    """Dotted module path from a repo-relative file path.

    ``src/repro/core/stream.py`` → ``repro.core.stream`` (the ``src``
    layout root is stripped); ``src/repro/obs/__init__.py`` →
    ``repro.obs``; files outside a package tree keep their directory
    path (``tests/test_x.py`` → ``tests.test_x``)."""
    parts = rel.replace("\\", "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if not parts:
        return ""
    parts[-1] = parts[-1][:-3] if parts[-1].endswith(".py") else parts[-1]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(p for p in parts if p)


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return parts[::-1]
    return None


def _is_top_level(fn: ast.AST) -> bool:
    return getattr(fn, "_scope", "") == getattr(fn, "name", None)


def _scope_of(node: ast.AST) -> str:
    return getattr(node, "_scope", "<module>")


class _ImportTable:
    """One module's resolved imports: local name → dotted module, and
    local name → (defining module, function name)."""

    def __init__(self, mod: ModuleInfo, program: "Program") -> None:
        self.mod_aliases: Dict[str, str] = {}
        self.from_funcs: Dict[str, Tuple[str, str]] = {}
        dotted = _module_name(mod.rel)
        is_pkg = mod.rel.replace("\\", "/").endswith("__init__.py")
        pkg_parts = dotted.split(".") if is_pkg else dotted.split(".")[:-1]
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.mod_aliases[a.asname] = a.name
                    else:
                        head = a.name.split(".")[0]
                        self.mod_aliases.setdefault(head, head)
            elif isinstance(node, ast.ImportFrom):
                if node.level == 0:
                    base = node.module or ""
                else:
                    keep = len(pkg_parts) - (node.level - 1)
                    if keep < 0:
                        continue
                    base = ".".join(pkg_parts[:keep]
                                    + ([node.module] if node.module else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    local = a.asname or a.name
                    full = f"{base}.{a.name}" if base else a.name
                    if program.resolve_module(full) is not None:
                        self.mod_aliases[local] = full
                    elif base:
                        self.from_funcs[local] = (base, a.name)


class Program:
    def __init__(self, modules: Sequence[ModuleInfo]) -> None:
        self.modules = list(modules)
        self.by_rel: Dict[str, ModuleInfo] = {m.rel: m for m in modules}
        self.by_name: Dict[str, ModuleInfo] = {}
        for m in self.modules:
            name = _module_name(m.rel)
            if name:
                self.by_name[name] = m
        stems: Dict[str, List[str]] = {}
        for name in self.by_name:
            stems.setdefault(name.split(".")[-1], []).append(name)
        self._stem_unique = {s: ns[0] for s, ns in stems.items()
                             if len(ns) == 1}
        self.imports: Dict[str, _ImportTable] = {
            m.rel: _ImportTable(m, self) for m in self.modules}

    # -- resolution ------------------------------------------------------

    def resolve_module(self, name: str) -> Optional[ModuleInfo]:
        m = self.by_name.get(name)
        if m is not None:
            return m
        if "." not in name:
            # bare import in a flat tree (fixtures): unique-stem fallback
            full = self._stem_unique.get(name)
            if full is not None:
                return self.by_name[full]
        return None

    def _func_targets(self, mod: ModuleInfo, node: ast.AST
                      ) -> Iterable[Tuple[ModuleInfo, ast.AST]]:
        """Top-level functions of *other* program modules that a Name /
        Attribute load in ``mod`` can refer to."""
        table = self.imports[mod.rel]
        if isinstance(node, ast.Name):
            for n in (node.id, *sorted(mod.aliases.get(node.id, ()))):
                tgt = table.from_funcs.get(n)
                if tgt is None:
                    continue
                m2 = self.resolve_module(tgt[0])
                if m2 is None or m2 is mod:
                    continue
                fn = m2.funcs.get(tgt[1])
                if fn is not None and _is_top_level(fn):
                    yield m2, fn
        elif isinstance(node, ast.Attribute):
            parts = _attr_chain(node)
            if not parts or len(parts) < 2:
                return
            head = table.mod_aliases.get(parts[0])
            expanded = (head.split(".") + parts[1:]) if head else parts
            for i in range(len(expanded) - 1, 0, -1):
                m2 = self.resolve_module(".".join(expanded[:i]))
                if m2 is None:
                    continue
                if m2 is not mod and len(expanded) - i == 1:
                    fn = m2.funcs.get(expanded[i])
                    if fn is not None and _is_top_level(fn):
                        yield m2, fn
                return  # longest matching prefix decides

    def _call_targets(self, mod: ModuleInfo, call: ast.Call
                      ) -> Iterable[Tuple[ModuleInfo, ast.AST]]:
        """Like :meth:`_func_targets`, but also same-module targets."""
        f = call.func
        if isinstance(f, ast.Name):
            for n in (f.id, *sorted(mod.aliases.get(f.id, ()))):
                fn = mod.funcs.get(n)
                if fn is not None and _is_top_level(fn):
                    yield mod, fn
        yield from self._func_targets(mod, f)

    # -- cross-module traced closure ------------------------------------

    def traced_expansion(self) -> Dict[str, Set[ast.AST]]:
        """Per-module functions that become traced only once jit roots are
        chased across imports (beyond each module's intra-module set)."""
        extra: Dict[str, Set[ast.AST]] = {m.rel: set() for m in self.modules}
        work: List[Tuple[ModuleInfo, ast.AST]] = [
            (m, fn) for m in self.modules
            for fn in sorted(m.traced, key=lambda f: f.lineno)]
        seen: Set[Tuple[str, int]] = {(m.rel, id(fn)) for m, fn in work}
        while work:
            m, fn = work.pop()
            for sub in ast.walk(fn):
                if not (isinstance(sub, (ast.Name, ast.Attribute))
                        and isinstance(sub.ctx, ast.Load)):
                    continue
                for m2, f2 in self._func_targets(m, sub):
                    # the target drags in its own module's intra closure
                    for f3 in m2._traced_closure([f2]):
                        key = (m2.rel, id(f3))
                        if key in seen:
                            continue
                        seen.add(key)
                        if f3 not in m2.traced:
                            extra[m2.rel].add(f3)
                        work.append((m2, f3))
        return extra

    # -- the whole-program lint -----------------------------------------

    def lint(self, rules: Optional[Sequence[str]] = None) -> List[Finding]:
        rules = tuple(rules or _lint.RULES)
        findings: List[Finding] = []
        for m in self.modules:
            for rule in rules:
                if rule == "interproc-unordered-iteration":
                    continue  # program-level, run once below
                findings.extend(_lint._RULE_FNS[rule](m))
        extra = self.traced_expansion()
        emitted = {(f.rule, f.path, f.line, f.col) for f in findings}
        for m in self.modules:
            grown = extra.get(m.rel)
            if not grown:
                continue
            saved = m.traced
            m.traced = saved | grown
            try:
                for rule in _RETROFIT_RULES:
                    if rule not in rules:
                        continue
                    for f in _lint._RULE_FNS[rule](m):
                        key = (f.rule, f.path, f.line, f.col)
                        if key not in emitted:
                            emitted.add(key)
                            findings.append(f)
            finally:
                m.traced = saved
        if "interproc-unordered-iteration" in rules:
            findings.extend(interproc_unordered(self))
        return findings


# ---------------------------------------------------------------------------
# interprocedural unordered-iteration
# ---------------------------------------------------------------------------


def _set_returning(program: Program) -> Dict[str, Set[str]]:
    """rel path → names of top-level functions that return sets — directly,
    or (to a fixpoint) by returning a call to another set-returning fn."""
    trackers: Dict[str, _SetTracker] = {}
    for m in program.modules:
        t = _SetTracker(m)
        t.visit(m.tree)
        trackers[m.rel] = t
    result: Dict[str, Set[str]] = {m.rel: set() for m in program.modules}
    for m in program.modules:
        t = trackers[m.rel]
        for fn in sorted(set(m.funcs.values()), key=lambda f: f.lineno):
            if not _is_top_level(fn):
                continue
            for node in ast.walk(fn):
                if (isinstance(node, ast.Return) and node.value is not None
                        and t._is_set_expr(node.value, _scope_of(node))):
                    result[m.rel].add(fn.name)
                    break
    changed = True
    while changed:
        changed = False
        for m in program.modules:
            for fn in sorted(set(m.funcs.values()), key=lambda f: f.lineno):
                if not _is_top_level(fn) or fn.name in result[m.rel]:
                    continue
                for node in ast.walk(fn):
                    if not (isinstance(node, ast.Return)
                            and isinstance(node.value, ast.Call)):
                        continue
                    for m2, f2 in program._call_targets(m, node.value):
                        if f2.name in result[m2.rel]:
                            result[m.rel].add(fn.name)
                            changed = True
                            break
                    if fn.name in result[m.rel]:
                        break
    return result


def _setcall_target(program: Program, mod: ModuleInfo, node: ast.AST,
                    returning: Dict[str, Set[str]]
                    ) -> Optional[Tuple[ModuleInfo, str]]:
    if not isinstance(node, ast.Call):
        return None
    for m2, fn in program._call_targets(mod, node):
        if fn.name in returning[m2.rel]:
            return m2, fn.name
    return None


def interproc_unordered(program: Program) -> List[Finding]:
    returning = _set_returning(program)
    out: List[Finding] = []

    def flag(mod: ModuleInfo, iter_node: ast.AST, where: str,
             m2: ModuleInfo, fname: str) -> None:
        origin = ("this module" if m2 is mod
                  else _module_name(m2.rel) or m2.rel)
        out.append(mod.finding(
            "interproc-unordered-iteration", iter_node, "warn",
            f"{where} iterates `{fname}()` which returns a set (defined in "
            f"{origin}) — hash-seed order leaks into whatever this loop "
            f"builds or mutates",
            f"sort at the boundary (`sorted({fname}(...))`), or return an "
            f"ordered container from `{fname}`"))

    for mod in program.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.For):
                hit = _setcall_target(program, mod, node.iter, returning)
                if hit is not None:
                    flag(mod, node.iter, "for-loop", *hit)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.SetComp, ast.DictComp)):
                order_sensitive = not isinstance(
                    node, (ast.SetComp, ast.DictComp))
                parent = getattr(node, "_parent", None)
                if (isinstance(parent, ast.Call)
                        and isinstance(parent.func, ast.Name)
                        and parent.func.id in _ORDER_NEUTRAL_SINKS):
                    order_sensitive = False
                if not order_sensitive:
                    continue
                for gen in node.generators:
                    hit = _setcall_target(program, mod, gen.iter, returning)
                    if hit is not None:
                        flag(mod, gen.iter, "comprehension", *hit)
    return out


def single_module_interproc(mod: ModuleInfo) -> List[Finding]:
    """Same-module slice of the interprocedural rule, for per-file scans
    (``lint_file``): iteration over calls to set-returning functions
    defined in the same file."""
    return interproc_unordered(Program([mod]))


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def build_program(paths: Sequence[Path], root: Path,
                  excludes: Sequence[str] = _DEFAULT_EXCLUDES
                  ) -> Tuple[Program, List[Finding]]:
    """Parse every file under ``paths`` into one :class:`Program`.
    Unparseable files become syntax findings instead of modules."""
    modules: List[ModuleInfo] = []
    syntax: List[Finding] = []
    for f in iter_python_files(paths, excludes):
        src = Path(f).read_text()
        try:
            tree = ast.parse(src, filename=str(f))
        except SyntaxError as e:
            syntax.append(Finding(
                rule="syntax", path=_rel(f, root), line=e.lineno or 1,
                col=e.offset or 0, severity="error",
                message=f"cannot parse: {e.msg}",
                hint="fix the syntax error"))
            continue
        modules.append(ModuleInfo(Path(f), _rel(f, root), tree))
    return Program(modules), syntax


def lint_program(paths: Sequence[Path], root: Path,
                 rules: Optional[Sequence[str]] = None,
                 excludes: Sequence[str] = _DEFAULT_EXCLUDES
                 ) -> List[Finding]:
    """Whole-program scan: every intra-module rule, plus the cross-module
    traced-set retrofit and the interprocedural rules.  The superset of
    :func:`repro.analysis.lint.lint_paths` the CLI and CI run."""
    program, syntax = build_program(paths, root, excludes)
    return syntax + program.lint(rules)
