"""Differential sanitizer: the dynamic twin of the determinism rules.

The static rules (:mod:`.determinism`, :mod:`.numerics`) prove what an AST
can prove; everything they cannot see — dtypes entering through opaque
calls, device kernels accumulating traced values, iteration order inside
compiled code — is caught here instead, by *running the claim*: a
determinism bug is, operationally, two same-seed runs whose reports
differ.

Protocol (DESIGN.md §15):

1. run a session factory **twice**, same seed, each run under
   :func:`sanitized` — ``np.seterr(all="raise")`` so silent overflow /
   invalid ops become exceptions, and ``jax_debug_nans`` so device NaNs
   fault at the op that produced them;
2. diff the two :class:`~repro.topology.engine.TopologyReport`\\ s
   **field-by-field through their dict forms**, floats compared by bit
   pattern (``struct.pack``) — not ``==``, which would wave through
   same-printed-differently values and choke on NaN;
3. any divergence is a list of ``path: a != b`` strings — empty means the
   run is bit-deterministic.

The module is import-light (stdlib only at module level); numpy and jax
load lazily inside :func:`sanitized`, and only if present.
"""

from __future__ import annotations

import contextlib
import struct
from typing import Any, Callable, List, Tuple

__all__ = ["sanitized", "diff_values", "diff_reports", "double_run"]


@contextlib.contextmanager
def sanitized():
    """Strict-numerics context: numpy floating-point faults raise, and jax
    (when importable) faults on NaN production inside jitted code.  Both
    settings are restored on exit."""
    try:
        import numpy as np
    except ImportError:  # pragma: no cover - numpy is a hard dep in practice
        np = None
    saved_np = np.seterr(all="raise") if np is not None else None
    saved_jax = None
    jax = None
    try:
        import jax
    except ImportError:  # pragma: no cover - analysis must run without jax
        pass
    if jax is not None:
        saved_jax = jax.config.jax_debug_nans
        jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        if np is not None:
            np.seterr(**saved_np)
        if jax is not None:
            jax.config.update("jax_debug_nans", saved_jax)


def _normalize(v: Any) -> Any:
    """Fold numpy scalars to Python scalars so 3 == np.int64(3) compares
    by value, while arrays stay arrays (compared elementwise below)."""
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "shape", None) == ():
        return v.item()
    return v


def _float_bits(x: float) -> bytes:
    return struct.pack("<d", x)


def diff_values(a: Any, b: Any, path: str = "report") -> List[str]:
    """Recursive bit-exact diff of two report-shaped values.  Returns
    human-readable divergence strings (empty list = identical).

    dicts diff by key set then per key; lists/tuples by length then per
    index; floats by IEEE-754 bit pattern (NaN == NaN, 0.0 != -0.0);
    numpy arrays by shape, dtype, and exact element equality.
    """
    a, b = _normalize(a), _normalize(b)
    if isinstance(a, dict) and isinstance(b, dict):
        out: List[str] = []
        for k in sorted(set(a) | set(b), key=str):
            if k not in a:
                out.append(f"{path}.{k}: only in second run")
            elif k not in b:
                out.append(f"{path}.{k}: only in first run")
            else:
                out.extend(diff_values(a[k], b[k], f"{path}.{k}"))
        return out
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        if len(a) != len(b):
            return [f"{path}: length {len(a)} != {len(b)}"]
        out = []
        for i, (x, y) in enumerate(zip(a, b)):
            out.extend(diff_values(x, y, f"{path}[{i}]"))
        return out
    # numpy arrays (anything with shape + dtype): exact comparison
    if getattr(a, "shape", None) is not None \
            or getattr(b, "shape", None) is not None:
        import numpy as np
        aa, bb = np.asarray(a), np.asarray(b)
        if aa.shape != bb.shape:
            return [f"{path}: shape {aa.shape} != {bb.shape}"]
        if aa.dtype != bb.dtype:
            return [f"{path}: dtype {aa.dtype} != {bb.dtype}"]
        if not np.array_equal(aa, bb, equal_nan=True):
            n = int((aa != bb).sum())
            return [f"{path}: arrays differ at {n} element(s)"]
        return []
    if isinstance(a, float) and isinstance(b, float):
        if _float_bits(a) != _float_bits(b):
            return [f"{path}: {a!r} != {b!r} (bitwise)"]
        return []
    if type(a) is not type(b):
        return [f"{path}: type {type(a).__name__} != {type(b).__name__}"]
    if a != b:
        return [f"{path}: {a!r} != {b!r}"]
    return []


def diff_reports(r1: Any, r2: Any) -> List[str]:
    """Field-by-field bit diff of two ``TopologyReport``-likes (anything
    with ``to_dict``; plain dicts pass through)."""
    d1 = r1.to_dict() if hasattr(r1, "to_dict") else r1
    d2 = r2.to_dict() if hasattr(r2, "to_dict") else r2
    return diff_values(d1, d2)


def double_run(factory: Callable[[], Any]) -> Tuple[Any, Any, List[str]]:
    """Run ``factory`` twice under :func:`sanitized` and diff the reports.

    ``factory`` must build *everything* (engine, topology, source) fresh on
    each call — shared state between the two runs would mask exactly the
    bugs this exists to catch.  Returns ``(report1, report2, divergences)``.
    """
    with sanitized():
        r1 = factory()
    with sanitized():
        r2 = factory()
    return r1, r2, diff_reports(r1, r2)
