"""Abstract integer-width / overflow pass (ISSUE 10 tentpole, part 2).

One rule, ``int32-overflow``: an *accumulator* — a value that grows by
repeated addition — held in a narrow integer dtype (int32 or smaller)
whose magnitude scales with stream length wraps silently once the running
total passes 2³¹−1.  At the declared :data:`SCALE_TARGET` (the ROADMAP's
10⁸-tuple runs) that happens as soon as the *mean per-step increment*
reaches ``(2³¹−1) // SCALE_TARGET`` ≈ 21, so "it worked in the tests"
(3·10⁴ tuples) says nothing about target scale.

The pass is a small dtype lattice evaluated flow-insensitively over each
module's AST:

* **dtype evidence** — every assignment records the dtypes its target has
  been observed to hold.  Array constructors with a dtype token
  (``np.zeros(n, np.int32)``, ``jnp.zeros(..., jnp.int32)``,
  ``x.astype(np.int32)``, ``np.int32(v)``, ``dtype="int32"``) seed the
  lattice; ``np.bincount`` seeds int64 (numpy's intp default); arithmetic
  joins to the widest operand.  Locals key on ``(scope, name)``;
  ``self.X`` attributes key on the enclosing class, joined across all its
  methods (a table allocated int32 in one method and accumulated in
  another is exactly the hazard).
* **accumulation sites** — ``x += v``, ``x = x + v``, ``x[i] += v``,
  ``np.add.at(x, i, v)``, and the jax functional form
  ``x = x.at[i].add(v)``.
* **scale filter** — the accumulator only scales with the stream when it
  aggregates per-tuple quantities; the pass requires a scale hint
  (:data:`SCALE_HINTS` substring) on the accumulator's name or on any
  name feeding the increment, so int32 *id* arrays and bounded local
  counters stay quiet.

Findings name the accumulator and the overflow point at
:data:`SCALE_TARGET`.  What the lattice cannot see (dtypes entering
through opaque calls, device kernels accumulating traced arguments) is
documented in DESIGN.md §15 — the differential sanitizer is the dynamic
backstop for exactly that residue.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .contracts import SCALE_TARGET
from .findings import Finding

__all__ = ["SCALE_TARGET", "SCALE_HINTS", "rule_int32_overflow"]

_INT32_MAX = 2 ** 31 - 1

#: Narrow integer dtypes the rule fires on (anything that wraps below the
#: int64 stream-count envelope).
_NARROW = {"int8", "int16", "int32", "uint8", "uint16", "uint32"}
_WIDE = {"int64", "uint64", "float64"}
_DTYPE_NAMES = _NARROW | _WIDE | {"float32"}

#: Constructors whose result dtype is the explicit dtype token if one is
#: given.  Without a token, ``zeros``-family default to float64 and the
#: carriers (``asarray``/``array``/``arange``) stay unknown.
_ZEROS_FAMILY = {"zeros", "ones", "empty", "full",
                 "zeros_like", "ones_like", "empty_like", "full_like"}
_CARRIERS = {"asarray", "array", "arange", "fromiter", "frombuffer"}

#: Substrings marking a name as stream-scale: tuple counts, byte billing,
#: engine clocks, running aggregates.  Matched case-insensitively against
#: the accumulator name and the names feeding the increment.
SCALE_HINTS: Tuple[str, ...] = (
    "count", "cnt", "total", "sum", "byte", "fed", "moved", "tuple",
    "busy", "offset", "acc", "bill", "replay",
)

_WIDTH = {"int8": 8, "uint8": 8, "int16": 16, "uint16": 16,
          "int32": 32, "uint32": 32, "float32": 32,
          "int64": 64, "uint64": 64, "float64": 64}


def _hinted(*names: str) -> bool:
    return any(h in n.lower() for n in names if n for h in SCALE_HINTS)


def _numeric_aliases(tree: ast.Module) -> Set[str]:
    """Local aliases of numpy and jax.numpy (``np``, ``jnp``, ...)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in ("numpy", "jax.numpy"):
                    out.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax" and node.level == 0:
                for a in node.names:
                    if a.name == "numpy":
                        out.add(a.asname or "numpy")
    return out


def _dtype_token(node: ast.AST, aliases: Set[str]) -> Optional[str]:
    """``np.int32`` / ``jnp.int32`` / ``"int32"`` → ``"int32"``."""
    if (isinstance(node, ast.Attribute) and node.attr in _DTYPE_NAMES
            and isinstance(node.value, ast.Name)
            and node.value.id in aliases):
        return node.attr
    if isinstance(node, ast.Constant) and node.value in _DTYPE_NAMES:
        return str(node.value)
    return None


class _DtypeEnv:
    """Flow-insensitive dtype evidence: every dtype each key was observed
    to hold anywhere in its scope (locals) or class (self attributes)."""

    def __init__(self) -> None:
        self.locals: Dict[Tuple[str, str], Set[str]] = {}
        self.attrs: Dict[Tuple[str, str], Set[str]] = {}

    @staticmethod
    def _class_key(node: ast.AST) -> Optional[str]:
        cur = getattr(node, "_parent", None)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return getattr(cur, "_scope", cur.name)
            cur = getattr(cur, "_parent", None)
        return None

    def key_for(self, target: ast.AST) -> Optional[Tuple[str, ...]]:
        """('local', scope, name) or ('attr', class, name) for a target."""
        if isinstance(target, ast.Name):
            return ("local", getattr(target, "_scope", "<module>"),
                    target.id)
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"):
            cls = self._class_key(target)
            if cls is not None:
                return ("attr", cls, target.attr)
        return None

    def record(self, target: ast.AST, dtype: Optional[str]) -> None:
        if dtype is None:
            return
        key = self.key_for(target)
        if key is None:
            return
        store = self.locals if key[0] == "local" else self.attrs
        store.setdefault((key[1], key[2]), set()).add(dtype)

    def observed(self, target: ast.AST) -> Set[str]:
        key = self.key_for(target)
        if key is None:
            return set()
        store = self.locals if key[0] == "local" else self.attrs
        return store.get((key[1], key[2]), set())


def _widest(a: Optional[str], b: Optional[str]) -> Optional[str]:
    if a is None or b is None:
        return a or b
    return a if _WIDTH[a] >= _WIDTH[b] else b


def _expr_dtype(node: ast.AST, env: _DtypeEnv, aliases: Set[str]
                ) -> Optional[str]:
    tok = _dtype_token(node, aliases)
    if tok is not None and isinstance(node, ast.Attribute):
        return None  # a dtype object, not a value of that dtype
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "astype":
                for sub in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    t = _dtype_token(sub, aliases)
                    if t:
                        return t
                return None
            if (isinstance(f.value, ast.Name) and f.value.id in aliases):
                if f.attr in _DTYPE_NAMES:
                    return f.attr          # np.int32(x)
                if f.attr == "bincount":
                    return "int64"
                if f.attr in _ZEROS_FAMILY | _CARRIERS:
                    for sub in list(node.args) + [kw.value
                                                  for kw in node.keywords]:
                        t = _dtype_token(sub, aliases)
                        if t:
                            return t
                    return ("float64" if f.attr in _ZEROS_FAMILY
                            else None)
        return None
    if isinstance(node, (ast.Name, ast.Attribute)):
        seen = env.observed(node)
        narrow = seen & _NARROW
        if narrow:
            # narrow evidence wins unless every write was wide: mixed
            # evidence means the accumulator *can* be narrow on some path
            return sorted(narrow, key=lambda d: -_WIDTH[d])[0]
        if seen:
            return sorted(seen, key=lambda d: -_WIDTH[d])[0]
        return None
    if isinstance(node, ast.BinOp):
        return _widest(_expr_dtype(node.left, env, aliases),
                       _expr_dtype(node.right, env, aliases))
    if isinstance(node, ast.Subscript):
        return _expr_dtype(node.value, env, aliases)
    return None


def _same_ref(a: ast.AST, b: ast.AST) -> bool:
    """`x` is `x`; `self.v` is `self.v` (one attribute level)."""
    if isinstance(a, ast.Name) and isinstance(b, ast.Name):
        return a.id == b.id
    if (isinstance(a, ast.Attribute) and isinstance(b, ast.Attribute)
            and a.attr == b.attr):
        return _same_ref(a.value, b.value)
    return False


def _display(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return f"{_display(node.value)}.{node.attr}"
    if isinstance(node, ast.Subscript):
        return f"{_display(node.value)}[...]"
    return "<expr>"


def _names_in(node: ast.AST) -> List[str]:
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.append(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.append(sub.attr)
    return out


def _accumulation_sites(tree: ast.Module):
    """Yield (anchor node, target expr, increment exprs) per site."""
    for node in ast.walk(tree):
        if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Add):
            t = node.target
            if isinstance(t, ast.Subscript):
                t = t.value
            if isinstance(t, (ast.Name, ast.Attribute)):
                yield node, t, [node.value]
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            t = node.targets[0]
            if not isinstance(t, (ast.Name, ast.Attribute)):
                continue
            v = node.value
            # x = x + inc  (either operand order)
            if isinstance(v, ast.BinOp) and isinstance(v.op, ast.Add):
                if _same_ref(v.left, t):
                    yield node, t, [v.right]
                elif _same_ref(v.right, t):
                    yield node, t, [v.left]
            # x = x.at[i].add(inc)  (jax functional scatter-add)
            elif (isinstance(v, ast.Call) and isinstance(v.func,
                                                         ast.Attribute)
                  and v.func.attr == "add"
                  and isinstance(v.func.value, ast.Subscript)
                  and isinstance(v.func.value.value, ast.Attribute)
                  and v.func.value.value.attr == "at"
                  and _same_ref(v.func.value.value.value, t)):
                yield node, t, list(v.args)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "at"
              and isinstance(node.func.value, ast.Attribute)
              and node.func.value.attr == "add"
              and len(node.args) >= 3):
            # np.add.at(target, idx, inc)
            t = node.args[0]
            if isinstance(t, (ast.Name, ast.Attribute)):
                yield node, t, [node.args[2]]


def rule_int32_overflow(mod) -> List[Finding]:
    """``int32-overflow``: narrow-int accumulators that scale with stream
    length (see module docstring for the lattice)."""
    aliases = _numeric_aliases(mod.tree)
    if not aliases:
        return []
    env = _DtypeEnv()
    # two sweeps: evidence flows through one level of name indirection
    # (`nv = jnp.zeros(..., jnp.int32)` before `self._v = nv`) regardless
    # of the walk order
    for _ in range(2):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                dt = _expr_dtype(node.value, env, aliases)
                for t in node.targets:
                    env.record(t, dt)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                env.record(node.target,
                           _expr_dtype(node.value, env, aliases))

    out: List[Finding] = []
    seen: Set[Tuple[int, int]] = set()
    min_inc = _INT32_MAX // SCALE_TARGET
    for anchor, target, incs in _accumulation_sites(mod.tree):
        dt = _expr_dtype(target, env, aliases)
        if dt not in _NARROW:
            continue
        names = [_display(target).split(".")[-1]]
        for inc in incs:
            names.extend(_names_in(inc))
        if not _hinted(*names):
            continue
        key = (anchor.lineno, anchor.col_offset)
        if key in seen:
            continue
        seen.add(key)
        out.append(mod.finding(
            "int32-overflow", anchor, "error",
            f"`{_display(target)}` accumulates in {dt} and scales with "
            f"stream length — at SCALE_TARGET={SCALE_TARGET:.0e} tuples "
            f"it wraps 2³¹−1 once the mean per-step increment reaches "
            f"{min_inc}",
            "hold the running total in int64 (a device kernel can keep "
            "its int32 chunk domain and widen at the fold — see "
            "DeviceStateStore's int64 lifetime base)"))
    return out
