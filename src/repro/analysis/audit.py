"""Trace/transfer auditor for the fused feed path (ISSUE 7, layer 3).

:class:`EdgeAuditor` wraps one :class:`~repro.kernels.feed_fused.FusedEdgeRunner`
instance and records every jit-boundary crossing:

- each ``run_segment`` launch, with the *recomputed static signature* the
  launch dispatches under (mirroring the tuple ``run_segment`` builds for
  ``_SEG_CACHE``) and the ``TRACE_COUNT`` delta it caused;
- each ``flush_pane`` / ``host_sync`` / ``refresh_membership`` — the
  device→host sync points — tagged with where in the feed they happened.

From that log it asserts the two budgets DESIGN.md §11 documents:

- **retrace budget** — traces observed ≤ distinct static signatures
  observed (every trace is explained by a new signature; nothing retraces
  on a signature already compiled);
- **sync budget** — device→host transfers happen only at pane-stride
  boundaries, at declared events, or at close
  (:data:`~repro.analysis.contracts.HOST_SYNC_POINTS`).

``jax.transfer_guard`` does not fire on the CPU backend (transfers are
zero-copy views there), so the auditor instruments the runner's methods —
the only code paths that materialize device state — instead of relying on
the guard.  On TPU the same audit holds with real transfers underneath.

Use as a context manager::

    runner = ...  # EdgeState.device after a fused open/feed
    with EdgeAuditor(runner, pane_stride=pane) as aud:
        session.feed(batch)
        ...
    aud.assert_retrace_budget()
    aud.assert_sync_budget(closed=True)
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Set, Tuple

from . import contracts

__all__ = ["AuditEvent", "EdgeAuditor", "TraceBudget"]


def _trace_count() -> int:
    from ..kernels import feed_fused

    return feed_fused.TRACE_COUNT


@dataclasses.dataclass
class AuditEvent:
    kind: str                 # begin_feed | segment | flush_pane |
                              # host_sync | refresh_membership
    tuples: int = 0           # segment length / feed length
    offset: int = 0           # cumulative tuples fed when this happened
    signature: Optional[tuple] = None  # segment launches only
    traces: int = 0           # TRACE_COUNT delta caused by this call
    context: str = "feed"     # feed | event | close (expect() tag)


class EdgeAuditor:
    """Instrument a live FusedEdgeRunner; restore on exit."""

    _METHODS = ("begin_feed", "run_segment", "flush_pane", "host_sync",
                "refresh_membership")

    def __init__(self, runner, pane_stride: Optional[int] = None) -> None:
        self.runner = runner
        self.pane_stride = pane_stride
        self.events: List[AuditEvent] = []
        self.signatures: Set[tuple] = set()
        self.traces = 0
        self._offset = 0          # tuples fed since the audit started
        self._context = "feed"
        self._orig = {}

    # -- lifecycle ---------------------------------------------------------

    def __enter__(self) -> "EdgeAuditor":
        r = self.runner
        for name in self._METHODS:
            self._orig[name] = getattr(r, name)
        r.begin_feed = self._begin_feed
        r.run_segment = self._run_segment
        r.flush_pane = self._flush_pane
        r.host_sync = self._host_sync
        r.refresh_membership = self._refresh_membership
        return self

    def __exit__(self, *exc) -> None:
        self.restore()

    def restore(self) -> None:
        for name, fn in self._orig.items():
            setattr(self.runner, name, fn)
        self._orig.clear()

    @contextlib.contextmanager
    def expect(self, context: str):
        """Declare a sanctioned sync context ('event' or 'close') around
        engine calls that legitimately cross the device→host boundary off
        the pane grid."""
        if context not in contracts.HOST_SYNC_POINTS:
            raise ValueError(f"unknown sync context {context!r}; one of "
                             f"{contracts.HOST_SYNC_POINTS}")
        prev, self._context = self._context, context
        try:
            yield self
        finally:
            self._context = prev

    # -- instrumented methods ----------------------------------------------

    def _begin_feed(self, grouper, state, keys_arr, values, times, sink):
        t0 = _trace_count()
        out = self._orig["begin_feed"](grouper, state, keys_arr, values,
                                       times, sink)
        self._log("begin_feed", tuples=int(keys_arr.shape[0]),
                  traces=_trace_count() - t0)
        return out

    def _run_segment(self, grouper, state, lo: int, hi: int):
        r = self.runner
        sig = self._signature(lo, hi)
        t0 = _trace_count()
        out = self._orig["run_segment"](grouper, state, lo, hi)
        self._offset += hi - lo
        ev = self._log("segment", tuples=hi - lo,
                       traces=_trace_count() - t0)
        ev.signature = sig
        self.signatures.add(sig)
        return out

    def _flush_pane(self, sink):
        t0 = _trace_count()
        out = self._orig["flush_pane"](sink)
        self._log("flush_pane", traces=_trace_count() - t0)
        return out

    def _host_sync(self, grouper):
        t0 = _trace_count()
        out = self._orig["host_sync"](grouper)
        self._log("host_sync", traces=_trace_count() - t0)
        return out

    def _refresh_membership(self, grouper, state):
        t0 = _trace_count()
        out = self._orig["refresh_membership"](grouper, state)
        self._log("refresh_membership", traces=_trace_count() - t0)
        return out

    def _log(self, kind: str, tuples: int = 0, traces: int = 0
             ) -> AuditEvent:
        ev = AuditEvent(kind=kind, tuples=tuples, offset=self._offset,
                        traces=traces, context=self._context)
        self.traces += traces
        self.events.append(ev)
        return ev

    def _signature(self, lo: int, hi: int) -> tuple:
        """Mirror of the static-signature tuple ``run_segment`` keys
        ``_SEG_CACHE`` with — recomputed from runner state *before* the
        launch, so the audit is independent of the cache internals."""
        from ..kernels.feed_fused import _bucket

        r = self.runner
        n_pad = _bucket(hi - lo)
        if r.scheme == "sg":
            r_n, dmax = 0, 0
        else:
            r_n = r._pts.shape[0]
            dmax = r._cands.shape[1]
        reset = r.has_pane and r.pane_tab is None
        return (r.scheme, n_pad, r._w1, r._kcap + 1, r_n, dmax,
                r.has_pane, reset, r.fifo_impl)

    # -- budget assertions -------------------------------------------------

    @property
    def dispatches(self) -> int:
        return sum(1 for e in self.events if e.kind == "segment")

    def assert_retrace_budget(self) -> None:
        """Traces ≤ distinct static signatures: nothing recompiled on a
        signature that was already compiled during this audit."""
        if self.traces > len(self.signatures):
            lines = [f"  {e.kind} @offset={e.offset} sig={e.signature} "
                     f"traces=+{e.traces}"
                     for e in self.events if e.traces]
            raise AssertionError(
                f"retrace budget exceeded: {self.traces} traces for "
                f"{len(self.signatures)} distinct signatures\n"
                + "\n".join(lines))

    def assert_sync_budget(self, closed: bool = False) -> None:
        """Every flush_pane/host_sync sits on a sanctioned sync point:
        a pane-stride boundary, a declared expect('event') /
        expect('close') context, or — when ``closed`` — the trailing
        close-time flush+sync pair."""
        syncs = [e for e in self.events
                 if e.kind in ("flush_pane", "host_sync")]
        tail: List[AuditEvent] = []
        if closed:
            while syncs and syncs[-1].offset == self._offset:
                tail.append(syncs.pop())
                if len(tail) == 2:
                    break
        bad = []
        for e in syncs:
            if e.context in ("event", "close"):
                continue
            if (self.pane_stride
                    and e.offset % self.pane_stride == 0):
                continue
            bad.append(e)
        if bad:
            raise AssertionError(
                "device→host sync off the sanctioned points "
                f"({', '.join(contracts.HOST_SYNC_POINTS)}): "
                + "; ".join(f"{e.kind} @offset={e.offset} "
                            f"context={e.context}" for e in bad))


class TraceBudget:
    """Assert TRACE_COUNT grows by at most ``budget`` inside the block::

        with TraceBudget(3):
            ...  # feeds across three distinct pow2 buckets
    """

    def __init__(self, budget: int, what: str = "block") -> None:
        self.budget = budget
        self.what = what
        self.traces = 0

    def __enter__(self) -> "TraceBudget":
        self._t0 = _trace_count()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.traces = _trace_count() - self._t0
        if exc_type is None and self.traces > self.budget:
            raise AssertionError(
                f"{self.what}: {self.traces} traces > budget "
                f"{self.budget}")
