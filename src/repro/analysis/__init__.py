"""Static hot-path hygiene + dataflow-contract checking (DESIGN.md §12).

Three layers:

- :mod:`repro.analysis.lint` — AST lint engine with JAX-aware rules
  (host-sync-in-jit, retrace-hazard, np-jnp-mixing, frozen-mutation,
  deprecated-shim, unordered-iteration, exactness-contract,
  topology-config);
- :mod:`repro.analysis.contracts` — the scheme × engine exactness table
  and static mirrors of the runtime topology/config build errors;
- :mod:`repro.analysis.audit` — runtime trace/transfer auditor for the
  fused engine's jit boundaries.

CLI: ``python -m repro.analysis [paths...]`` (see :mod:`.cli`), gated in
CI against the checked-in ``analysis_baseline.json``.

This package is import-light: pulling in the contracts table or the lint
engine must not drag jax in (the CI lint job stays fast), so jax-touching
imports live inside functions.
"""

from .contracts import (BANDED_SCHEMES, DRIFT_SCHEMES, EXACT_SCHEMES,
                        EXACTNESS, SCHEMES, exactness)
from .findings import Baseline, Finding, apply_baseline
from .lint import RULES, lint_file, lint_paths

__all__ = [
    "SCHEMES", "EXACTNESS", "EXACT_SCHEMES", "BANDED_SCHEMES",
    "DRIFT_SCHEMES", "exactness",
    "Finding", "Baseline", "apply_baseline",
    "RULES", "lint_file", "lint_paths",
]
