"""Whole-program static analysis + runtime sanitizer (DESIGN.md §12, §15).

Layers:

- :mod:`repro.analysis.lint` — AST lint engine with JAX-aware rules
  (host-sync-in-jit, retrace-hazard, np-jnp-mixing, frozen-mutation,
  deprecated-shim, unordered-iteration, exactness-contract,
  topology-config, registry-counter-mutation, and the ISSUE-10 rules
  int32-overflow / unseeded-rng / wall-clock-leak / unbounded-signature /
  interproc-unordered-iteration);
- :mod:`repro.analysis.callgraph` — whole-program layer: import
  resolution, cross-module jit traced-set closure, interprocedural rules
  (:func:`lint_program` is the CLI/CI entry point);
- :mod:`repro.analysis.numerics` — abstract integer-width/overflow pass
  against :data:`repro.analysis.contracts.SCALE_TARGET`;
- :mod:`repro.analysis.determinism` — RNG, wall-clock, and
  jit-signature-space determinism rules;
- :mod:`repro.analysis.contracts` — the scheme × engine exactness table,
  static mirrors of the runtime topology/config build errors, and the
  determinism/numerics targets;
- :mod:`repro.analysis.sanitize` — the dynamic twin: same-seed double-run
  under strict numerics, reports diffed bit-for-bit;
- :mod:`repro.analysis.audit` — runtime trace/transfer auditor for the
  fused engine's jit boundaries.

CLI: ``python -m repro.analysis [paths...]`` (see :mod:`.cli`), gated in
CI against the checked-in ``analysis_baseline.json``.

This package is import-light: pulling in the contracts table or the lint
engine must not drag jax or numpy in (the CI lint job stays fast and
dependency-free), so jax/numpy-touching imports live inside functions.
"""

from .callgraph import lint_program
from .contracts import (BANDED_SCHEMES, DRIFT_SCHEMES, EXACT_SCHEMES,
                        EXACTNESS, SCALE_TARGET, SCHEMES, exactness)
from .findings import Baseline, Finding, apply_baseline
from .lint import RULES, lint_file, lint_paths

__all__ = [
    "SCHEMES", "EXACTNESS", "EXACT_SCHEMES", "BANDED_SCHEMES",
    "DRIFT_SCHEMES", "exactness", "SCALE_TARGET",
    "Finding", "Baseline", "apply_baseline",
    "RULES", "lint_file", "lint_paths", "lint_program",
]
