"""Dataflow contracts as data (ISSUE 7, layer 2).

Two things live here, both *imported* by the code they govern instead of
being re-derived at every call site:

1. **The exactness-contract table** — scheme × engine → ``exact`` |
   ``banded``.  This is the single source of truth for which engine modes
   must reproduce the per-tuple reference oracle bit-for-bit and which are
   only §6-banded (DESIGN.md §6/§11).  The equivalence tests import
   :data:`EXACT_SCHEMES` / :data:`BANDED_SCHEMES` from here, and the
   ``exactness-contract`` lint rule flags any module that hardcodes its own
   partition — a test asserting the wrong contract is a lint finding, not a
   flake.

2. **Static mirrors of the runtime ``Topology``/``SchemeConfig`` build
   errors** — the checks :class:`repro.topology.Topology` and the typed
   scheme configs run eagerly at construction, re-expressed over plain
   literals (stage names, edge endpoint pairs, config kwargs) so the
   ``topology-config`` lint rule can run them over an AST at review time,
   before any runtime exists.  Config kwargs are validated by actually
   constructing the (pure, frozen) config dataclass: the runtime validator
   *is* the static validator, so the two can never drift.

The trace/transfer budgets of the fused feed path (DESIGN.md §11) are also
declared here so the auditor (:mod:`repro.analysis.audit`) and its tier-1
tests assert the documented numbers rather than private copies.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "SCHEMES",
    "ENGINE_MODES",
    "EXACT",
    "BANDED",
    "EXACTNESS",
    "EXACT_SCHEMES",
    "BANDED_SCHEMES",
    "DRIFT_SCHEMES",
    "exactness",
    "exact_schemes",
    "banded_schemes",
    "STEADY_FEED_DISPATCHES",
    "HOST_DISPATCHES",
    "HOST_SYNC_POINTS",
    "SCALE_TARGET",
    "WALL_CLOCK_STAMP_MODULES",
    "validate_config_literal",
    "validate_stage_literal",
    "validate_edge_literal",
    "validate_topology_literal",
]

# ---------------------------------------------------------------------------
# exactness-contract table (scheme × engine mode → contract vs the oracle)
# ---------------------------------------------------------------------------

SCHEMES: Tuple[str, ...] = ("sg", "fg", "pkg", "dc", "wc", "fish")
ENGINE_MODES: Tuple[str, ...] = ("reference", "batched", "fused")

EXACT = "exact"    # bit-identical routing/counts/replicas vs the oracle
BANDED = "banded"  # bounded drift within the DESIGN.md §6 bands

#: The contract of each (scheme, engine mode) against the per-tuple
#: reference oracle.  SG/FG/PKG route sequentially-exactly in every engine;
#: DC/WC/FISH read frequencies at sub-chunk/segment granularity in the
#: batched and fused engines, so they are banded there (DESIGN.md §6, §11).
#: Fused-mode timing additionally carries an f32 epsilon — that is a
#: *metric* tolerance, not a routing contract, and is not encoded here.
EXACTNESS: Dict[Tuple[str, str], str] = {}
for _s in SCHEMES:
    EXACTNESS[(_s, "reference")] = EXACT
    _routed_exact = _s in ("sg", "fg", "pkg")
    EXACTNESS[(_s, "batched")] = EXACT if _routed_exact else BANDED
    EXACTNESS[(_s, "fused")] = EXACT if _routed_exact else BANDED


def exactness(scheme: str, mode: str) -> str:
    """``exact`` | ``banded`` for one (scheme, engine-mode) pair."""
    try:
        return EXACTNESS[(scheme, mode)]
    except KeyError:
        raise ValueError(
            f"unknown (scheme, mode) = ({scheme!r}, {mode!r}); schemes: "
            f"{SCHEMES}, modes: {ENGINE_MODES}")


def exact_schemes(mode: str = "batched") -> Tuple[str, ...]:
    return tuple(s for s in SCHEMES if exactness(s, mode) == EXACT)


def banded_schemes(mode: str = "batched") -> Tuple[str, ...]:
    return tuple(s for s in SCHEMES if exactness(s, mode) == BANDED)


#: The canonical partitions the equivalence tests parameterize over.
#: (Identical for the batched and fused engines — asserted by the table
#: construction above and re-asserted in tests/test_analysis.py.)
EXACT_SCHEMES: Tuple[str, ...] = exact_schemes("batched")
BANDED_SCHEMES: Tuple[str, ...] = banded_schemes("batched")
DRIFT_SCHEMES = BANDED_SCHEMES  # historical alias used by the test suite

# ---------------------------------------------------------------------------
# trace / transfer budgets of the fused feed path (DESIGN.md §11)
# ---------------------------------------------------------------------------

#: Device launches per steady-state ``session.feed`` (feed boundaries on
#: pane boundaries, no events): the ISSUE-6 headline contract.
STEADY_FEED_DISPATCHES = 1

#: Device launches made by the host engines (batched / reference): none.
HOST_DISPATCHES = 0

#: The only sanctioned device→host sync points of a fused edge.  The
#: auditor classifies every observed ``flush_pane`` / ``host_sync`` into
#: one of these; anything else is a budget violation.
HOST_SYNC_POINTS: Tuple[str, ...] = ("pane_boundary", "event", "close")

# ---------------------------------------------------------------------------
# determinism & numerics targets (ISSUE 10)
# ---------------------------------------------------------------------------

#: The tuple count every counter/accumulator must survive — the ROADMAP's
#: multi-host north star (10⁷–10⁸ tuples/run).  The ``int32-overflow``
#: pass phrases its findings against this number, and the accepted-findings
#: baseline records which target its justifications were audited against
#: (a baseline justified at 10⁸ says nothing about 10¹⁰).
SCALE_TARGET: int = 10 ** 8

#: The only modules allowed to read the wall clock: the obs stamp points
#: (trace spans and metric-timeline stamps carry real timestamps *by
#: design*).  A ``time.*``/``datetime.now`` value escaping a function
#: anywhere else can reach ``TopologyReport``/timeline state, making two
#: same-seed runs diverge — the ``wall-clock-leak`` rule flags exactly
#: those escapes.
WALL_CLOCK_STAMP_MODULES: Tuple[str, ...] = (
    "src/repro/obs/trace.py",
    "src/repro/obs/timeline.py",
)


# ---------------------------------------------------------------------------
# static mirrors of the runtime Topology / SchemeConfig build errors
# ---------------------------------------------------------------------------


def validate_config_literal(scheme: str, kwargs: Dict[str, object]
                            ) -> Optional[str]:
    """Validate a ``config_for(scheme, **kwargs)`` call whose arguments are
    all literals, by running the real (pure, frozen-dataclass) constructor.
    Returns an error message, or None when the config is valid."""
    from ..topology.configs import config_for

    try:
        config_for(scheme, **kwargs)
    except (ValueError, TypeError) as e:
        return str(e)
    return None


SOURCE = "source"  # mirror of repro.topology.graph.SOURCE


def validate_stage_literal(name: object, parallelism: object,
                           cost: object = None,
                           capacities: object = None) -> Optional[str]:
    """Literal mirror of ``Stage.__post_init__`` (the checks expressible
    without constructing transforms/operators)."""
    if isinstance(name, str) and (not name or name == SOURCE):
        return f"invalid stage name {name!r} ({SOURCE!r} is reserved)"
    if isinstance(parallelism, int) and parallelism < 1:
        return (f"stage {name!r}: parallelism must be >= 1, "
                f"got {parallelism}")
    if isinstance(cost, (int, float)) and cost <= 0.0:
        return f"stage {name!r}: cost must be positive"
    if cost is not None and capacities:
        return f"stage {name!r}: give cost or capacities, not both"
    return None


def validate_edge_literal(src: object, dst: object,
                          grouping_is_config: Optional[bool] = None
                          ) -> Optional[str]:
    """Literal mirror of ``Edge.__post_init__``."""
    if dst == SOURCE:
        return "an edge cannot point at the source"
    if isinstance(src, str) and src == dst:
        return f"self-edge on stage {src!r}"
    if grouping_is_config is False:
        return (f"edge {src}->{dst}: grouping must be a SchemeConfig "
                f"(use repro.topology.configs.config_for(name))")
    return None


def validate_topology_literal(stage_names: Sequence[str],
                              edges: Iterable[Tuple[str, str]]
                              ) -> List[str]:
    """Literal mirror of ``Topology.__post_init__`` over extracted stage
    names and (src, dst) endpoint pairs: duplicate stages, unknown
    endpoints, fan-in, unreachable stages, disconnection/cycles."""
    errors: List[str] = []
    names = list(stage_names)
    if not names:
        return ["topology needs at least one stage"]
    if len(set(names)) != len(names):
        errors.append(f"duplicate stage names in {names}")
    known = set(names)
    edges = list(edges)
    indeg = {n: 0 for n in names}
    for src, dst in edges:
        if src != SOURCE and src not in known:
            errors.append(f"edge {src}->{dst}: unknown src {src!r}")
        if dst not in known:
            errors.append(f"edge {src}->{dst}: unknown dst {dst!r}")
        else:
            indeg[dst] += 1
    for n, d in indeg.items():
        if d == 0:
            errors.append(f"stage {n!r} has no inbound edge (unreachable)")
        elif d > 1:
            errors.append(f"stage {n!r} has {d} inbound edges; fan-in onto "
                          f"a shared worker pool is not supported")
    # BFS from the source over the edge list (the runtime ordered_edges walk)
    if not errors:
        reached = 0
        frontier = [SOURCE]
        remaining = list(edges)
        while frontier:
            nxt, keep = [], []
            for src, dst in remaining:
                if src in frontier:
                    reached += 1
                    nxt.append(dst)
                else:
                    keep.append((src, dst))
            remaining, frontier = keep, nxt
        if reached != len(edges):
            errors.append("topology is not connected to the source "
                          "(cycle or disconnected component)")
    return errors
