"""Determinism rules: RNG, wall clock, jit-signature spaces (ISSUE 10).

Three rules, all protecting the same invariant the paper's validation
rests on — two runs with the same seed produce bit-identical reports:

``unseeded-rng``
    Global-state RNG destroys seeded replay: ``np.random.rand`` /
    ``np.random.seed`` (the module-level legacy API) and stdlib
    ``random.*`` module functions share hidden process state, so any
    other consumer (another session, a test, a warm-up) shifts the
    stream.  Seedless constructors (``np.random.default_rng()``,
    ``PCG64()``, ``RandomState()`` with no arguments) draw OS entropy —
    unreplayable by definition.  The sanctioned pattern is an
    explicitly-seeded ``np.random.Generator`` threaded through the code
    that draws from it (``EdgeState.rng``, the synthetic generators).

``wall-clock-leak``
    A ``time.*``/``datetime.now`` read is fine while it stays local
    (elapsed-time prints); it breaks replay the moment it *escapes* —
    returned, yielded, or stored on an object — because the escaped stamp
    can reach ``TopologyReport``/timeline values.  The rule runs a
    per-function taint pass (wall-clock calls seed taint; assignments
    propagate it; return/yield/attribute-store sink it) plus a flat ban on
    module-level reads (an import-time stamp is a hidden global).  The
    declared obs stamp points
    (:data:`repro.analysis.contracts.WALL_CLOCK_STAMP_MODULES`) are
    exempt: timestamps are their *job*.

``unbounded-signature``
    A jit cache keyed by a static-signature tuple
    (``_SEG_CACHE[sig] = jax.jit(...)``) recompiles once per distinct
    tuple value, so the cache is only bounded if every element's value
    set is.  The rule finds cache-store sites, chases the key back to its
    tuple construction (through locals and one call-site hop for
    parameters), and classifies each element: literals, booleans
    (comparisons, ``is None``), ``bit_length``-bucketed sizes and
    compositions thereof are bounded; anything rooted in open-ended
    runtime values (``x.shape[0]``, foreign attributes, raw parameters)
    is not, and gets a finding naming the element.  Sanctioned unbounded
    elements (worker-universe growth) are baselined with a ``why``, which
    is exactly the documentation the recompile budget wants.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .contracts import WALL_CLOCK_STAMP_MODULES
from .findings import Finding
from .lint import _is_jit_ref

__all__ = [
    "rule_unseeded_rng",
    "rule_wall_clock_leak",
    "rule_unbounded_signature",
]


# ---------------------------------------------------------------------------
# unseeded-rng
# ---------------------------------------------------------------------------

#: numpy.random constructors that are deterministic *when given a seed*.
_SEEDED_CTORS = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "PCG64DXSM", "Philox", "MT19937", "RandomState"}
#: stdlib random: the seedable class is fine; everything module-level (and
#: SystemRandom, which is nondeterministic by design) is not.
_PY_SEEDED = {"Random"}


class _RngAliases:
    def __init__(self, tree: ast.Module) -> None:
        self.numpy: Set[str] = set()       # import numpy as np
        self.np_random: Set[str] = set()   # import numpy.random as npr
        self.py_random: Set[str] = set()   # import random
        self.np_names: Dict[str, str] = {}  # from numpy.random import X
        self.py_names: Dict[str, str] = {}  # from random import X
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.numpy.add(a.asname or "numpy")
                    elif a.name == "numpy.random":
                        if a.asname:
                            self.np_random.add(a.asname)
                        else:
                            self.numpy.add("numpy")
                    elif a.name == "random":
                        self.py_random.add(a.asname or "random")
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                if node.module == "numpy":
                    for a in node.names:
                        if a.name == "random":
                            self.np_random.add(a.asname or "random")
                elif node.module == "numpy.random":
                    for a in node.names:
                        self.np_names[a.asname or a.name] = a.name
                elif node.module == "random":
                    for a in node.names:
                        self.py_names[a.asname or a.name] = a.name


def _attr_parts(node: ast.AST) -> Optional[List[str]]:
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return parts[::-1]
    return None


def _has_seed(call: ast.Call) -> bool:
    return bool(call.args) or any(kw.arg == "seed" for kw in call.keywords)


def rule_unseeded_rng(mod) -> List[Finding]:
    al = _RngAliases(mod.tree)
    out: List[Finding] = []

    def flag(node: ast.Call, what: str, msg: str, hint: str) -> None:
        out.append(mod.finding("unseeded-rng", node, "error",
                               f"`{what}` {msg}", hint))

    def check_np(node: ast.Call, fn: str, what: str) -> None:
        if fn in _SEEDED_CTORS:
            if not _has_seed(node):
                flag(node, what, "draws OS entropy when constructed "
                     "without a seed — two same-\"seed\" runs diverge",
                     "pass the run's seed explicitly "
                     "(np.random.default_rng(seed))")
        else:
            flag(node, what, "mutates numpy's hidden global RNG state — "
                 "any other consumer shifts the stream and seeded replay "
                 "breaks",
                 "draw from an explicitly-seeded, explicitly-threaded "
                 "np.random.Generator instead")

    def check_py(node: ast.Call, fn: str, what: str) -> None:
        if fn in _PY_SEEDED:
            if not _has_seed(node):
                flag(node, what, "seeds itself from OS entropy",
                     "pass the run's seed (random.Random(seed))")
        elif fn == "SystemRandom":
            flag(node, what, "is nondeterministic by design",
                 "use a seeded random.Random / np.random.Generator")
        else:
            flag(node, what, "uses the stdlib's hidden global RNG state",
                 "thread a seeded random.Random (or better, the run's "
                 "np.random.Generator)")

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        parts = _attr_parts(node.func)
        if parts is not None and len(parts) >= 2:
            if (len(parts) >= 3 and parts[0] in al.numpy
                    and parts[1] == "random"):
                check_np(node, parts[2], ".".join(parts[:3]))
                continue
            if parts[0] in al.np_random:
                check_np(node, parts[1], ".".join(parts[:2]))
                continue
            if parts[0] in al.py_random:
                check_py(node, parts[1], ".".join(parts[:2]))
                continue
        elif isinstance(node.func, ast.Name):
            name = node.func.id
            if name in mod.funcs:
                continue  # locally shadowed
            if name in al.np_names:
                check_np(node, al.np_names[name], name)
            elif name in al.py_names:
                check_py(node, al.py_names[name], name)
    return out


# ---------------------------------------------------------------------------
# wall-clock-leak
# ---------------------------------------------------------------------------

_WALL_TIME_FNS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
                  "monotonic", "monotonic_ns", "process_time",
                  "process_time_ns"}
_WALL_DT_FNS = {"now", "utcnow", "today"}


class _ClockAliases:
    def __init__(self, tree: ast.Module) -> None:
        self.time: Set[str] = set()
        self.datetime: Set[str] = set()      # the datetime *class*
        self.datetime_mod: Set[str] = set()  # the datetime *module*
        self.names: Set[str] = set()         # from time import perf_counter
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "time":
                        self.time.add(a.asname or "time")
                    elif a.name == "datetime":
                        self.datetime_mod.add(a.asname or "datetime")
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                if node.module == "time":
                    for a in node.names:
                        if a.name in _WALL_TIME_FNS:
                            self.names.add(a.asname or a.name)
                elif node.module == "datetime":
                    for a in node.names:
                        if a.name in ("datetime", "date"):
                            self.datetime.add(a.asname or a.name)


def _is_wall_clock(node: ast.AST, al: _ClockAliases) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Name):
        return f.id in al.names
    parts = _attr_parts(f)
    if parts is None or len(parts) < 2:
        return False
    if parts[0] in al.time and parts[1] in _WALL_TIME_FNS:
        return True
    if parts[0] in al.datetime and parts[1] in _WALL_DT_FNS:
        return True
    return (len(parts) >= 3 and parts[0] in al.datetime_mod
            and parts[1] in ("datetime", "date")
            and parts[2] in _WALL_DT_FNS)


def _contains_taint(node: ast.AST, tainted: Set[str],
                    al: _ClockAliases) -> bool:
    for sub in ast.walk(node):
        if _is_wall_clock(sub, al):
            return True
        if (isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load)
                and sub.id in tainted):
            return True
    return False


def _own_statements(fn: ast.AST):
    """Walk a function's nodes, skipping nested function/class bodies."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop(0)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def rule_wall_clock_leak(mod) -> List[Finding]:
    if mod.rel.replace("\\", "/") in WALL_CLOCK_STAMP_MODULES:
        return []
    al = _ClockAliases(mod.tree)
    if not (al.time or al.datetime or al.datetime_mod or al.names):
        return []
    out: List[Finding] = []

    # module-level reads: an import-time stamp is a hidden global
    for node in ast.walk(mod.tree):
        if (_is_wall_clock(node, al)
                and getattr(node, "_scope", None) == "<module>"):
            out.append(mod.finding(
                "wall-clock-leak", node, "warn",
                "module-level wall-clock read — an import-time stamp is a "
                "hidden global that differs between otherwise identical "
                "runs",
                "read the clock inside the obs stamp points, or pass "
                "stamps in explicitly"))

    for fn in sorted(set(mod.funcs.values()), key=lambda f: f.lineno):
        tainted: Set[str] = set()
        stmts = list(_own_statements(fn))
        # two passes: taint reaches uses that lexically precede the
        # assignment order ast.walk discovered them in
        for _ in range(2):
            for node in stmts:
                targets: List[ast.AST] = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                elif isinstance(node, (ast.For, ast.AsyncFor)):
                    if _contains_taint(node.iter, tainted, al):
                        targets = [node.target]
                elif isinstance(node, ast.withitem):
                    if (node.optional_vars is not None
                            and _contains_taint(node.context_expr,
                                                tainted, al)):
                        targets = [node.optional_vars]
                value = getattr(node, "value", None)
                if targets and value is not None and _contains_taint(
                        value, tainted, al):
                    for t in targets:
                        for sub in ast.walk(t):
                            if isinstance(sub, ast.Name):
                                tainted.add(sub.id)
        for node in stmts:
            if isinstance(node, (ast.Return, ast.Yield)):
                if node.value is not None and _contains_taint(
                        node.value, tainted, al):
                    out.append(mod.finding(
                        "wall-clock-leak", node, "warn",
                        f"wall-clock-derived value escapes `{fn.name}` — "
                        f"an escaped stamp can reach report/timeline "
                        f"state, so two same-seed runs diverge",
                        "derive times from the engine clock, or stamp "
                        "only inside the declared obs stamp points "
                        "(contracts.WALL_CLOCK_STAMP_MODULES)"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                value = node.value
                if value is None or not _contains_taint(value, tainted, al):
                    continue
                for t in targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    if isinstance(base, ast.Attribute):
                        out.append(mod.finding(
                            "wall-clock-leak", node, "warn",
                            f"wall-clock-derived value stored on "
                            f"`{_attr_src(base)}` persists beyond "
                            f"`{fn.name}` and can reach report/timeline "
                            f"state",
                            "stamp only inside the declared obs stamp "
                            "points, or pass the stamp in explicitly"))
                        break
    return out


def _attr_src(node: ast.Attribute) -> str:
    parts = _attr_parts(node)
    return ".".join(parts) if parts else node.attr


# ---------------------------------------------------------------------------
# unbounded-signature
# ---------------------------------------------------------------------------

def _enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    cur = getattr(node, "_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "_parent", None)
    return None


def _scope_of(node: ast.AST) -> str:
    return getattr(node, "_scope", "<module>")


class _BoundChecker:
    """Classify whether an expression's *value set* is statically bounded.

    Bounded: literals, booleans (comparisons, ``is``/``in``, ``not``),
    ``x.bit_length()`` (≤ 64 values), shifts/arithmetic/``min``/``max``/
    conditional expressions over bounded operands, names and ``self.X``
    attributes whose every assignment is bounded (cycles among such
    definitions introduce no new values and count as bounded), and calls
    to module functions all of whose return expressions are bounded (the
    ``_bucket``-style pow2 helpers).  Everything else — raw parameters,
    ``.shape[0]``, foreign attributes, subscripts — is open-ended.
    """

    def __init__(self, mod) -> None:
        self.mod = mod
        self._in_progress: Set[Tuple[str, str]] = set()

    # -- assignment collection ------------------------------------------

    def _local_assigns(self, scope: str, name: str) -> List[ast.AST]:
        out = []
        for node in ast.walk(self.mod.tree):
            if (isinstance(node, ast.Assign)
                    and _scope_of(node) == scope):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == name:
                        out.append(node.value)
        return out

    def _attr_assigns(self, attr: str) -> List[ast.AST]:
        out = []
        for node in ast.walk(self.mod.tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if (isinstance(t, ast.Attribute) and t.attr == attr
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        out.append(node.value)
        return out

    # -- classification -------------------------------------------------

    def bounded(self, node: ast.AST, scope: str) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, (ast.Compare, ast.BoolOp)):
            return True
        if isinstance(node, ast.UnaryOp):
            return (isinstance(node.op, ast.Not)
                    or self.bounded(node.operand, scope))
        if isinstance(node, ast.IfExp):
            return (self.bounded(node.body, scope)
                    and self.bounded(node.orelse, scope))
        if isinstance(node, ast.BinOp):
            return (self.bounded(node.left, scope)
                    and self.bounded(node.right, scope))
        if isinstance(node, ast.Call):
            return self._bounded_call(node, scope)
        if isinstance(node, ast.Name):
            return self._bounded_name(node.id, scope)
        if isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                return self._bounded_defs(
                    ("attr", node.attr), self._attr_assigns(node.attr))
            return False
        return False

    def _bounded_call(self, node: ast.Call, scope: str) -> bool:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "bit_length":
            return True  # ≤ 64 distinct values whatever the operand
        if isinstance(f, ast.Name):
            if f.id == "bool":
                return True
            if f.id in ("min", "max", "abs", "int"):
                return all(self.bounded(a, scope) for a in node.args)
            target = self.mod.funcs.get(f.id)
            if target is not None:
                key = ("fn", f.id)
                if key in self._in_progress:
                    return True
                self._in_progress.add(key)
                try:
                    returns = [n.value for n in ast.walk(target)
                               if isinstance(n, ast.Return)
                               and n.value is not None]
                    return bool(returns) and all(
                        self.bounded(r, _scope_of(r)) for r in returns)
                finally:
                    self._in_progress.discard(key)
        return False

    def _bounded_name(self, name: str, scope: str) -> bool:
        assigns = self._local_assigns(scope, name)
        if not assigns and scope != "<module>":
            # fall back to module globals (MIN_BUCKET-style constants)
            assigns = self._local_assigns("<module>", name)
            if assigns:
                return self._bounded_defs(("g", name), assigns,
                                          "<module>")
            return False  # a parameter or foreign name: open-ended
        return self._bounded_defs((scope, name), assigns, scope)

    def _bounded_defs(self, key, assigns: Sequence[ast.AST],
                      scope: Optional[str] = None) -> bool:
        if not assigns:
            return False
        if key in self._in_progress:
            return True  # definition cycle: no new values introduced
        self._in_progress.add(key)
        try:
            return all(self.bounded(a, scope or _scope_of(a))
                       for a in assigns)
        finally:
            self._in_progress.discard(key)


def _jit_cache_stores(mod):
    """(assign node, subscript key expr) for ``CACHE[sig] = jax.jit(...)``."""
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and _is_jit_ref(node.value.func)):
            continue
        for t in node.targets:
            if isinstance(t, ast.Subscript):
                yield node, t.slice


def _sig_tuples(mod, key_expr: ast.AST):
    """Resolve a cache-key expression to (tuple node, scope) candidates:
    the tuple construction(s) whose value reaches the cache subscript —
    directly, via a local assignment, or via one parameter/call-site hop."""
    if isinstance(key_expr, ast.Tuple):
        yield key_expr, _scope_of(key_expr)
        return
    if not isinstance(key_expr, ast.Name):
        return
    name = key_expr.id
    fn = _enclosing_function(key_expr)
    scope = _scope_of(key_expr)
    local = [v for v in ast.walk(mod.tree)
             if isinstance(v, ast.Assign) and _scope_of(v) == scope
             for t in v.targets
             if isinstance(t, ast.Name) and t.id == name]
    for assign in local:
        if isinstance(assign.value, ast.Tuple):
            yield assign.value, scope
    if local or fn is None:
        return
    params = [a.arg for a in (list(fn.args.posonlyargs)
                              + list(fn.args.args))]
    if name not in params:
        return
    idx = params.index(name)
    for call in ast.walk(mod.tree):
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Name)
                and call.func.id == fn.name):
            continue
        arg: Optional[ast.AST] = None
        if idx < len(call.args):
            arg = call.args[idx]
        else:
            arg = next((kw.value for kw in call.keywords
                        if kw.arg == name), None)
        if arg is None:
            continue
        for tup, sc in _sig_tuples(mod, arg):
            yield tup, sc


def rule_unbounded_signature(mod) -> List[Finding]:
    out: List[Finding] = []
    checker = _BoundChecker(mod)
    seen: Set[Tuple[int, int, int]] = set()
    for _, key_expr in _jit_cache_stores(mod):
        for tup, scope in _sig_tuples(mod, key_expr):
            for i, elem in enumerate(tup.elts):
                if checker.bounded(elem, scope):
                    continue
                key = (tup.lineno, tup.col_offset, i)
                if key in seen:
                    continue
                seen.add(key)
                src = ast.unparse(elem)
                out.append(mod.finding(
                    "unbounded-signature", tup, "warn",
                    f"jit cache key element {i} (`{src}`) has an "
                    f"unbounded static value set — every new value "
                    f"compiles and caches a fresh variant",
                    "bucket the element (pow2 / bit_length), draw it "
                    "from a literal set, or document the runtime bound "
                    "in the baseline `why`"))
    return out
