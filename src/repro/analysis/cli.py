"""`python -m repro.analysis` — scan, compare against the baseline, gate.

Exit codes: 0 clean (every finding baselined), 1 new findings (or a
baseline problem), 2 usage error.  ``--strict-stale`` additionally fails
when the baseline carries entries that no longer match anything, so the
baseline shrinks as code is fixed.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .callgraph import lint_program
from .findings import Baseline, apply_baseline, findings_to_json
from .lint import RULES

__all__ = ["main"]

_DEFAULT_SCAN = ("src", "tests", "benchmarks", "examples")


def _repo_root(start: Path) -> Path:
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    return start


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="whole-program static analysis: hot-path hygiene, "
                    "dataflow contracts, determinism & numerics")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/directories to scan (default: "
                    + " ".join(_DEFAULT_SCAN) + " under the repo root)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="accepted-findings file (default: "
                    "<repo>/analysis_baseline.json when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding as new")
    ap.add_argument("--write-baseline", type=Path, metavar="PATH",
                    help="write the current scan as the baseline "
                    "(carries forward existing justifications) and exit 0")
    ap.add_argument("--json", type=Path, metavar="PATH",
                    help="write the machine-readable findings report")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--strict-stale", action="store_true",
                    help="fail when baseline entries match nothing")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-finding output; summary only")
    args = ap.parse_args(argv)

    root = _repo_root(Path.cwd())
    rules: Optional[List[str]] = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}; "
                  f"available: {', '.join(RULES)}", file=sys.stderr)
            return 2

    paths = list(args.paths)
    if not paths:
        paths = [root / p for p in _DEFAULT_SCAN if (root / p).exists()]
    missing = [p for p in paths if not Path(p).exists()]
    if missing:
        print(f"no such path: {', '.join(map(str, missing))}",
              file=sys.stderr)
        return 2

    findings = lint_program(paths, root, rules)

    baseline = Baseline()
    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline:
        cand = root / "analysis_baseline.json"
        baseline_path = cand if cand.exists() else None
    if baseline_path is not None and not args.no_baseline:
        try:
            baseline = Baseline.load(baseline_path)
        except (OSError, ValueError, KeyError) as e:
            print(f"bad baseline: {e}", file=sys.stderr)
            return 1

    if args.write_baseline:
        baseline.dump(args.write_baseline, findings=findings)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline} "
              f"— fill in every 'why' before committing")
        return 0

    fresh, stale = apply_baseline(findings, baseline)

    if args.json:
        args.json.write_text(
            findings_to_json(findings, fresh=fresh, stale=stale) + "\n")

    if not args.quiet:
        for f in fresh:
            print(f.format())
    accepted = len(findings) - len(fresh)
    print(f"repro.analysis: {len(findings)} finding(s) — "
          f"{accepted} baselined, {len(fresh)} new"
          + (f", {len(stale)} stale baseline entr"
             + ("y" if len(stale) == 1 else "ies") if stale else ""))
    if stale and (args.strict_stale or not args.quiet):
        for fp in stale:
            print(f"  stale baseline entry (fixed? remove it): {fp}")

    if fresh:
        print("new findings — fix them, or justify them in "
              "analysis_baseline.json with a 'why'", file=sys.stderr)
        return 1
    if stale and args.strict_stale:
        return 1
    return 0
