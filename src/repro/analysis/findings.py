"""Findings, fingerprints, and the accepted-findings baseline.

A *finding* is one (rule, file, line) hit with severity and a fix-it hint.
The CLI compares the current scan against a checked-in baseline
(``analysis_baseline.json``) and fails only on findings the baseline does
not cover — so legacy accepted findings don't block CI, while any *new*
finding (or a new instance of an accepted one) goes red.

Fingerprints are deliberately line-free: ``rule::path::scope`` where
*scope* is the enclosing ``Class.function`` qualname (or ``<module>``).
Unrelated edits that shift line numbers therefore do not invalidate the
baseline; what is matched is "rule R fires N times inside scope S of file
F".  The baseline stores a count per fingerprint plus a mandatory ``why``
justification (JSON has no comments, so the justification is schema).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Severity",
    "Finding",
    "Baseline",
    "apply_baseline",
    "findings_to_json",
]

#: Severity ladder.  ``error`` findings gate CI; ``warn`` findings gate CI
#: too (they are real hazards, just with plausible sanctioned uses that the
#: baseline records); ``note`` findings are informational context that
#: still must be baselined to keep the default scan clean.
Severity = str
SEVERITIES: Tuple[str, ...] = ("error", "warn", "note")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str           # rule id, e.g. "host-sync-in-jit"
    path: str           # repo-relative posix path
    line: int           # 1-based
    col: int            # 0-based (ast convention)
    severity: Severity
    message: str        # what is wrong, with the offending source element
    hint: str           # fix-it hint: what to do instead
    scope: str = "<module>"  # enclosing qualname, for the fingerprint

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.scope}"

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity}[{self.rule}] {self.message}\n"
                f"    hint: {self.hint}")


class Baseline:
    """Accepted findings: fingerprint → (allowed count, justification).

    Schema v2 (ISSUE 10) adds a required top-level ``scale_target``: the
    tuple count the justifications were audited against.  A ``why``
    explaining an accepted ``int32-overflow`` finding at 10⁸ tuples says
    nothing about 10¹⁰, so when :data:`repro.analysis.contracts.SCALE_TARGET`
    moves, every v2 baseline goes stale *loudly* (load error) instead of
    silently green-lighting un-reaudited counters.  v1 baselines (no
    ``scale_target``) still load, for migration; ``dump`` always writes v2.
    """

    VERSION = 2

    def __init__(self, entries: Optional[Dict[str, Tuple[int, str]]] = None,
                 scale_target: Optional[int] = None) -> None:
        self.entries: Dict[str, Tuple[int, str]] = dict(entries or {})
        #: tuple count the whys were audited against; None = legacy v1
        self.scale_target = scale_target

    # -- (de)serialisation -------------------------------------------------

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        data = json.loads(Path(path).read_text())
        version = data.get("version")
        if version not in (1, cls.VERSION):
            raise ValueError(
                f"{path}: unsupported baseline version {version!r}")
        scale_target: Optional[int] = None
        if version == cls.VERSION:
            from .contracts import SCALE_TARGET
            raw = data.get("scale_target")
            if not isinstance(raw, int):
                raise ValueError(
                    f"{path}: baseline v{cls.VERSION} requires an integer "
                    f"'scale_target' (the tuple count the justifications "
                    f"were audited against)")
            if raw != SCALE_TARGET:
                raise ValueError(
                    f"{path}: baseline was audited at scale_target={raw}, "
                    f"but contracts.SCALE_TARGET={SCALE_TARGET} — re-audit "
                    f"the accepted findings and regenerate "
                    f"(--write-baseline)")
            scale_target = raw
        entries: Dict[str, Tuple[int, str]] = {}
        for item in data.get("accepted", []):
            fp = item["fingerprint"]
            why = item.get("why", "").strip()
            if not why or why.startswith("TODO"):
                raise ValueError(
                    f"{path}: baseline entry {fp!r} has no 'why' "
                    f"justification — every accepted finding must say why")
            if fp in entries:
                raise ValueError(f"{path}: duplicate baseline entry {fp!r}")
            entries[fp] = (int(item.get("count", 1)), why)
        return cls(entries, scale_target=scale_target)

    def dump(self, path: Path, *, findings: Sequence[Finding] = ()) -> None:
        """Write the baseline (always at the current schema version, with
        the current ``contracts.SCALE_TARGET``).  When regenerating from a
        scan (``--write-baseline``), carry forward existing justifications
        and stub the new ones so a human must fill them in."""
        from .contracts import SCALE_TARGET

        by_fp: Dict[str, int] = {}
        for f in findings:
            by_fp[f.fingerprint] = by_fp.get(f.fingerprint, 0) + 1
        accepted = []
        for fp in sorted(by_fp):
            _, why = self.entries.get(fp, (0, ""))
            accepted.append({
                "fingerprint": fp,
                "count": by_fp[fp],
                "why": why or "TODO: justify or fix",
            })
        payload = {"version": self.VERSION, "scale_target": SCALE_TARGET,
                   "accepted": accepted}
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def apply_baseline(findings: Iterable[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[str]]:
    """Split a scan into (new findings, stale baseline fingerprints).

    The first ``count`` findings per accepted fingerprint are suppressed;
    any excess is new.  Baseline entries that no longer match anything are
    reported as stale so the baseline can shrink as code is fixed.
    """
    seen: Dict[str, int] = {}
    fresh: List[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        n = seen.get(f.fingerprint, 0) + 1
        seen[f.fingerprint] = n
        allowed, _ = baseline.entries.get(f.fingerprint, (0, ""))
        if n > allowed:
            fresh.append(f)
    stale = [fp for fp in sorted(baseline.entries) if fp not in seen]
    return fresh, stale


def findings_to_json(findings: Sequence[Finding], *,
                     fresh: Sequence[Finding], stale: Sequence[str]
                     ) -> str:
    """Machine-readable scan report (the CI artifact)."""
    fresh_set = {id(f) for f in fresh}
    return json.dumps({
        "version": Baseline.VERSION,
        "total": len(findings),
        "new": len(fresh),
        "stale_baseline": list(stale),
        "findings": [
            {**dataclasses.asdict(f),
             "fingerprint": f.fingerprint,
             "new": id(f) in fresh_set}
            for f in sorted(findings,
                            key=lambda f: (f.path, f.line, f.col, f.rule))
        ],
    }, indent=2)
