"""The differential sanitizer (ISSUE 10): diff mechanics, the strict
numerics context, and the acceptance claim itself — same-seed double runs
of the fused simulator and the serving engine are bit-identical.
"""
import math

import numpy as np
import pytest

from repro.analysis.sanitize import (diff_reports, diff_values, double_run,
                                     sanitized)
from repro.data.synthetic import zipf_time_evolving
from repro.topology import (Edge, ServingTopologyEngine, SimulatorEngine,
                            Source, Stage, Topology, config_for)


# -- diff_values mechanics ---------------------------------------------------

def test_diff_identical_nested():
    v = {"a": [1.0, 2, "x"], "b": {"c": (3.5, float("nan"))}}
    assert diff_values(v, dict(v)) == []


def test_diff_floats_bitwise():
    # == would pass 0.0 vs -0.0 and fail nan vs nan; bit compare does the
    # opposite, which is what report determinism means
    assert diff_values(0.0, -0.0) != []
    assert diff_values(float("nan"), float("nan")) == []
    assert diff_values(1.0, 1.0 + 1e-16) == []  # same double
    d = diff_values(1.0, 1.0 + 2 ** -52)
    assert len(d) == 1 and "bitwise" in d[0]


def test_diff_reports_key_and_length_mismatches():
    d = diff_values({"a": 1, "b": 2}, {"a": 1, "c": 3})
    assert sorted(d) == ["report.b: only in first run",
                        "report.c: only in second run"]
    assert diff_values([1, 2], [1, 2, 3]) == ["report: length 2 != 3"]
    assert diff_values({"x": [1, 9]}, {"x": [1, 8]}) \
        == ["report.x[1]: 9 != 8"]


def test_diff_arrays_exact():
    a = np.array([1.0, float("nan")])
    assert diff_values(a, a.copy()) == []
    assert diff_values(a, a.astype(np.float32)) \
        == ["report: dtype float64 != float32"]
    assert diff_values(np.arange(3), np.arange(4)) \
        == ["report: shape (3,) != (4,)"]
    d = diff_values(np.array([1, 2, 3]), np.array([1, 5, 3]))
    assert d == ["report: arrays differ at 1 element(s)"]


def test_diff_normalizes_numpy_scalars():
    assert diff_values(np.int64(3), 3) == []
    assert diff_values(np.float64(2.5), 2.5) == []
    assert diff_values(np.int64(3), 4) != []


def test_diff_type_mismatch():
    assert diff_values(1, 1.0) == ["report: type int != float"]


def test_diff_reports_uses_to_dict():
    class R:
        def __init__(self, x):
            self.x = x

        def to_dict(self):
            return {"x": self.x}

    assert diff_reports(R(1), R(1)) == []
    assert diff_reports(R(1), R(2)) == ["report.x: 1 != 2"]


# -- the sanitized() context -------------------------------------------------

def test_sanitized_raises_on_silent_numpy_faults_and_restores():
    before = np.geterr()
    with sanitized():
        with pytest.raises(FloatingPointError):
            np.float64(1.0) / np.float64(0.0)
    assert np.geterr() == before
    # outside the context the default behaviour is back (no raise)
    assert math.isinf(np.float64(1.0) / np.float64(0.0))


def test_sanitized_restores_on_exception():
    before = np.geterr()
    with pytest.raises(RuntimeError):
        with sanitized():
            raise RuntimeError("boom")
    assert np.geterr() == before


# -- the acceptance claim: double runs are bit-identical ---------------------

def _topo(name):
    return Topology(name=name,
                    stages=(Stage("worker", parallelism=8),),
                    edges=(Edge("source", "worker", config_for("pkg")),))


def _keys():
    return np.asarray(zipf_time_evolving(
        3_000, num_keys=500, z=1.2, flip_head=200, seed=7))


def test_double_run_fused_bit_identical():
    def fused():
        return SimulatorEngine(mode="fused", seed=3).run(
            _topo("t-fused"), Source(_keys(), arrival_rate=20_000.0))

    r1, r2, divergences = double_run(fused)
    assert divergences == []
    assert r1 is not r2  # two real runs, not one report compared to itself


def test_double_run_serving_bit_identical():
    def serving():
        return ServingTopologyEngine(max_requests=16).run(
            _topo("t-serving"), Source(_keys(), arrival_rate=20_000.0))

    _, _, divergences = double_run(serving)
    assert divergences == []


def test_double_run_surfaces_nondeterminism():
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        return {"latency_p99": 1.0 + state["n"] * 2 ** -52}

    _, _, divergences = double_run(flaky)
    assert len(divergences) == 1
    assert divergences[0].startswith("report.latency_p99:")
