"""End-to-end behaviour tests: training convergence, checkpoint-restart
fault tolerance, serving, and dry-run artifact integrity."""

import dataclasses
import glob
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, reduced_config
from repro.launch.train import TrainLoop
from repro.optim.adamw import AdamWConfig


def _loop(tmp, steps_total=60, arch="olmo-1b", routing=None, seed=0):
    cfg = reduced_config(get_config(arch))
    cfg = dataclasses.replace(cfg, num_layers=2, grad_accum=1)
    if routing and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, routing=routing))
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=steps_total)
    return TrainLoop(cfg, opt, batch=4, seq=64, ckpt_dir=tmp, seed=seed)


def test_training_reduces_loss(tmp_path):
    loop = _loop(str(tmp_path))
    hist = loop.run(40, ckpt_every=0, log_every=100)
    first = np.mean(hist[:5])
    last = np.mean(hist[-5:])
    assert last < first - 0.2, f"no learning: {first:.3f} -> {last:.3f}"
    assert np.isfinite(hist).all()


def test_checkpoint_restart_resumes_exactly(tmp_path):
    loop = _loop(str(tmp_path))
    loop.run(10, ckpt_every=10, log_every=100)
    w_saved = np.asarray(
        jax.tree_util.tree_leaves(loop.params)[0]).copy()
    step_saved = loop.step

    # "crash": build a fresh loop and restore
    loop2 = _loop(str(tmp_path))
    assert loop2.maybe_restore()
    assert loop2.step == step_saved
    w_restored = np.asarray(jax.tree_util.tree_leaves(loop2.params)[0])
    np.testing.assert_array_equal(w_saved, w_restored)
    # training continues
    hist = loop2.run(5, ckpt_every=0, log_every=100)
    assert len(hist) == 5 and np.isfinite(hist).all()


def test_grad_accum_matches_full_batch_direction(tmp_path):
    """2-microbatch accumulation ~ full-batch step (same data)."""
    from repro.launch import steps as S
    from repro.models import transformer as T
    from repro.optim.adamw import init_opt_state

    cfg = reduced_config(get_config("olmo-1b"))
    cfg1 = dataclasses.replace(cfg, num_layers=2, grad_accum=1, remat=False)
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg1, key)
    batch = {
        "tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
    }
    outs = {}
    for name, c in (("full", cfg1), ("accum", cfg2)):
        st = init_opt_state(params, opt_cfg)
        step = jax.jit(S.make_train_step(c, opt_cfg, None))
        new_p, _, _, metrics = step(params, st, None, batch)
        outs[name] = (jax.tree_util.tree_leaves(new_p)[0], metrics["loss"])
    np.testing.assert_allclose(float(outs["full"][1]),
                               float(outs["accum"][1]), rtol=1e-2)
    np.testing.assert_allclose(np.asarray(outs["full"][0], np.float32),
                               np.asarray(outs["accum"][0], np.float32),
                               atol=0.05)


def test_moe_fish_routing_trains(tmp_path):
    loop = _loop(str(tmp_path), arch="deepseek-v2-lite-16b", routing="fish")
    hist = loop.run(12, ckpt_every=0, log_every=100)
    assert np.isfinite(hist).all()
    assert float(jnp.sum(loop.hotness)) > 0  # hotness state evolved


# ---------------------------------------------------------------------------
# Dry-run artifact integrity (deliverable (e) — produced by launch/dryrun.py)
# ---------------------------------------------------------------------------

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")


def _artifacts(tag):
    return {
        (j["arch"], j["shape"]): j
        for p in glob.glob(os.path.join(ART, f"*_{tag}.json"))
        for j in [json.load(open(p))]
    }


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*_singlepod.json")),
                    reason="dry-run artifacts not generated yet")
@pytest.mark.parametrize("tag", ["singlepod", "multipod"])
def test_dryrun_grid_complete(tag):
    arts = _artifacts(tag)
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            assert (arch, sname) in arts, f"missing cell {arch}/{sname}/{tag}"
            r = arts[(arch, sname)]
            if not cfg.supports_shape(shape):
                assert r["status"] == "skipped"
            else:
                assert r["status"] == "ok", (arch, sname, r)
                assert r["devices"] == (512 if tag == "multipod" else 256)


@pytest.mark.skipif(not glob.glob(os.path.join(ART, "*_singlepod.json")),
                    reason="dry-run artifacts not generated yet")
def test_dryrun_roofline_terms_sane():
    arts = _artifacts("singlepod")
    for (arch, sname), r in arts.items():
        if r["status"] != "ok":
            continue
        rf = r.get("roofline")
        assert rf is not None, (arch, sname)
        assert rf["compute_s"] >= 0 and rf["collective_s"] >= 0
        if SHAPES[sname].kind == "train":
            assert r["flops_global"] > 1e12, (arch, sname)
            # HLO flops must be >= the pure model matmul flops
            from benchmarks.roofline import model_flops
            mf = model_flops(get_config(arch), SHAPES[sname])
            assert r["flops_global"] >= 0.5 * mf, (arch, sname, mf)
