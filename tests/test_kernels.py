"""Per-kernel Pallas validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import fish_count_ref, ssd_chunked_ref, ssd_ref


# ---------------------------------------------------------------------------
# fish_count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k_slots,n_keys,block_n", [
    (128, 512, 128),
    (256, 1000, 256),   # non-multiple n -> padding path
    (100, 3000, 1024),  # table needs lane padding
    (1024, 4096, 512),
])
def test_fish_count_shapes(k_slots, n_keys, block_n):
    rng = np.random.default_rng(k_slots + n_keys)
    n_real = k_slots * 3 // 4
    table = np.full(k_slots, -1, np.int32)
    table[:n_real] = rng.choice(10_000, n_real, replace=False)
    keys = rng.integers(0, 12_000, n_keys).astype(np.int32)
    c1, m1 = ops.fish_count(jnp.asarray(table), jnp.asarray(keys),
                            block_n=block_n)
    c2, m2 = fish_count_ref(jnp.asarray(table), jnp.asarray(keys))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@given(st.integers(1, 200), st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_fish_count_property(n_keys, n_table, seed):
    rng = np.random.default_rng(seed)
    table = rng.choice(500, n_table, replace=False).astype(np.int32)
    keys = rng.integers(0, 600, n_keys).astype(np.int32)
    counts, matched = ops.fish_count(jnp.asarray(table), jnp.asarray(keys))
    # total matched keys == total counts
    assert int(np.asarray(counts).sum()) == int(np.asarray(matched).sum())
    # every count equals the true occurrence count
    for i, t in enumerate(table):
        assert counts[i] == (keys == t).sum()


def test_fish_count_empty_table():
    table = jnp.full((128,), -1, jnp.int32)
    keys = jnp.arange(100, dtype=jnp.int32)
    counts, matched = ops.fish_count(table, keys)
    assert int(counts.sum()) == 0 and not bool(matched.any())


# ---------------------------------------------------------------------------
# SSD chunk kernels
# ---------------------------------------------------------------------------


def _ssd_inputs(b, s, h, p, g, n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, s, h, p)).astype(dtype)
    a = (-np.abs(rng.normal(size=(b, s, h))) * 0.1).astype(dtype)
    bb = (rng.normal(size=(b, s, g, n)) * 0.3).astype(dtype)
    cc = (rng.normal(size=(b, s, g, n)) * 0.3).astype(dtype)
    return map(jnp.asarray, (x, a, bb, cc))


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 32, 32),
    (2, 256, 4, 64, 1, 64, 64),
    (1, 128, 8, 64, 4, 32, 128),  # chunk == seq
])
def test_ssd_pallas_vs_sequential(b, s, h, p, g, n, chunk):
    x, a, bb, cc = _ssd_inputs(b, s, h, p, g, n, seed=s + h)
    y_ref, f_ref = ssd_ref(x, a, bb, cc)
    y_k, f_k = ops.ssd_scan(x, a, bb, cc, chunk=chunk, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_ref_impl_matches_sequential():
    x, a, bb, cc = _ssd_inputs(2, 128, 4, 32, 1, 32, seed=9)
    y_ref, f_ref = ssd_ref(x, a, bb, cc)
    y_c, f_c = ops.ssd_scan(x, a, bb, cc, chunk=32, impl="ref")
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f_c), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_initial_state_carries():
    """Chunked scan with an initial state == sequential with that state."""
    x, a, bb, cc = _ssd_inputs(1, 64, 2, 16, 1, 16, seed=3)
    s0 = jnp.asarray(np.random.default_rng(1).normal(
        size=(1, 2, 16, 16)).astype(np.float32)) * 0.5
    y_ref, f_ref = ssd_ref(x, a, bb, cc, initial_state=s0)
    y_c, f_c = ssd_chunked_ref(x, a, bb, cc, chunk=16, initial_state=s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f_c), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_bf16_inputs():
    x, a, bb, cc = _ssd_inputs(1, 64, 2, 16, 1, 16, seed=5)
    y32, _ = ops.ssd_scan(x, a, bb, cc, chunk=16, impl="pallas")
    y16, _ = ops.ssd_scan(x.astype(jnp.bfloat16), a, bb.astype(jnp.bfloat16),
                          cc.astype(jnp.bfloat16), chunk=16, impl="pallas")
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32),
                               rtol=5e-2, atol=5e-2)


@given(st.integers(1, 3), st.sampled_from([32, 64, 128]),
       st.sampled_from([16, 32, 64]), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_ssd_property_decay_bounded(b, s, chunk, seed):
    """Property: with zero decay rate (a=0) and b=c=const, SSD degenerates
    to a running sum — outputs must be monotone in t for positive x."""
    h, p, g, n = 2, 16, 1, 8
    chunk = min(chunk, s)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.abs(rng.normal(size=(b, s, h, p))).astype(np.float32))
    a = jnp.zeros((b, s, h), jnp.float32)
    ones = jnp.ones((b, s, g, n), jnp.float32) * 0.5
    y, _ = ops.ssd_scan(x, a, ones, ones, chunk=chunk, impl="pallas")
    y = np.asarray(y)
    assert (np.diff(y.sum(axis=(2, 3)), axis=1) >= -1e-3).all()
