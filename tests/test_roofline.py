"""Roofline formula tests: analytic MODEL_FLOPS vs exact parameter counts."""

import numpy as np
import pytest

import jax

from benchmarks.roofline import (hbm_traffic_bytes, matmul_params,
                                 model_flops)
from repro.configs import SHAPES, get_config, list_archs
from repro.models import transformer as T


@pytest.mark.parametrize("arch", list_archs())
def test_matmul_params_close_to_true_count(arch):
    """Analytic matmul-param count must track the real (eval_shape) count:
    within 5% after removing the embedding table (not a matmul)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    true_total = sum(int(np.prod(x.shape))
                     for x in jax.tree_util.tree_leaves(params))
    emb = int(np.prod(params["embed"].shape))
    # embedding lookup is not a matmul; tied archs reuse it as the head
    true_matmul = true_total - (0 if cfg.tie_embeddings else emb)
    counts = matmul_params(cfg)
    analytic = counts["total"]  # includes the encoder term for whisper
    ratio = analytic / true_matmul
    assert 0.93 < ratio < 1.07, f"{arch}: analytic/true = {ratio:.3f}"


def test_moe_active_well_below_total():
    counts = matmul_params(get_config("kimi-k2-1t-a32b"))
    assert counts["active"] < 0.08 * counts["total"]  # ~32B of ~1T
    assert counts["total"] > 0.9e12  # the 1T check


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "kimi-k2-1t-a32b"])
def test_train_flops_is_6nd(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    n_act = matmul_params(cfg)["active"]
    assert mf == pytest.approx(6.0 * n_act * shape.global_batch
                               * shape.seq_len)


def test_decode_flops_per_token():
    cfg = get_config("gemma2-2b")
    shape = SHAPES["decode_32k"]
    mf = model_flops(cfg, shape)
    n = matmul_params(cfg)["active"]
    assert mf == pytest.approx(2.0 * n * shape.global_batch)


def test_hbm_traffic_decode_dominated_by_cache():
    cfg = get_config("gemma2-2b")
    shape = SHAPES["decode_32k"]
    art = {"devices": 256, "param_bytes_global": 6e9,
           "memory_analysis": {"argument_size_in_bytes": int(1.6 * 2**30)}}
    b = hbm_traffic_bytes(cfg, shape, art)
    # cache r/w (2 x ~1.58 GiB) >> params/device (23 MB)
    assert b > 3e9


def test_whisper_encoder_flops_counted():
    cfg = get_config("whisper-large-v3")
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    dec_only = 6.0 * matmul_params(cfg)["active"] * 256 * 4096
    assert mf_train > dec_only  # encoder term present
