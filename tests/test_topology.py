"""Topology API (ISSUE 3): typed configs, multi-stage engines vs the
per-tuple reference oracle, deprecation shims, scoped events.

Equivalence contract (extends DESIGN.md §6 to multi-hop):

* SG / FG / PKG — the batched multi-stage engine matches the per-tuple
  reference interpreter *exactly* (same routing, hence identical per-edge
  metrics up to float noise), even through a fanout transform.
* DC / WC / FISH — bounded drift: sub-chunked frequencies shift individual
  assignments but every per-edge paper metric stays within tight bands.
"""

import warnings

import numpy as np
import pytest

from repro.core import MembershipEvent, make_grouper, simulate_stream
from repro.data.synthetic import zipf_time_evolving
from repro.topology import (SCHEME_CONFIGS, DChoicesConfig, Edge, FishConfig,
                            ScopedEvent, ServingTopologyEngine, ShuffleConfig,
                            SimulatorEngine, Source, Stage, Topology,
                            config_for, hashed_fanout, project_mod)

from repro.analysis.contracts import (DRIFT_SCHEMES, EXACT_SCHEMES,
                                      SCHEMES)


@pytest.fixture(scope="module")
def keys():
    return zipf_time_evolving(6_000, num_keys=600, z=1.4, seed=0)


def _word_count(spec, split_w=5, count_w=7, fanout=3, vocab=300):
    return Topology(
        name="wc",
        stages=(Stage("split", split_w,
                      transform=hashed_fanout(fanout, vocab)),
                Stage("count", count_w)),
        edges=(Edge("source", "split", ShuffleConfig()),
               Edge("split", "count", spec)),
    )


# ---------------------------------------------------------------------------
# typed configs round-trip + validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
def test_config_build_matches_legacy_make_grouper(scheme):
    cfg = config_for(scheme)
    g_new = cfg.build(8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        g_old = make_grouper(scheme, 8)
    assert type(g_new) is type(g_old)
    assert cfg.scheme == scheme == g_new.name
    for k in range(200):
        assert g_new.probe_route(k) == g_old.probe_route(k), k


def test_config_validation_rejects_bad_fields():
    with pytest.raises(ValueError):
        FishConfig(alpha=1.5)
    with pytest.raises(ValueError):
        FishConfig(epoch=0)
    with pytest.raises(ValueError):
        FishConfig(theta_frac=-0.25)
    with pytest.raises(ValueError):
        DChoicesConfig(k_max=0)
    with pytest.raises(ValueError):
        config_for("nope")
    with pytest.raises(ValueError):
        ShuffleConfig().build(0)
    # paper Fig. 13 sweeps theta up to 2/n — must be representable
    assert FishConfig(theta_frac=2.0).to_params().theta(8) == 0.25


def test_configs_are_reusable_values():
    cfg = FishConfig(epoch=100)
    g1, g2 = cfg.build(4), cfg.build(4)
    assert g1 is not g2
    g1.assign_batch(np.arange(50, dtype=np.int64))
    assert g2.memory_overhead() == 0  # builds never share state
    assert cfg == FishConfig(epoch=100)  # frozen value semantics
    assert hash(cfg) == hash(FishConfig(epoch=100))


def test_deprecation_shims_warn():
    with pytest.warns(DeprecationWarning, match="make_grouper"):
        g = make_grouper("pkg", 4)
    with pytest.warns(DeprecationWarning, match="simulate_stream"):
        m = simulate_stream(g, np.arange(100, dtype=np.int64) % 7,
                            arrival_rate=1e3)
    assert m.execution_time > 0


# ---------------------------------------------------------------------------
# topology validation
# ---------------------------------------------------------------------------


def test_topology_validation():
    s = Stage("a", 2)
    with pytest.raises(ValueError):  # unknown dst
        Topology("t", stages=(s,), edges=(
            Edge("source", "b", ShuffleConfig()),))
    with pytest.raises(ValueError):  # unreachable stage
        Topology("t", stages=(s, Stage("b", 2)), edges=(
            Edge("source", "a", ShuffleConfig()),))
    with pytest.raises(ValueError):  # fan-in onto one pool
        Topology("t", stages=(s, Stage("b", 2)), edges=(
            Edge("source", "a", ShuffleConfig()),
            Edge("source", "b", ShuffleConfig()),
            Edge("a", "b", ShuffleConfig())))
    with pytest.raises(TypeError):  # stringly-typed grouping rejected
        Edge("source", "a", "fish")
    with pytest.raises(ValueError):  # reserved name
        Stage("source", 2)
    # a valid 3-stage chain orders edges source-out first
    topo = Topology("t3", stages=(
        Stage("a", 2, transform=project_mod(10)), Stage("b", 2),
        Stage("c", 2)), edges=(
        Edge("b", "c", ShuffleConfig()),
        Edge("a", "b", ShuffleConfig()),
        Edge("source", "a", ShuffleConfig())))
    assert [e.name for e in topo.ordered_edges()] == [
        "source->a", "a->b", "b->c"]
    assert topo.sinks() == ["c"]
    assert topo.fanout_to("a") == 1 and topo.fanout_to("b") == 1


def test_transforms_are_deterministic_and_shaped():
    t = hashed_fanout(4, 100)
    keys = np.array([3, 3, 17], dtype=np.int64)
    out1, out2 = t(keys), t(keys)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (12,)
    # same key always emits the same word set — hot key ⇒ hot words
    np.testing.assert_array_equal(out1[:4], out1[4:8])
    assert (out1 >= 0).all() and (out1 < 100).all()
    p = project_mod(8)
    np.testing.assert_array_equal(p(np.array([7, 8, 9])), [7, 0, 1])


# ---------------------------------------------------------------------------
# multi-stage engine vs the per-tuple reference oracle
# ---------------------------------------------------------------------------


def _reports(scheme, keys, **topo_kw):
    topo = _word_count(config_for(scheme), **topo_kw)
    src = Source(keys, arrival_rate=2e4)
    rb = SimulatorEngine(mode="batched").run(topo, src)
    rr = SimulatorEngine(mode="reference").run(topo, src)
    return rb, rr


@pytest.mark.parametrize("scheme", EXACT_SCHEMES)
def test_multistage_exact_vs_oracle(scheme, keys):
    rb, rr = _reports(scheme, keys)
    for eb, er in zip(rb.edges, rr.edges):
        assert eb.edge == er.edge
        assert eb.memory_overhead == er.memory_overhead, eb.edge
        for field, v_ref in er.row().items():
            assert eb.row()[field] == pytest.approx(v_ref, rel=1e-9), \
                (eb.edge, field)
    assert rb.e2e_latency_p99 == pytest.approx(rr.e2e_latency_p99, rel=1e-9)
    assert rb.total_time == pytest.approx(rr.total_time, rel=1e-9)


@pytest.mark.parametrize("scheme", DRIFT_SCHEMES)
def test_multistage_drift_bounded_vs_oracle(scheme, keys):
    rb, rr = _reports(scheme, keys)
    for eb, er in zip(rb.edges, rr.edges):
        assert eb.execution_time == pytest.approx(er.execution_time,
                                                  rel=0.05), eb.edge
        assert eb.throughput == pytest.approx(er.throughput, rel=0.05)
        assert eb.memory_overhead == pytest.approx(er.memory_overhead,
                                                   rel=0.25)
        # load balance must not degrade materially vs the oracle
        assert eb.imbalance <= er.imbalance + 0.05, eb.edge
        # queueing latency stays the same order of magnitude
        assert eb.latency_p99 <= max(er.latency_p99 * 10.0, 0.05)
    assert rb.total_time == pytest.approx(rr.total_time, rel=0.05)


def test_downstream_arrivals_are_upstream_finishes(keys):
    """Chaining sanity: the counting edge cannot start before the split
    finishes — e2e p99 is at least each edge's own p99."""
    rb, _ = _reports("sg", keys)
    assert rb.e2e_latency_p99 >= max(e.latency_p99 for e in rb.edges)
    n_split = rb.edge("split").n_tuples
    assert rb.edge("count").n_tuples == n_split * 3  # fanout


# ---------------------------------------------------------------------------
# one engine protocol: the same Topology through both engines
# ---------------------------------------------------------------------------


def test_wordcount_same_topology_both_engines(keys):
    topo = _word_count(FishConfig())
    src = Source(keys, arrival_rate=2e4)
    r_sim = SimulatorEngine().run(topo, src)
    r_srv = ServingTopologyEngine(max_requests=64).run(topo, src)
    for rep in (r_sim, r_srv):
        assert [e.edge for e in rep.edges] == ["source->split",
                                               "split->count"]
        assert [e.scheme for e in rep.edges] == ["sg", "fish"]
        assert rep.edge("count").latency_p99 > 0
        assert rep.edge("count").memory_overhead > 0
        assert rep.e2e_latency_p99 > 0
    assert r_sim.engine == "dspe-batched"
    assert r_srv.engine == "serving"
    # serving subsampled the source but dropped nothing
    assert r_srv.n_source_tuples == 64
    assert sum(e.dropped for e in r_srv.edges) == 0
    assert r_srv.edge("count").n_tuples == 64 * 3


# ---------------------------------------------------------------------------
# scoped events: per-stage membership churn with remap accounting
# ---------------------------------------------------------------------------


def test_scoped_membership_event_remaps_one_edge(keys):
    topo = _word_count(config_for("fg"))
    n_count = keys.shape[0] * 3
    events = [ScopedEvent("count",
                          MembershipEvent(at=n_count // 2,
                                          workers=tuple(range(6))))]
    rep = SimulatorEngine().run(topo, Source(keys, arrival_rate=2e4),
                                events)
    er = rep.edge("count")
    assert len(er.remap_events) == 1
    # consistent hashing: removing 1 of 7 workers moves a bounded slice
    assert er.remap_frac_mean is not None
    assert 0.0 < er.remap_frac_mean < 0.5
    # the split edge saw no event
    assert rep.edge("split").remap_events == []
    # SG has no key affinity: remap fraction is None
    rep_sg = SimulatorEngine().run(
        _word_count(config_for("sg")), Source(keys, arrival_rate=2e4),
        events)
    assert rep_sg.edge("count").remap_frac_mean is None
    assert rep_sg.edge("count").remap_events[0]["moved"] is None


def test_serving_engine_scoped_events(keys):
    topo = _word_count(config_for("fg"), fanout=2)
    n_count = 48 * 2
    events = [
        # worker 6 fails mid-stream…
        ScopedEvent("count", MembershipEvent(at=n_count // 3,
                                             workers=tuple(range(6)))),
        # …then the pool scales out with a fresh id (ids are never reused)
        ScopedEvent("count", MembershipEvent(at=2 * n_count // 3,
                                             workers=tuple(range(6)) + (7,))),
    ]
    eng = ServingTopologyEngine(max_requests=48)
    rep = eng.run(topo, Source(keys, arrival_rate=2e4), events)
    er = rep.edge("count")
    assert sum(e.dropped for e in rep.edges) == 0
    assert len(er.remap_events) == 2
    assert er.remap_frac_mean is not None and er.remap_frac_mean < 0.6


def test_report_roundtrips_to_dict(keys):
    rep = SimulatorEngine().run(_word_count(config_for("pkg")),
                                Source(keys, arrival_rate=2e4))
    d = rep.to_dict()
    assert d["engine"] == "dspe-batched"
    assert len(d["edges"]) == 2
    for e in d["edges"]:
        for f in ("latency_p50", "latency_p99", "memory_overhead",
                  "imbalance", "scheme", "workers"):
            assert f in e
    with pytest.raises(KeyError):
        rep.edge("nope")
