"""Scenario subsystem + CapacityEvent behaviour (ISSUE 2 tentpole)."""

import numpy as np
import pytest

from repro.core import CapacityEvent, MembershipEvent, simulate_edge
from repro.topology import build_grouper
from repro.data.synthetic import zipf_time_evolving
from repro.scenarios import (CapacitySpec, ChurnOp, Scenario, StragglerSpec,
                             WorkloadSpec, base_capacities, build_keys,
                             compile_events, default_scenarios,
                             run_dspe_scenario, run_serving_scenario)

SCHEMES = ("sg", "fg", "pkg", "dc", "wc", "fish")


def _sim_batched(g, keys, **kw):
    return simulate_edge(g, keys, mode="batched", **kw).metrics


def _sim_reference(g, keys, **kw):
    return simulate_edge(g, keys, mode="reference", **kw).metrics


# ---------------------------------------------------------------------------
# CapacityEvent plumbing
# ---------------------------------------------------------------------------


def test_capacity_event_straggler_slows_then_recovery_bounds():
    keys = zipf_time_evolving(10_000, num_keys=1_000, z=1.2, seed=2)
    w = 4
    caps = np.full(w, 0.9 * w / 2e4)
    base = _sim_batched(build_grouper("sg", w), keys, capacities=caps,
                          arrival_rate=2e4)
    onset = [CapacityEvent(at=3_000, capacities={1: float(caps[1]) * 6})]
    slow = _sim_batched(build_grouper("sg", w), keys, capacities=caps,
                          arrival_rate=2e4, events=onset)
    both = onset + [CapacityEvent(at=6_000, capacities={1: float(caps[1])})]
    rec = _sim_batched(build_grouper("sg", w), keys, capacities=caps,
                          arrival_rate=2e4, events=both)
    assert slow.latency_p99 > base.latency_p99 * 2
    assert rec.execution_time < slow.execution_time


def test_capacity_event_exact_between_engines():
    keys = zipf_time_evolving(8_000, num_keys=800, z=1.2, seed=3)
    ev = [CapacityEvent(at=2_000, capacities={0: 9e-4, 2: 1e-4}),
          MembershipEvent(at=5_000, workers=(0, 1, 2)),
          CapacityEvent(at=6_000, capacities={0: 3e-4})]
    m_ref = _sim_reference(build_grouper("fg", 4), keys,
                                      arrival_rate=2e4, events=ev)
    m_bat = _sim_batched(build_grouper("fg", 4), keys,
                            arrival_rate=2e4, events=ev)
    for field, v_ref in m_ref.row().items():
        assert m_bat.row()[field] == pytest.approx(v_ref, rel=1e-9), field


# ---------------------------------------------------------------------------
# scenario compilation
# ---------------------------------------------------------------------------


def test_compile_events_lowering():
    sc = Scenario(
        "t", workers=4,
        workload=WorkloadSpec("piecewise", 1_000, 100),
        capacity=CapacitySpec(hetero=(2.0, 1.0),
                              straggler=StragglerSpec(worker=1, onset=0.5,
                                                      recovery=0.8,
                                                      slowdown=4.0)),
        churn=(ChurnOp(0.25, "remove", 3), ChurnOp(0.75, "add", 4)),
    )
    events = compile_events(sc, 1_000)
    mem = [e for e in events if isinstance(e, MembershipEvent)]
    cap = [e for e in events if isinstance(e, CapacityEvent)]
    assert [e.at for e in mem] == [250, 750]
    assert list(mem[0].workers) == [0, 1, 2]
    assert list(mem[1].workers) == [0, 1, 2, 4]
    # straggler onset/recovery + newcomer capacity definition
    assert {e.at for e in cap} == {500, 800, 750}
    caps0 = base_capacities(sc)
    onset = next(e for e in cap if e.at == 500)
    assert onset.capacities[1] == pytest.approx(caps0[1] * 4.0)
    # heterogeneous speeds: worker 0 twice as fast as worker 1
    assert caps0[1] == pytest.approx(2.0 * caps0[0])


def test_out_of_range_events_do_not_stall_cursor():
    keys = zipf_time_evolving(2_000, num_keys=200, z=1.2, seed=5)
    ev = [MembershipEvent(at=-1, workers=(0, 1, 2, 3)),   # before the stream
          MembershipEvent(at=500, workers=(0, 1)),        # must still fire
          MembershipEvent(at=5_000, workers=(0,))]        # past the end
    for sim in (_sim_batched, _sim_reference):
        g = build_grouper("fg", 4)
        sim(g, keys, arrival_rate=2e4, events=ev)
        assert g.active_workers == [0, 1]


def test_piecewise_zipf_remainder_stays_in_last_phase():
    from repro.data.synthetic import piecewise_zipf
    out = piecewise_zipf(5_000, 600, phases=6, seed=0)  # 6 ∤ 5000
    assert out.shape == (5_000,) and out.dtype == np.int32
    # the remainder extends the final phase instead of opening a 7th hot
    # set: the last 2 tuples draw from the same hot set as the tuples
    # right before them (same top key within the final 833+remainder span)
    per = 5_000 // 6
    last_phase = out[5 * per:]
    assert last_phase.shape[0] == 5_000 - 5 * per


def test_workload_kinds_and_validation():
    assert build_keys(WorkloadSpec("zf_flip", 500, 50)).shape == (500,)
    assert build_keys(WorkloadSpec("piecewise", 500, 50)).shape == (500,)
    with pytest.raises(ValueError):
        build_keys(WorkloadSpec("nope", 10, 5))
    with pytest.raises(ValueError):
        compile_events(Scenario("t", churn=(ChurnOp(0.1, "explode", 0),)), 100)


# ---------------------------------------------------------------------------
# DSPE scenario runs: every scheme through every default scenario
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
def test_dspe_default_suite_all_schemes(scheme):
    for sc in default_scenarios(num_tuples=3_000, num_keys=300, workers=6):
        out = run_dspe_scenario(sc, scheme)
        assert out["throughput"] > 0, sc.name
        assert out["memory_overhead"] > 0, sc.name
        has_membership = bool(sc.churn)
        if has_membership:
            assert out["remap_events"], sc.name
            if scheme == "sg":
                assert out["remap_frac_mean"] is None
            else:
                # consistent hashing: single-host churn remaps a ~1/W slice
                assert out["remap_frac_mean"] < 0.5, (sc.name, out)


def test_reference_engine_scenario_smoke():
    sc = default_scenarios(num_tuples=1_500, num_keys=200, workers=4)[3]
    out = run_dspe_scenario(sc, "pkg", engine="reference")
    assert out["engine"] == "reference"
    assert out["throughput"] > 0


# ---------------------------------------------------------------------------
# serving scenario runs: control plane in the loop
# ---------------------------------------------------------------------------


def test_serving_failure_scenario_elastic_continue():
    sc = next(s for s in default_scenarios(3_000, 300, 6)
              if s.name == "failure_elastic")
    out = run_serving_scenario(sc, "fish", num_requests=60)
    assert out["completed"] == out["submitted"] == 60
    # heartbeat monitor detected the silent replica; policy chose rescale
    assert "rescaled" in out["policy_outcomes"]
    assert out["remap_fracs"] and max(out["remap_fracs"]) < 0.6


def test_serving_straggler_scenario_detected():
    sc = next(s for s in default_scenarios(3_000, 300, 6)
              if s.name == "straggler_recovery")
    out = run_serving_scenario(sc, "sg", num_requests=60)
    assert out["completed"] == 60
    assert out["straggler_detected"]


@pytest.mark.parametrize("scheme", SCHEMES)
def test_serving_churn_storm_all_schemes(scheme):
    sc = next(s for s in default_scenarios(2_400, 240, 6)
              if s.name == "churn_storm")
    out = run_serving_scenario(sc, scheme, num_requests=48)
    assert out["completed"] == 48, out
