"""Stream-simulator + grouping-scheme behaviour tests (paper §2.3 / §6)."""

import numpy as np
import pytest

from repro.core import (FishGrouper, FishParams, MembershipEvent,
                        simulate_edge)
from repro.topology import build_grouper
from repro.data.synthetic import zipf_time_evolving


def _sim_batched(g, keys, **kw):
    return simulate_edge(g, keys, mode="batched", **kw).metrics


def _sim_reference(g, keys, **kw):
    return simulate_edge(g, keys, mode="reference", **kw).metrics


@pytest.fixture(scope="module")
def skewed_keys():
    return zipf_time_evolving(30_000, num_keys=3_000, z=1.4, seed=0)


def _run(name, keys, workers=16, **kw):
    g = build_grouper(name, workers)
    caps = np.full(workers, 0.9 * workers / 20_000.0)
    return g, _sim_batched(g, keys, capacities=caps, arrival_rate=20_000.0,
                              **kw)


def test_sg_balances_but_replicates(skewed_keys):
    g, m = _run("sg", skewed_keys)
    assert m.imbalance < 0.01
    assert m.memory_overhead_norm > 2.0   # heavy state replication


def test_fg_minimal_memory_but_imbalanced(skewed_keys):
    g, m = _run("fg", skewed_keys)
    assert m.memory_overhead_norm == pytest.approx(1.0)
    assert m.imbalance > 0.5


def test_pkg_bounded_two_workers(skewed_keys):
    g, _ = _run("pkg", skewed_keys)
    assert max(len(ws) for ws in g.replicas.values()) <= 2


def test_fish_near_sg_latency_near_fg_memory(skewed_keys):
    """The paper's headline: FISH ≈ SG load balance at ≈ FG memory."""
    _, m_sg = _run("sg", skewed_keys)
    _, m_fg = _run("fg", skewed_keys)
    _, m_fish = _run("fish", skewed_keys)
    # execution time within 1.35x of SG (paper: worst case 1.32x)
    assert m_fish.execution_time <= 1.35 * m_sg.execution_time
    # memory within a small multiple of FG, far below SG
    assert m_fish.memory_overhead_norm <= 3.0
    assert m_fish.memory_overhead_norm < 0.5 * m_sg.memory_overhead_norm


def test_fish_beats_wc_on_time_evolving(skewed_keys):
    _, m_wc = _run("wc", skewed_keys)
    _, m_fish = _run("fish", skewed_keys)
    assert m_fish.latency_p99 <= m_wc.latency_p99 * 1.05


def test_fish_handles_heterogeneous_workers():
    keys = zipf_time_evolving(20_000, num_keys=2_000, z=1.2, seed=3)
    w = 8
    caps = np.concatenate([np.full(4, 2.0), np.full(4, 1.0)]) * 0.9 * w / 2e4
    g_fish = build_grouper("fish", w)
    m_fish = _sim_batched(g_fish, keys, capacities=caps,
                             arrival_rate=2e4)
    g_sg = build_grouper("sg", w)
    m_sg = _sim_batched(g_sg, keys, capacities=caps, arrival_rate=2e4)
    # SG ignores capacity; FISH's Eq. 2 should not be slower (hwa, Fig. 16)
    assert m_fish.execution_time <= m_sg.execution_time * 1.10


def test_membership_event_rescale():
    keys = zipf_time_evolving(12_000, num_keys=1_000, z=1.2, seed=5)
    g = FishGrouper(8)
    m = _sim_batched(
        g, keys, arrival_rate=2e4,
        events=[MembershipEvent(at=6_000, workers=list(range(7)))],
    )
    assert m.execution_time > 0
    # no tuples assigned to the removed worker after the event
    assert 7 not in set(g.ring.workers)


def test_fish_without_ch_remaps_more():
    """RQ4 (Fig. 17): consistent hashing bounds remapping on rescale."""
    keys = zipf_time_evolving(16_000, num_keys=1_500, z=1.1, seed=6)
    ev = [MembershipEvent(at=8_000, workers=list(range(9)))]

    g_ch = FishGrouper(8, use_consistent_hash=True)
    m_ch = _sim_batched(g_ch, keys, arrival_rate=2e4, events=ev)
    g_no = FishGrouper(8, use_consistent_hash=False)
    m_no = _sim_batched(g_no, keys, arrival_rate=2e4, events=ev)
    assert m_ch.memory_overhead <= m_no.memory_overhead * 1.05
