"""interproc-unordered-iteration fixture: set-returning callees."""


def active_workers(assignments):
    return {w for ws in assignments for w in ws}


def candidate_workers(assignments):
    return active_workers(assignments)


def rebalance(assignments, ring):
    for w in active_workers(assignments):
        ring.append(w)
    moves = [w for w in candidate_workers(assignments)]
    return moves
