"""deprecated-shim fixture: legacy entry points."""

from repro.core import make_grouper, simulate_stream


def legacy_run(keys):
    g = make_grouper("pkg", 4)               # L7: deprecated shim
    return simulate_stream(g, keys)          # L8: deprecated shim


def modern_run(keys):
    from repro.topology import build_grouper, config_for

    g = build_grouper(config_for("pkg"), 4)  # replacement path: not flagged
    return g
