"""exactness-contract fixture: locally redefined contract partitions."""

EXACT_SCHEMES = ("sg", "fg", "pkg")   # L3: shadows the contracts table
DRIFT_SCHEMES = ("dc", "wc")          # L4: wrong, and shadows the table
EXACTNESS = {("sg", "fused"): "exact"}  # L5: shadows the table

SCHEMES = ("sg", "fish")  # intentional subset (benchmarks do this): ok
