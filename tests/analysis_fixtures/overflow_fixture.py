"""int32-overflow fixture: narrow accumulators that scale with the stream."""
import jax.numpy as jnp
import numpy as np


def bill_bytes(batches):
    total_bytes = np.int32(0)
    for b in batches:
        total_bytes += np.int32(b.size * 12)
    return total_bytes


def scatter_counts(idx):
    tuple_counts = np.zeros(8, np.int32)
    np.add.at(tuple_counts, idx, 1)
    return tuple_counts


def device_accumulate(idx, moved):
    acc_table = jnp.zeros(8, jnp.int32)
    acc_table = acc_table.at[idx].add(moved)
    return acc_table


class Counters:
    def __init__(self, n):
        self.tuple_count = np.zeros(n, np.int32)

    def feed(self, idx, moved):
        self.tuple_count[idx] += moved
        self.tuple_count = self.tuple_count + moved
