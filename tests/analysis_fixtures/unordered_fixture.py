"""unordered-iteration fixture: set order leaking into effects."""


def rebalance(workers, ring):
    live = set(workers)
    dead = {0, 1}
    for w in live - dead:          # L7: set difference drives ring mutation
        ring.add(w)
    order = [w for w in live]      # L9: list built in set order
    for w in {"a", "b"} | live:    # L10: union iterated directly
        ring.remove(w)
    return order


def fine(workers, ring):
    live = set(workers)
    for w in sorted(live):         # sorted: not flagged
        ring.add(w)
    total = sum(w for w in live)   # order-neutral sink: not flagged
    return {w for w in live}, total  # set comprehension: not flagged
