"""unbounded-signature fixture: jit cache keyed by open-ended values."""
import jax

_CACHE = {}


def _bucket(n):
    return max(64, 1 << int(n - 1).bit_length())


def get_fn(keys, scheme):
    sig = (scheme, _bucket(keys.shape[0]), keys.shape[0])
    if sig not in _CACHE:
        def seg(x):
            return x
        _CACHE[sig] = jax.jit(seg)
    return _CACHE[sig]
