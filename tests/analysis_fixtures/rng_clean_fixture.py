"""unseeded-rng near-miss: explicitly seeded, explicitly threaded."""
import random

import numpy as np
from numpy.random import PCG64, default_rng


def draw(n, seed):
    g = default_rng(seed)
    h = np.random.default_rng(123)
    p = np.random.Generator(PCG64(seed))
    r = random.Random(seed)
    return g.normal(size=n), h, p, r.random()
