"""registry-counter-mutation fixture (ISSUE 9): direct stores to
registry-backed counters, plus the shapes the rule must NOT flag."""
from repro.kernels import feed_fused


class FakeServingEngine:
    def submit(self):
        self.shed = 0                 # error: bypasses the registry cell
        self.queue_depth_peak += 1    # error
        self.in_flight_peak = 3       # error

    def ok(self):
        self._m_shed.add(1)           # fine: mutation through the cell


class FusedEdgeRunner:
    def begin_feed(self):
        self.dispatches = 0           # error: `dispatches` is a property


class Report:
    def stamp(self):
        self.shed = 3                 # fine: a plain data field, no registry


feed_fused.TRACE_COUNT += 1           # error: external module-counter write
feed_fused.dispatches = 2             # error
report = Report()
report.shed = 1                       # fine: base is a local, not a module
