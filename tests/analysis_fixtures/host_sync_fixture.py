"""host-sync-in-jit fixture: forced host syncs inside jit-traced code."""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_host_sync(x):
    s = jnp.sum(x)
    a = float(s)               # L11: float() on a traced value
    b = s.item()               # L12: .item() is a device->host sync
    c = np.asarray(s)          # L13: np.asarray pulls to host
    flag = bool(s > 0)         # L14: bool() concretizes the tracer
    return a + b + float(c) + flag  # L15: float() again (non-constant)


def _traced_helper(x):
    # reached from the jit root below: still traced code
    return x.tolist()          # L20: .tolist() in traced closure


@jax.jit
def bad_via_helper(x):
    return _traced_helper(x)
