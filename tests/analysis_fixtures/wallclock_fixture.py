"""wall-clock-leak fixture: stamps escaping; module-level read."""
import time
from datetime import datetime

IMPORT_STAMP = time.time()


def stamp_report():
    t0 = time.perf_counter()
    return t0


class Report:
    def record(self):
        self.started_at = datetime.now()
