"""unseeded-rng fixture: global-state and entropy-seeded RNG."""
import random

import numpy as np
import numpy.random as npr
from numpy.random import default_rng


def draw(n):
    a = np.random.rand(n)
    b = npr.randint(0, 10, n)
    np.random.seed(0)
    g = default_rng()
    h = np.random.default_rng()
    r = random.random()
    s = random.SystemRandom()
    return a, b, g, h, r, s
