"""frozen-mutation fixture: writes through the frozen contract."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class Frozen:
    field: int = 0

    def __post_init__(self):
        object.__setattr__(self, "field", abs(self.field))  # L11: note

    def poke(self):
        object.__setattr__(self, "field", 3)  # L14: error outside post-init


def clobber(batch, arr):
    batch.keys = arr        # L18: rebinding a RecordBatch column
    batch.values[0] = 7.0   # L19: writing into a frozen column
    batch.timestamps += 1.0  # L20: aug-assign rebind of a column


def fine(self_like):
    self_like.other = 1  # not a column name: not flagged
