"""topology-config fixture: literal constructs the runtime would reject."""

from repro.topology import Edge, Stage, Topology, config_for

BAD_SCHEME = config_for("nope")              # L5: unknown scheme
BAD_ALPHA = config_for("fish", alpha=1.5)    # L6: alpha out of [0, 1]
BAD_STAGE = Stage("source", 4)               # L7: reserved stage name
BAD_PAR = Stage("work", 0)                   # L8: parallelism < 1
BAD_EDGE = Edge("a", "a", config_for("sg"))  # L9: self-edge
BAD_GROUPING = Edge("source", "a", "pkg")    # L10: stringly grouping

BAD_TOPO = Topology(                         # L12: duplicate stage names
    name="dup",
    stages=(Stage("a", 2), Stage("a", 2)),
    edges=(Edge("source", "a", config_for("sg")),),
)

OK_CONFIG = config_for("fish", alpha=0.5)    # valid literal: not flagged
OK_TOPO = Topology(
    name="ok",
    stages=(Stage("a", 2),),
    edges=(Edge("source", "a", config_for("sg")),),
)
