"""retrace-hazard fixture: signatures that recompile per call."""

import functools

import jax


@functools.partial(jax.jit, static_argnames=("scale",))  # L8: float static
def scaled(x, scale: float):
    return x * scale


@functools.partial(jax.jit, static_argnames=("shape",))  # L13: unhashable
def reshaped(x, shape: list):
    return x.reshape(shape)


def storm(xs):
    out = []
    for x in xs:
        out.append(jax.jit(lambda v: v * 2)(x))  # L21: jit rebuilt per call
    return out


def fine(xs):
    # assigned once and reused: not flagged
    f = jax.jit(lambda v: v * 2)
    return [f(x) for x in xs]
