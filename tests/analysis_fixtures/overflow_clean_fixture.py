"""int32-overflow near-miss: wide accumulators and non-accumulated ids."""
import numpy as np


def bill_bytes(batches):
    total_bytes = np.int64(0)
    for b in batches:
        total_bytes += np.int64(b.size * 12)
    return total_bytes


def worker_ids(n):
    ids = np.arange(n, dtype=np.int32)
    return ids[::-1]


def bounded_retries(attempts):
    retries = np.int32(0)
    for a in attempts:
        if not a:
            retries += np.int32(1)
    return retries
