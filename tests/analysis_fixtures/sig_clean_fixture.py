"""unbounded-signature near-miss: every cache-key element bounded."""
import jax

_CACHE = {}
_MIN_BUCKET = 64


def _pow2(n):
    return max(_MIN_BUCKET, 1 << int(n - 1).bit_length())


def get_fn(n, has_pane, fifo):
    sig = (_pow2(n), bool(has_pane), "assoc" if fifo else "scan")
    if sig not in _CACHE:
        def seg(x):
            return x
        _CACHE[sig] = jax.jit(seg)
    return _CACHE[sig]
