"""wall-clock-leak near-miss: local elapsed-time that never escapes."""
import time


def timed(fn):
    t0 = time.perf_counter()
    fn()
    elapsed = time.perf_counter() - t0
    print(f"took {elapsed:.3f}s")
    return 42
