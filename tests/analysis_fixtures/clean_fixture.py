"""Clean fixture: near-miss patterns that must produce zero findings."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

BIG = jnp.int32(1 << 30)  # device constant: fine to close over in a trace
HOST_ONLY = np.int32(7)   # host constant never referenced from traced code


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def padded_sum(x, n: int, interpret: bool = False):
    # int/bool statics are hashable and bounded: fine
    return jnp.sum(x[:n]) + BIG


def host_side(x):
    # host code may sync and use numpy freely
    arr = np.asarray(x)
    return float(arr.sum()) + int(HOST_ONLY)


_JIT_CACHE = {}


def cached_jit(n):
    # signature-keyed cache: the sanctioned inner-jit pattern
    if n not in _JIT_CACHE:
        _JIT_CACHE[n] = jax.jit(lambda v: v[:n].sum())
    return _JIT_CACHE[n]


def ordered_rebalance(workers, ring):
    for w in sorted(set(workers)):
        ring.add(w)
    return {w for w in set(workers)}
