"""np-jnp-mixing fixture: host numpy inside device-traced code."""

import jax
import jax.numpy as jnp
import numpy as np

BIG = np.int32(1 << 30)  # host constant, referenced from traced code


@jax.jit
def mixed(x):
    y = np.maximum(x, 0)           # L12: np op inside traced code
    return jnp.where(x > 0, y, BIG)  # L13: module-level np value `BIG`


@jax.jit
def clean(x):
    return jnp.where(x > 0, x, jnp.int32(0))  # all-jnp: not flagged
