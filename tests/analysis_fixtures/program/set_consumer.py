"""Iterates an imported set-returning callee: unordered across modules."""
from set_provider import live_workers


def drain(table, sink):
    for w in live_workers(table):
        sink.append(w)
