"""Cross-module traced closure: the jit root lives here..."""
import jax

from xjit_b import mixed_helper


@jax.jit
def entry(x):
    return mixed_helper(x) + 1.0
