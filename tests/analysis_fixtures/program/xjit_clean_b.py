"""A helper that stays on device: nothing to flag, even once traced."""
import jax.numpy as jnp


def device_helper(x):
    return jnp.dot(x, x)
