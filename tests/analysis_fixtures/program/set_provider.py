"""A set-returning function consumed from another module."""


def live_workers(table):
    return set(table)
