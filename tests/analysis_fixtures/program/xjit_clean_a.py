"""Cross-module near-miss: the jit root calls a device-clean helper."""
import jax

from xjit_clean_b import device_helper


@jax.jit
def entry(x):
    return device_helper(x) + 1.0
