"""...and the hazards live here, invisible to intra-module linting."""
import numpy as np


def mixed_helper(x):
    y = np.asarray(x)
    return np.dot(y, y)
