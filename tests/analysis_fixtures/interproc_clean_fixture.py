"""interproc-unordered-iteration near-miss: sorted at the boundary."""


def active_workers(assignments):
    return {w for ws in assignments for w in ws}


def ordered_workers(assignments):
    return sorted(active_workers(assignments))


def rebalance(assignments, ring):
    for w in sorted(active_workers(assignments)):
        ring.append(w)
    n = len([1 for w in ordered_workers(assignments)])
    return n
