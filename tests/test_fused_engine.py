"""Fused device feed path (ISSUE 6): one jitted launch per (edge, segment).

The equivalence contract, against the host engines:

* **integer-exact SG/FG/PKG** — routing counts, replica sets
  (``memory_overhead``), imbalance and merged windows match the batched
  engine bit-for-bit across feeds and events; finish times / latencies
  agree up to the f32 timing epsilon (the device FIFO runs in float32, so
  a hot worker's sequential busy-time accumulation drifts by a few
  hundred ulps — DESIGN.md §11).
* **§6-banded DC/WC/FISH** — the fused tracker is a dense device table
  (no SpaceSaving eviction), so routing drifts within the DESIGN.md §6
  bands against the reference oracle, while window contents stay exact.
* **merged windows exact for every scheme** — keyed window state is
  routed-stream-identical no matter which engine routed it, so the
  merged windows equal :func:`direct_aggregate` on the raw stream.
* **one dispatch per steady-state feed** — when feed boundaries land on
  pane boundaries and no events fire, each ``session.feed`` costs one
  device launch; events and mid-feed pane cuts add segments.
* **pow2-padded shapes** — feeds in the same padding bucket reuse the
  jitted segment function (no recompilation).
"""

import warnings

import numpy as np
import pytest

from repro.core import CapacityEvent, MembershipEvent
from repro.core.stream import simulate_edge
from repro.data.synthetic import zipf_time_evolving
from repro.kernels import feed_fused
from repro.state import WindowOp, direct_aggregate
from repro.state.store import ArrayStateStore, DeviceStateStore, DictStateStore
from repro.topology import (Edge, ScopedEvent, ServingTopologyEngine,
                            SimulatorEngine, Source, Stage, Topology,
                            WindowOp as TopoWindowOp, config_for)

from repro.analysis.contracts import (DRIFT_SCHEMES, EXACT_SCHEMES,
                                      SCHEMES)

# float32 device FIFO: sequential busy-time accumulation on a hot worker
# drifts a few hundred ulps from the float64 host scan (DESIGN.md §11)
F32_REL = 1e-4


@pytest.fixture(scope="module")
def keys():
    return zipf_time_evolving(6_000, num_keys=600, z=1.4, seed=0)


@pytest.fixture(scope="module")
def values(keys):
    return np.random.default_rng(5).integers(1, 10, keys.shape[0]).astype(
        np.int64)


def _topo(scheme, op=None, workers=8):
    return Topology(
        name=f"fused-{scheme}",
        stages=(Stage("agg", workers, operator=op),),
        edges=(Edge("source", "agg", config_for(scheme)),),
    )


def _run(mode, topo, src, events=(), feeds=1):
    sess = SimulatorEngine(mode=mode).open(
        topo, arrival_rate=src.arrival_rate)
    if events:
        sess.advance(events)
    n = int(src.keys.shape[0])
    for batch in src.iter_batches(batch_size=-(-n // feeds)):
        sess.feed(batch)
    return sess.close()


# ---------------------------------------------------------------------------
# fused vs batched: integer-exact for the sequential schemes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", EXACT_SCHEMES)
@pytest.mark.parametrize("feeds", (1, 4))
def test_fused_exact_schemes_match_batched(scheme, feeds, keys, values):
    op = TopoWindowOp(agg="sum", value="payload", size=1_500)
    topo = _topo(scheme, op)
    src = Source(keys, arrival_rate=2e4, values=values)
    rb = _run("batched", topo, src, feeds=feeds)
    rf = _run("fused", topo, src, feeds=feeds)
    eb, ef = rb.edges[0], rf.edges[0]
    assert ef.n_tuples == eb.n_tuples
    assert ef.memory_overhead == eb.memory_overhead
    assert ef.imbalance == eb.imbalance
    assert ef.latency_p99 == pytest.approx(eb.latency_p99, rel=F32_REL)
    assert ef.latency_avg == pytest.approx(eb.latency_avg, rel=F32_REL)
    assert ef.execution_time == pytest.approx(eb.execution_time, rel=F32_REL)
    assert rf.state["agg"]["merged"] == rb.state["agg"]["merged"]
    assert rf.state["agg"]["partials"] == rb.state["agg"]["partials"]


# ---------------------------------------------------------------------------
# fused vs the reference oracle: §6 bands for the epoch-paced schemes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", DRIFT_SCHEMES)
def test_fused_drift_schemes_within_bands(scheme, keys, values):
    op = TopoWindowOp(agg="sum", value="payload", size=1_500)
    topo = _topo(scheme, op)
    src = Source(keys, arrival_rate=2e4, values=values)
    ro = _run("reference", topo, src)
    rf = _run("fused", topo, src, feeds=3)
    eo, ef = ro.edges[0], rf.edges[0]
    assert ef.n_tuples == eo.n_tuples
    assert ef.execution_time == pytest.approx(eo.execution_time, rel=0.05)
    assert ef.throughput == pytest.approx(eo.throughput, rel=0.05)
    assert ef.memory_overhead == pytest.approx(eo.memory_overhead, rel=0.25)
    assert ef.imbalance <= eo.imbalance + 0.05
    assert ef.latency_p99 <= max(eo.latency_p99 * 10.0, 0.05)
    # window contents are routing-independent: exact under drift too
    assert rf.state["agg"]["merged"] == direct_aggregate(
        keys, op, values=values)


# ---------------------------------------------------------------------------
# multi-feed with events + payload windows: the full churn protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
def test_fused_multi_feed_with_events(scheme, keys, values):
    op = TopoWindowOp(agg="sum", value="payload", size=1_024,
                      backend="dict")
    topo = _topo(scheme, op)
    src = Source(keys, arrival_rate=2e4, values=values)
    events = [
        ScopedEvent("agg", MembershipEvent(at=2_000,
                                           workers=tuple(range(10)))),
        ScopedEvent("agg", CapacityEvent(at=3_500,
                                         capacities={0: 4e-3})),
        ScopedEvent("agg", MembershipEvent(at=5_000,
                                           workers=tuple(range(1, 10)))),
    ]
    rb = _run("batched", topo, src, events, feeds=4)
    rf = _run("fused", topo, src, events, feeds=4)
    # keyed window state is exact regardless of scheme: same routed stream
    assert rf.state["agg"]["merged"] == rb.state["agg"]["merged"]
    assert rf.state["agg"]["merged"] == direct_aggregate(
        keys, op, values=values)
    ef = rf.edges[0]
    assert len(ef.remap_events) == len(rb.edges[0].remap_events) == 2
    if scheme in EXACT_SCHEMES:
        eb = rb.edges[0]
        assert ef.memory_overhead == eb.memory_overhead
        assert ef.latency_p99 == pytest.approx(eb.latency_p99, rel=F32_REL)
        assert rf.state["agg"]["migration_bytes"] == \
            rb.state["agg"]["migration_bytes"]


# ---------------------------------------------------------------------------
# incremental operator emission: windows flow downstream per feed
# ---------------------------------------------------------------------------


def _merge_topo(scheme, backend="array"):
    op = TopoWindowOp(agg="sum", value="payload", size=1_000,
                      backend=backend)
    return Topology(name="m", stages=(
        Stage("count", 6, operator=op), Stage("merge", 4)),
        edges=(Edge("source", "count", config_for(scheme)),
               Edge("count", "merge", config_for("fg"))))


@pytest.mark.parametrize("backend", ("dict", "array", "device"))
def test_fused_merge_stage_matches_batched(backend, keys, values):
    src = Source(keys, arrival_rate=2e4, values=values)
    rb = _run("batched", _merge_topo("fg", backend), src, feeds=4)
    rf = _run("fused", _merge_topo("fg", backend), src, feeds=4)
    assert rf.state["count"]["merged"] == rb.state["count"]["merged"]
    assert rf.edges[1].n_tuples == rb.edges[1].n_tuples
    assert rf.edges[1].latency_p99 == pytest.approx(
        rb.edges[1].latency_p99, rel=F32_REL)


@pytest.mark.parametrize("mode", ("batched", "fused"))
def test_operator_emits_incrementally_per_feed(mode, keys, values):
    """Windows that close during a feed reach the downstream merge edge
    before ``close()`` — the merge edge exists (and has tuples) after the
    first window-crossing feed."""
    src = Source(keys, arrival_rate=2e4, values=values)
    sess = SimulatorEngine(mode=mode).open(_merge_topo("fg"),
                                           arrival_rate=2e4)
    feeds = list(src.iter_batches(batch_size=3_000))
    sess.feed(feeds[0])  # 3 windows of 1000 close inside this feed
    st = sess._st.get("count->merge")
    assert st is not None and st.n > 0
    mid = st.n
    sess.feed(feeds[1])
    rep = sess.close()
    assert rep.edges[1].n_tuples > mid
    assert rep.state["count"]["merged"] == direct_aggregate(
        keys, _merge_topo("fg").stages[0].operator, values=values)


def test_serving_operator_emits_incrementally(keys, values):
    src = Source(keys[:600], arrival_rate=2e4, values=values[:600])
    eng = ServingTopologyEngine(max_requests=200)
    topo = Topology(name="m", stages=(
        Stage("count", 6, operator=TopoWindowOp(agg="count", size=150)),
        Stage("merge", 4)),
        edges=(Edge("source", "count", config_for("fg")),
               Edge("count", "merge", config_for("fg"))))
    sess = eng.open(topo)
    feeds = list(src.iter_batches(batch_size=200))
    sess.feed(feeds[0])
    st = sess._st.get("count->merge")
    assert st is not None and st.n > 0  # window 0 flowed mid-session
    for b in feeds[1:]:
        sess.feed(b)
    rep = sess.close()
    assert rep.edges[1].n_tuples == rep.state["count"]["partial_entries"]


# ---------------------------------------------------------------------------
# dispatch accounting: one launch per steady-state feed
# ---------------------------------------------------------------------------


def test_one_dispatch_per_steady_state_feed(keys, values):
    # feed size == pane stride: every feed is exactly one event-free
    # segment, so the whole feed is a single device launch
    op = TopoWindowOp(agg="sum", value="payload", size=1_500)
    src = Source(keys, arrival_rate=2e4, values=values)
    rep = _run("fused", _topo("fg", op), src, feeds=4)
    assert rep.edges[0].dispatches == 4
    # without an operator there are no pane cuts either
    rep = _run("fused", _topo("fg"), src, feeds=4)
    assert rep.edges[0].dispatches == 4


def test_events_and_pane_cuts_add_dispatches(keys, values):
    op = TopoWindowOp(agg="sum", value="payload", size=1_024)
    src = Source(keys, arrival_rate=2e4, values=values)
    ev = [ScopedEvent("agg", MembershipEvent(at=2_100,
                                             workers=tuple(range(10))))]
    rep = _run("fused", _topo("fg", op), src, ev, feeds=2)
    # 2 feeds of 3000: pane cuts at 1024/2048 + the event cut at 2100 make
    # feed 1 four segments; cuts at 3072/4096/5120 make feed 2 four more
    assert rep.edges[0].dispatches == 8
    # host engines never dispatch
    assert _run("batched", _topo("fg", op), src,
                ev, feeds=2).edges[0].dispatches == 0


def test_dispatches_surface_on_edge_result(keys):
    g = config_for("fg").build(8)
    res = simulate_edge(g, keys[:1_000], arrival_rate=2e4, mode="fused",
                        capacities=np.full(8, 4e-4))
    assert res.dispatches == 1
    g2 = config_for("fg").build(8)
    res2 = simulate_edge(g2, keys[:1_000], arrival_rate=2e4,
                         capacities=np.full(8, 4e-4))
    assert res2.dispatches == 0
    np.testing.assert_allclose(res.finishes, res2.finishes, rtol=F32_REL)


# ---------------------------------------------------------------------------
# pow2 padding: same bucket → no recompilation
# ---------------------------------------------------------------------------


def test_same_bucket_feeds_do_not_retrace(keys, values):
    src = Source(keys, arrival_rate=2e4, values=values)
    op = TopoWindowOp(agg="sum", value="payload", size=3_000)
    sess = SimulatorEngine(mode="fused").open(_topo("fg", op),
                                              arrival_rate=2e4)
    feeds = list(src.iter_batches(batch_size=1_500))
    sess.feed(feeds[0])
    sess.feed(feeds[1])  # shapes warmed: every pad bucket seen
    before = feed_fused.TRACE_COUNT
    sess.feed(feeds[2])
    sess.feed(feeds[3])
    assert feed_fused.TRACE_COUNT == before  # same (1500→2048) bucket
    sess.close()


def test_bucket_boundaries_are_pow2():
    assert feed_fused._bucket(1) == feed_fused.MIN_BUCKET
    assert feed_fused._bucket(64) == 64
    assert feed_fused._bucket(65) == 128
    assert feed_fused._bucket(1_500) == 2_048
    assert feed_fused._bucket(2_048) == 2_048


# ---------------------------------------------------------------------------
# fallback: unsupported inputs delegate to the host engines, warning once
# ---------------------------------------------------------------------------


def test_fused_falls_back_on_negative_keys():
    ks = np.array([-3, 1, 2, -1] * 50, dtype=np.int64)
    g = config_for("fg").build(4)
    with pytest.warns(UserWarning, match="falling back"):
        res = simulate_edge(g, ks, arrival_rate=1e4, mode="fused",
                            capacities=np.full(4, 3e-4))
    g2 = config_for("fg").build(4)
    ref = simulate_edge(g2, ks, arrival_rate=1e4,
                        capacities=np.full(4, 3e-4))
    np.testing.assert_array_equal(res.finishes, ref.finishes)
    # the sentinel sticks: the next feed delegates silently
    n = ks.shape[0]
    ts = (np.arange(n, 2 * n, dtype=np.float64)) / 1e4
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res2 = simulate_edge(g, ks, times=ts, arrival_rate=1e4,
                             mode="fused", state=res.state)
    assert res2.dispatches == 0


def test_fused_rejects_state_sink_in_host_modes(keys):
    from repro.state import KeyedStateManager
    g = config_for("fg").build(4)
    mgr = KeyedStateManager(WindowOp(agg="count", size=100))
    with pytest.raises(ValueError, match="state_sink"):
        simulate_edge(g, keys[:100], arrival_rate=1e4, mode="batched",
                      state_sink=mgr)


# ---------------------------------------------------------------------------
# device-resident state store backend
# ---------------------------------------------------------------------------


def _fill(store, rng, rounds=5):
    for _ in range(rounds):
        ks = rng.integers(0, 500, 300)
        vs = rng.integers(1, 100, 300)
        store.update_batch(ks, vs)


def test_device_store_matches_dict_store():
    rng1, rng2 = (np.random.default_rng(9) for _ in range(2))
    dev, ref = DeviceStateStore(), DictStateStore()
    _fill(dev, rng1), _fill(ref, rng2)
    dk, dv, dc = dev.items()
    rk, rv, rc = ref.items()
    order = np.argsort(rk, kind="stable")
    np.testing.assert_array_equal(dk, rk[order])
    np.testing.assert_array_equal(dv, rv[order])
    np.testing.assert_array_equal(dc, rc[order])
    assert dev.num_entries == ref.num_entries
    assert dev.size_bytes() == ref.size_bytes()


def test_device_store_take_and_merge_roundtrip():
    dev, ref = DeviceStateStore(), ArrayStateStore()
    ks = np.arange(40, dtype=np.int64)
    vs = (ks * 7 + 1)
    dev.update_batch(ks, vs), ref.update_batch(ks, vs)
    tk = np.array([3, 17, 39], dtype=np.int64)
    vd, cd = dev.take(tk)
    vr, cr = ref.take(tk)
    np.testing.assert_array_equal(vd, vr)
    np.testing.assert_array_equal(cd, cr)
    assert dev.num_entries == ref.num_entries
    # migrated entries land back exactly (the §9 churn protocol)
    dev.merge_entries(tk, vd, cd), ref.merge_entries(tk, vr, cr)
    np.testing.assert_array_equal(dev.items()[1], ref.items()[1])
    with pytest.raises(KeyError):
        dev.take(np.array([999]))


def test_device_store_guards_int32_range():
    dev = DeviceStateStore()
    with pytest.raises(ValueError, match="int32"):
        dev.update_batch(np.array([2**40]), np.array([1]))


# ---------------------------------------------------------------------------
# fused rejection predicate
# ---------------------------------------------------------------------------


def test_fused_reject_reasons(keys):
    g = config_for("fg").build(4)
    ok = feed_fused.fused_reject_reason(g, keys[:100], None, None, None)
    assert ok is None
    bad = feed_fused.fused_reject_reason(
        g, np.array([-1, 2]), None, None, None)
    assert bad is not None and "negative" in bad
    obs = feed_fused.fused_reject_reason(
        g, keys[:100], None, None, lambda *a: None)
    assert obs is not None


# ---------------------------------------------------------------------------
# trace/transfer auditor (ISSUE 7): the §11 budgets hold on a live runner
# ---------------------------------------------------------------------------


from repro.analysis.audit import EdgeAuditor, TraceBudget  # noqa: E402
from repro.topology import RecordBatch  # noqa: E402


def _mixed_batches(keys, values, sizes):
    """Slices of the key stream at the given (uneven) batch sizes, with a
    shared monotone clock.  The first record carries the global max key so
    the runner's key-capacity axis is fixed from the warm-up feed on —
    leaving the pow2 pad bucket as the only shape axis under audit."""
    total = sum(sizes)
    ks = np.resize(keys, total).copy()
    ks[0] = ks.max()
    vs = np.resize(values, total)
    ts = np.arange(total, dtype=np.float64) / 2e4
    out, lo = [], 0
    for n in sizes:
        out.append(RecordBatch(keys=ks[lo:lo + n],
                               timestamps=ts[lo:lo + n],
                               values=vs[lo:lo + n]))
        lo += n
    return out


def test_auditor_retrace_budget_mixed_batch_sizes(keys, values):
    # feeds spanning three pow2 pad buckets: 900/700→1024, 1500/2000→2048,
    # 64→64.  TRACE_COUNT must stay within the documented signature set —
    # one trace per distinct bucket at most, zero for repeats.
    sizes = (900, 1_500, 64, 700, 1_500, 900, 2_000, 64)
    batches = _mixed_batches(keys, values, sizes)
    sess = SimulatorEngine(mode="fused").open(_topo("pkg"),
                                              arrival_rate=2e4)
    sess.feed(batches[0])  # warm-up: creates the runner, pins kcap
    runner = sess._st["source->agg"].state.device
    assert runner is not None
    with TraceBudget(3, what="mixed-bucket sweep"):
        with EdgeAuditor(runner) as aud:
            for b in batches[1:]:
                sess.feed(b)
    aud.assert_retrace_budget()
    # every launch dispatched under a documented signature: the pad-bucket
    # axis takes exactly the three pow2 values, nothing else varies
    assert {sig[1] for sig in aud.signatures} == {64, 1_024, 2_048}
    assert aud.dispatches == len(sizes) - 1  # no panes, no events
    assert all(e.tuples == n
               for e, n in zip((e for e in aud.events
                                if e.kind == "segment"), sizes[1:]))
    sess.close()


def test_auditor_sync_budget_pane_boundaries(keys, values):
    # device→host transfers only at pane flushes and close (HOST_SYNC_POINTS):
    # feed size == pane stride, so every flush lands on the pane grid and
    # the close-time drain is the only off-grid sync
    op = TopoWindowOp(agg="sum", value="payload", size=1_500)
    src = Source(keys, arrival_rate=2e4, values=values)
    sess = SimulatorEngine(mode="fused").open(_topo("fg", op),
                                              arrival_rate=2e4)
    feeds = list(src.iter_batches(batch_size=1_500))
    sess.feed(feeds[0])  # warm-up: creates the runner
    runner = sess._st["source->agg"].state.device
    with EdgeAuditor(runner, pane_stride=1_500) as aud:
        for b in feeds[1:]:
            sess.feed(b)
        aud.assert_retrace_budget()
        with aud.expect("close"):
            rep = sess.close()
    aud.assert_sync_budget(closed=True)
    assert aud.dispatches == len(feeds) - 1
    assert rep.edges[0].n_tuples == keys.shape[0]
    # the audited feeds flushed their panes on the stride grid
    flushes = [e for e in aud.events
               if e.kind == "flush_pane" and e.context == "feed"]
    assert flushes and all(e.offset % 1_500 == 0 for e in flushes)
