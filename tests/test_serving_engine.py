"""ServingEngine accounting fixes (ISSUE 2 satellites).

* a replica decodes at most ``speed`` tokens per tick *total* (spread over
  its active slots), not ``speed × active_slots``;
* ``add_replica`` propagates the new replica's true capacity (1/speed) to
  the router so Alg. 3 routes proportionally after scale-out.
"""

import numpy as np
import pytest

from repro.serving.engine import Request, ServingEngine


@pytest.mark.parametrize("speed", [1.0, 2.0, 3.0])
def test_tokens_per_tick_bounded_by_speed(speed):
    eng = ServingEngine(num_replicas=1, slots_per_replica=4,
                        tokens_per_tick=np.array([speed]), grouping="fish")
    for i in range(12):  # keep all 4 slots saturated
        eng.submit(Request(i, f"s{i % 3}", arrival=0.0, target_tokens=25))
    ticks = 40
    prev = 0
    for _ in range(ticks):
        eng.tick()
        delta = eng.total_tokens - prev
        prev = eng.total_tokens
        assert delta <= int(np.ceil(speed)), "decoded more than speed/tick"
    assert eng.total_tokens <= speed * ticks + 1
    # saturated replica should also achieve ~speed tokens/tick
    assert eng.total_tokens >= 0.9 * speed * ticks


def test_fractional_speed_accumulates():
    eng = ServingEngine(num_replicas=1, slots_per_replica=2,
                        tokens_per_tick=np.array([0.5]), grouping="fish")
    eng.submit(Request(0, "s", arrival=0.0, target_tokens=5))
    for _ in range(20):
        eng.tick()
    # 0.5 tokens/tick -> 5 target tokens need ~10 ticks, done well within 20
    assert len(eng.done) == 1
    assert eng.total_tokens == 5


def test_throughput_bounded_by_aggregate_speed():
    rng = np.random.default_rng(0)
    speeds = np.array([1.0, 2.0])
    eng = ServingEngine(num_replicas=2, slots_per_replica=4,
                        tokens_per_tick=speeds, grouping="fish")
    for i in range(40):
        eng.submit(Request(i, f"hot{rng.integers(0, 3)}", arrival=0.0,
                           target_tokens=int(rng.integers(4, 10))))
    eng.run(until_done=40)
    assert len(eng.done) == 40
    m = eng.metrics()
    assert m.throughput_tokens <= speeds.sum() + 1e-9


def test_add_replica_capacity_reaches_router():
    eng = ServingEngine(num_replicas=2, slots_per_replica=2, grouping="fish")
    r = eng.add_replica(speed=4.0, slots=2)
    caps = eng.router.estimator.capacities
    assert caps.shape[0] == 3
    # exact 1/speed, not the 1.0 scale-out pad
    assert caps[r] == pytest.approx(0.25)

    # the fast newcomer must actually attract routed work (Alg. 3 argmin)
    for i in range(30):
        eng.submit(Request(i, f"cold{i}", arrival=0.0, target_tokens=4))
    assert int(eng.router.assigned_counts[r]) > 0


def test_set_replica_speed_updates_router():
    eng = ServingEngine(num_replicas=2, slots_per_replica=2, grouping="fish")
    eng.set_replica_speed(1, 0.25)  # straggler onset: 4x slower
    assert eng.speeds[1] == 0.25
    # EMA sample moved the estimate toward 4.0 s/token
    assert eng.router.estimator.capacities[1] > 2.0


# ---------------------------------------------------------------------------
# bounded replica queues + open-loop accounting (ISSUE 8 satellites)
# ---------------------------------------------------------------------------


def test_bounded_queue_sheds_and_run_still_terminates():
    """With bounded per-replica queues, overload sheds; ``run(until_done)``
    must count shed requests toward completion or it would spin forever
    waiting for requests that will never finish."""
    eng = ServingEngine(num_replicas=2, slots_per_replica=1,
                        grouping="fish", max_queue_per_replica=2)
    for i in range(40):
        eng.submit(Request(i, f"s{i % 4}", arrival=0.0, target_tokens=3))
    assert eng.shed > 0
    eng.run(until_done=40, max_ticks=2_000)
    # terminated by the done+shed count, not by the tick ceiling
    assert len(eng.done) + eng.shed == 40
    assert len(eng.done) < 40
    m = eng.metrics()
    assert m.shed == eng.shed
    assert m.queue_depth_peak <= 2 * 2


def test_unbounded_queue_never_sheds():
    eng = ServingEngine(num_replicas=2, slots_per_replica=1, grouping="fish")
    for i in range(40):
        eng.submit(Request(i, f"s{i % 4}", arrival=0.0, target_tokens=3))
    assert eng.shed == 0
    eng.run(until_done=40)
    assert len(eng.done) == 40


def test_shed_submit_returns_sentinel_and_is_not_queued():
    eng = ServingEngine(num_replicas=1, slots_per_replica=1,
                        grouping="fish", max_queue_per_replica=1)
    rs = [eng.submit(Request(i, "s", arrival=0.0, target_tokens=2))
          for i in range(5)]
    # requests enter slots only on tick(): 1 queued admitted, 4 shed
    assert rs.count(-1) == eng.shed == 4
    assert sum(len(q) for q in eng.queues) == 1


def test_time_in_queue_metrics_cover_finished_requests():
    eng = ServingEngine(num_replicas=1, slots_per_replica=1, grouping="fish")
    for i in range(6):
        eng.submit(Request(i, "s", arrival=0.0, target_tokens=2))
    eng.run(until_done=6)
    m = eng.metrics()
    # serialized on one slot: later requests waited strictly longer
    assert m.time_in_queue_p99 > 0.0
    assert m.time_in_queue_avg > 0.0
    assert m.time_in_queue_p99 >= m.time_in_queue_avg
    assert m.in_flight_peak == 1
    for r in eng.done:
        assert r.started >= r.arrival


def test_stall_replica_pauses_decode_for_exact_ticks():
    eng = ServingEngine(num_replicas=1, slots_per_replica=2, grouping="fish")
    eng.submit(Request(0, "s", arrival=0.0, target_tokens=3))
    eng.stall_replica(0, 5)
    for _ in range(5):
        eng.tick()
    assert eng.total_tokens == 0  # stalled: decoded nothing
    for _ in range(5):
        eng.tick()
    assert eng.total_tokens > 0  # resumed right after the stall
    eng.run(until_done=1)
    # 3 tokens at speed 1 + 5 stall ticks
    assert eng.done[0].finished == pytest.approx(8.0)
