"""Open-loop load subsystem (ISSUE 8): arrival processes, bounded-queue
admission control, the open-loop driver's accounting, p99 autoscaling, and
tick-billed state migration.
"""

import numpy as np
import pytest

from repro.load import (ArrivalProcess, ConstantRate, DiurnalRate,
                        FlashCrowd, FlipZipfKeys, IngressQueue,
                        MarkovModulatedRate, OpenLoopDriver, P99Autoscaler,
                        ZipfKeys)
from repro.scenarios import (OpenLoopScenario, default_open_loop_scenarios,
                             open_loop_topology, run_open_loop_scenario)
from repro.state import WindowOp
from repro.topology import (Edge, ScopedEvent, SimulatorEngine, Stage,
                            Topology, config_for)
from repro.topology.graph import RecordBatch
from repro.core import MembershipEvent, at_time

STAGE = "worker"


def one_edge(scheme="fish", workers=4, cost=0.002, window=None):
    return Topology(
        name="t",
        stages=(Stage(STAGE, parallelism=workers, cost=cost,
                      operator=window),),
        edges=(Edge("source", STAGE, config_for(scheme)),),
    )


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


def test_arrivals_deterministic_and_rate_accurate():
    ap = ArrivalProcess(ConstantRate(2_000.0), ZipfKeys(256), tick=0.05,
                        seed=7)
    b1 = list(ap.batches(0.0, 2.0))
    b2 = list(ArrivalProcess(ConstantRate(2_000.0), ZipfKeys(256),
                             tick=0.05, seed=7).batches(0.0, 2.0))
    assert len(b1) == len(b2) == 40
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x.keys, y.keys)
        np.testing.assert_array_equal(x.timestamps, y.timestamps)
    n = sum(len(b) for b in b1)
    # Poisson(4000) total: 5 sigma ≈ 316
    assert abs(n - 4_000) < 350
    for b in b1:
        assert np.all(np.diff(b.timestamps) >= 0)


def test_arrivals_timestamps_live_in_their_tick():
    ap = ArrivalProcess(ConstantRate(500.0), ZipfKeys(64), tick=0.1, seed=0)
    for i, b in enumerate(ap.batches(0.0, 1.0)):
        if len(b):
            assert b.timestamps.min() >= i * 0.1 - 1e-9
            assert b.timestamps.max() <= (i + 1) * 0.1 + 1e-9


def test_flash_crowd_multiplies_rate_inside_window():
    base = ConstantRate(1_000.0)
    flash = base * FlashCrowd(at=10.0, duration=5.0, magnitude=4.0, ramp=0.0)
    assert flash(5.0) == pytest.approx(1_000.0)
    assert flash(12.0) == pytest.approx(4_000.0)
    assert flash(16.0) == pytest.approx(1_000.0)


def test_diurnal_rate_oscillates_and_stays_nonnegative():
    r = ConstantRate(100.0) * DiurnalRate(amplitude=1.0, period=10.0)
    vals = np.array([r(t) for t in np.linspace(0, 10, 101)])
    assert vals.min() == pytest.approx(0.0, abs=1e-9)  # trough of 1+sin
    assert vals.max() == pytest.approx(200.0, rel=0.01)
    assert r(0.0) == pytest.approx(100.0)
    with pytest.raises(ValueError):
        DiurnalRate(amplitude=1.5)  # >1 would go negative


def test_markov_modulated_rate_is_deterministic_per_seed():
    r1 = MarkovModulatedRate(levels=(0.5, 2.0), mean_dwell=1.0, seed=3)
    r2 = MarkovModulatedRate(levels=(0.5, 2.0), mean_dwell=1.0, seed=3)
    ts = np.linspace(0, 20, 41)
    assert [r1(t) for t in ts] == [r2(t) for t in ts]
    assert {r1(t) for t in ts} <= {0.5, 2.0}


def test_flip_zipf_changes_hot_set_at_flip_time():
    fk = FlipZipfKeys(128, z=1.5, flip_time=5.0)
    rng = np.random.default_rng(0)
    pre = fk.sample(4_000, 1.0, rng)
    post = fk.sample(4_000, 6.0, rng)
    hot_pre = np.bincount(pre, minlength=128).argmax()
    hot_post = np.bincount(post, minlength=128).argmax()
    assert hot_pre != hot_post


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def _offer_ticks(q, n_ticks=20, per_tick=100, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n_ticks):
        keys = rng.integers(0, 64, per_tick).astype(np.int32)
        ts = np.full(per_tick, float(i))
        q.offer(keys, ts)
        assert q.check_identity()


@pytest.mark.parametrize("policy", ["shed", "defer", "degrade"])
def test_admission_identity_holds_under_overload(policy):
    q = IngressQueue(capacity=150, policy=policy)
    _offer_ticks(q)
    # drain in chunks; identity must hold at every step
    while len(q):
        q.pop(37)
        assert q.check_identity()
    s = q.stats
    assert s.offered == 2_000
    assert s.fed + s.shed == 2_000
    if policy == "defer":
        assert s.shed == 0 and s.deferred > 0
    else:
        assert s.shed > 0


@pytest.mark.parametrize("policy", ["shed", "degrade"])
def test_bounded_queue_never_exceeds_capacity(policy):
    q = IngressQueue(capacity=150, policy=policy)
    _offer_ticks(q)
    assert len(q) <= 150
    assert q.stats.queue_depth_peak <= 150


def test_degrade_thins_uniformly():
    q = IngressQueue(capacity=500, policy="degrade", seed=1)
    keys = np.arange(2_000, dtype=np.int32) % 64
    q.offer(keys, np.zeros(2_000))
    got, _, _ = q.pop(500)
    assert got.shape[0] == 500
    # an unbiased thinning keeps roughly the source key distribution
    assert np.unique(got).shape[0] > 50


def test_pop_is_fifo_and_returns_arrival_timestamps():
    q = IngressQueue(capacity=10, policy="defer")
    q.offer(np.array([1, 2], dtype=np.int32), np.array([0.25, 0.5]))
    q.offer(np.array([3], dtype=np.int32), np.array([0.75]))
    keys, arrivals, _ = q.pop(3)
    np.testing.assert_array_equal(keys, [1, 2, 3])
    np.testing.assert_allclose(arrivals, [0.25, 0.5, 0.75])


# ---------------------------------------------------------------------------
# open-loop driver
# ---------------------------------------------------------------------------


def test_driver_overload_sheds_and_accounting_closes():
    """Flash-crowd overload through a bounded shedding queue: backpressure
    engages, the queue stays bounded, and every offered record is either
    fed, shed, or residual — exactly."""
    ol = OpenLoopScenario("t", workers=4, rate=1_500.0, horizon=2.0,
                          utilization=0.8, flash=(0.8, 0.5, 3.0),
                          num_keys=256, queue_capacity=150, policy="shed",
                          backpressure=0.25)
    r = run_open_loop_scenario(ol, "fish", engine="batched", drain=True)
    assert r["identity_ok"]
    assert r["offered"] == r["fed"] + r["shed_ingress"] + r["residual"]
    assert r["shed"] > 0
    assert r["residual"] == 0  # drained
    assert r["queue_depth_peak"] <= 150
    assert r["queue_delay_p99"] > 0.0
    # total latency decomposes into queue delay + service latency
    assert r["total_latency_p99"] >= r["latency_p99"] - 1e-9


def test_driver_no_drain_reports_residual():
    ol = OpenLoopScenario("t", workers=4, rate=1_500.0, horizon=1.0,
                          utilization=0.8, flash=(0.2, 0.8, 4.0),
                          num_keys=256, queue_capacity=10_000,
                          policy="defer", backpressure=0.05)
    r = run_open_loop_scenario(ol, "fish", engine="batched", drain=False)
    assert r["identity_ok"]
    assert r["residual"] > 0
    assert r["offered"] == r["fed"] + r["residual"]


def test_open_loop_matches_closed_loop_replay_with_at_time_event():
    """Feeding the same admitted schedule closed loop (same batches, same
    at_time membership event) reproduces the open-loop run exactly — the
    driver adds accounting, never different execution."""
    ap = ArrivalProcess(ConstantRate(800.0), ZipfKeys(128, z=1.2),
                        tick=0.05, seed=11)
    ev_t = 0.5
    horizon = 1.0

    def event():
        return ScopedEvent(STAGE, at_time(
            MembershipEvent(workers=(0, 1, 2)), ev_t))

    # open loop: unbounded queue, no backpressure -> every tick feeds whole
    sess = SimulatorEngine(mode="batched").open(one_edge(),
                                                arrival_rate=800.0)
    sess.advance([event()])
    drv = OpenLoopDriver(sess, IngressQueue(10**6, policy="defer"))
    rep_open = drv.run(ap, 0.0, horizon).topology

    # closed loop: identical batches (re-timestamped to the feed grid, as
    # the driver does), identical event
    sess2 = SimulatorEngine(mode="batched").open(one_edge(),
                                                 arrival_rate=800.0)
    sess2.advance([event()])
    t_feed = 0.0
    for b in ArrivalProcess(ConstantRate(800.0), ZipfKeys(128, z=1.2),
                            tick=0.05, seed=11).batches(0.0, horizon):
        t_feed += 0.05
        if len(b):
            sess2.feed(RecordBatch(b.keys, np.full(len(b), t_feed)))
    rep_closed = sess2.close()

    ro, rc = rep_open.edge(STAGE), rep_closed.edge(STAGE)
    assert ro.n_tuples == rc.n_tuples
    assert ro.latency_p99 == pytest.approx(rc.latency_p99)
    assert ro.latency_avg == pytest.approx(rc.latency_avg)
    assert ro.remap_events == rc.remap_events
    assert ro.imbalance == pytest.approx(rc.imbalance)


def test_feed_receipt_reports_per_feed_latencies_and_backlog():
    sess = SimulatorEngine(mode="batched").open(one_edge(cost=0.01),
                                                arrival_rate=400.0)
    keys = np.zeros(100, dtype=np.int64)  # all on one worker: backlog grows
    rec = sess.feed(RecordBatch(keys, np.full(100, 0.1)))
    assert rec.n == 100
    assert rec.latencies.shape == (100,)
    assert rec.latency_p99 > 0.0
    assert rec.backlog > 0.0  # 1s of work offered in one instant
    sess.close()


# ---------------------------------------------------------------------------
# p99 autoscaler
# ---------------------------------------------------------------------------


def test_autoscaler_scales_out_on_step_and_converges():
    """A sustained step to 1.5x the provisioned load must trigger
    scale-out, and once the pool is right-sized (plus the step-era backlog
    has drained) the scaler goes quiet: no actions over the final quarter
    of the run, end-window p99 back within the SLO, and the pool well
    short of the max_workers rail."""
    horizon, slo = 14.0, 0.1
    rate = ConstantRate(1_000.0) * FlashCrowd(at=2.0, duration=horizon,
                                              magnitude=1.5, ramp=0.0)
    ap = ArrivalProcess(rate, ZipfKeys(256, z=1.2), tick=0.05, seed=0)
    # cost 0.0028 s/tuple: 4 workers run at ~0.7 utilization pre-step
    sess = SimulatorEngine(mode="batched").open(one_edge(cost=0.0028),
                                                arrival_rate=1_000.0)
    scaler = P99Autoscaler(STAGE, slo_p99=slo, workers=range(4),
                           max_workers=16, window=0.5, cooldown=1.0,
                           sample_keys=range(256))
    drv = OpenLoopDriver(sess, IngressQueue(10**6, policy="defer"),
                         autoscaler=scaler)
    drv.run(ap, 0.0, horizon, drain=True)
    events = scaler.events
    assert events and all(e["action"] == "scale_out" for e in events)
    assert events[0]["p99"] > slo  # triggered by a real violation
    assert 4 < len(scaler.workers) < 16
    # converged: quiet over the final quarter, and back under the SLO
    assert all(e["t"] < 0.75 * horizon for e in events), events
    assert scaler.window_p99() is not None
    assert scaler.window_p99() <= slo


def test_autoscaler_never_drops_below_initial_pool():
    a = P99Autoscaler(STAGE, slo_p99=10.0, workers=range(4), max_workers=8,
                      window=1.0, cooldown=0.0, min_samples=1)
    # feed absurdly low latencies forever: scale-in pressure every step
    class R:
        latencies = np.full(64, 1e-6)
    for i in range(50):
        a.observe(float(i), R())
    assert a.workers == [0, 1, 2, 3]
    assert not a.events  # already at the floor: no scale-in ever emitted


def test_autoscaler_waits_for_min_samples_and_cooldown():
    a = P99Autoscaler(STAGE, slo_p99=0.1, workers=range(2), max_workers=8,
                      window=100.0, cooldown=5.0, min_samples=64)
    class R:
        latencies = np.full(10, 99.0)  # way over SLO
    assert a.observe(0.0, R()) == []  # 10 samples < min_samples
    emitted = []
    for i in range(1, 8):
        emitted += a.observe(float(i) * 0.1, R())
    # fires exactly once the window holds >= 64 samples, then cooldown
    # (5s) silences every later observation in the loop
    assert len(emitted) == 1
    assert a.observe(0.8, R()) == []  # still cooling down
    assert a.events[0]["action"] == "scale_out"


def test_autoscaler_new_worker_ids_are_never_reused():
    a = P99Autoscaler(STAGE, slo_p99=0.1, workers=range(2), max_workers=4,
                      window=1.0, cooldown=0.0, min_samples=1)
    class Hot:
        latencies = np.full(8, 9.0)
    class Cold:
        latencies = np.full(8, 1e-9)
    a.observe(0.0, Hot())   # out: adds 2
    a.observe(1.0, Hot())   # out: adds 3
    a.observe(2.0, Cold())  # in: retires 3
    a.observe(3.0, Hot())   # out again: must add 4, not reuse 3
    assert [e["worker"] for e in a.events] == [2, 3, 3, 4]
    assert a.workers == [0, 1, 2, 4]


# ---------------------------------------------------------------------------
# tick-billed state migration
# ---------------------------------------------------------------------------


def _membership_run(cost_per_byte, mode="batched"):
    keys = (np.arange(3_000) % 64).astype(np.int64)
    sim = SimulatorEngine(mode=mode, migration_cost_per_byte=cost_per_byte)
    sess = sim.open(one_edge("fg", window=WindowOp("count", size=3_000)),
                    arrival_rate=2_000.0)
    sess.advance([ScopedEvent(STAGE, MembershipEvent(at=1_500,
                                                     workers=(0, 1)))])
    sess.feed(RecordBatch(keys, np.linspace(0, 1.5, 3_000)))
    return sess.close()


@pytest.mark.parametrize("mode", ["batched", "reference"])
def test_migration_cost_billed_to_engine_clock(mode):
    free = _membership_run(0.0, mode)
    paid = _membership_run(1e-4, mode)
    assert free.migration_stall == 0.0
    assert paid.migration_stall > 0.0
    # billing shows up where it should: on the destinations' clocks
    assert paid.edge(STAGE).latency_p99 >= free.edge(STAGE).latency_p99
    # zero-cost runs are bit-identical to the pre-ISSUE-8 behaviour
    assert free.edge(STAGE).latency_p99 > 0.0


def test_open_loop_autoscale_bills_migration():
    ol = OpenLoopScenario("t", workers=4, rate=1_400.0, horizon=4.0,
                          utilization=0.7, flash=(1.0, 2.0, 2.5),
                          num_keys=256, queue_capacity=10**6,
                          policy="defer", backpressure=None,
                          slo_p99=0.08, max_workers=12)
    r = run_open_loop_scenario(ol, "fish", engine="batched", drain=True,
                               migration_cost_per_byte=1e-5,
                               window=WindowOp("count", size=1_000))
    assert r["autoscale_events"]
    assert r["migration_stall"] > 0.0


# ---------------------------------------------------------------------------
# serving engine open loop
# ---------------------------------------------------------------------------


def test_serving_open_loop_two_level_shed_accounting():
    ol = OpenLoopScenario("t", workers=4, rate=800.0, horizon=1.5,
                          utilization=0.8, flash=(0.5, 0.5, 3.0),
                          num_keys=128, queue_capacity=200, policy="shed",
                          backpressure=0.25)
    r = run_open_loop_scenario(ol, "fish", engine="serving", drain=True,
                               ticks_per_second=200.0,
                               max_queue_per_replica=8)
    assert r["identity_ok"]
    assert r["offered"] == r["fed"] + r["shed_ingress"] + r["residual"]
    assert r["shed"] == r["shed_ingress"] + r["shed_engine"]
    assert r["residual"] == 0
    # totals are simulator-only (serving receipts are finish-ordered)
    assert r["total_latency_p99"] is None


def test_default_open_loop_scenarios_run_clean():
    for ol in default_open_loop_scenarios(rate=600.0, horizon=1.0,
                                          workers=2, num_keys=64):
        r = run_open_loop_scenario(ol, "fish", engine="batched", drain=True)
        assert r["identity_ok"], ol.name
        assert r["residual"] == 0, ol.name
