"""Per-arch smoke tests: reduced config, one fwd/train step on CPU,
asserting output shapes + finiteness (assignment requirement)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced_config
from repro.models import transformer as T

B, S = 2, 64


def _batch(cfg, key, with_labels=True):
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if with_labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.rope_kind == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    hot = T.init_hotness_state(cfg)
    batch = _batch(cfg, key)
    train_fn = jax.jit(lambda p, b, h: T.forward_train(p, b, cfg, h))
    loss, out = train_fn(params, batch, hot)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    if cfg.moe is not None:
        assert out["new_hotness"].shape == hot.shape
        assert np.isfinite(np.asarray(out["new_hotness"])).all()


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_then_decode_continues(arch):
    """prefill(S tokens) then one decode step — shapes + finite logits."""
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key, with_labels=False)
    prefill_fn = jax.jit(lambda p, b: T.prefill(p, b, cfg))
    cache, logits = prefill_fn(params, batch)
    pv = T.padded_vocab(cfg)
    assert logits.shape == (B, pv)
    assert np.isfinite(np.asarray(logits[:, :cfg.vocab_size])).all()

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    emb = (jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
           if cfg.embeds_input else None)
    decode_fn = jax.jit(lambda p, c, t, e: T.decode_step(p, c, t, cfg, e))
    lg2, cache2 = decode_fn(params, cache, tok, emb)
    assert lg2.shape == (B, pv)
    assert np.isfinite(np.asarray(lg2[:, :cfg.vocab_size])).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-9b",
                                  "qwen1.5-0.5b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forcing consistency: decoding token-by-token from a prefix
    must match the prefill logits of the longer sequence."""
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)

    prefill_fn = jax.jit(lambda p, b: T.prefill(p, b, cfg))

    # full prefill over 16 tokens
    _, logits_full = prefill_fn(params, {"tokens": toks})

    # prefill over 15, then decode token 16
    cache, _ = prefill_fn(params, {"tokens": toks[:, :15]})
    # decode caches from prefill are sized to the prefix; rebuild at 16 for
    # attention archs by re-prefilling into a padded cache is framework work —
    # here we exercise the ssm/hybrid paths whose state is seq-independent.
    if cfg.ssm is not None or cfg.rglru is not None:
        decode_fn = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
        logits_step, _ = decode_fn(params, cache, toks[:, 15:16])
        np.testing.assert_allclose(
            np.asarray(logits_step[0, :cfg.vocab_size]),
            np.asarray(logits_full[0, :cfg.vocab_size]),
            rtol=0.08, atol=0.35,
        )


def test_mamba_decode_matches_train_path():
    """Recurrent decode == chunked SSD train path, token by token."""
    cfg = reduced_config(get_config("mamba2-780m"))
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    n = 8
    toks = jax.random.randint(key, (1, n), 0, cfg.vocab_size)

    # train-path logits at each position via prefill on growing prefixes
    _, logits_prefill = T.prefill(params, {"tokens": toks}, cfg)

    # decode path: feed tokens one by one
    cache = T.init_cache(cfg, 1, n)
    # decode_step increments pos first; start at -1
    cache["pos"] = jnp.int32(-1)
    lg = None
    for i in range(n):
        lg, cache = T.decode_step(params, cache, toks[:, i:i + 1], cfg)
    np.testing.assert_allclose(
        np.asarray(lg[0, :cfg.vocab_size]),
        np.asarray(logits_prefill[0, :cfg.vocab_size]),
        rtol=0.08, atol=0.35,
    )


def test_gemma2_softcaps_bound_logits():
    cfg = reduced_config(get_config("gemma2-2b"))
    key = jax.random.PRNGKey(4)
    params = T.init_params(cfg, key)
    _, logits = T.prefill(params, {"tokens": jax.random.randint(
        key, (1, 32), 0, cfg.vocab_size)}, cfg)
    real = np.asarray(logits[0, :cfg.vocab_size])
    assert np.abs(real).max() <= cfg.logit_softcap + 1e-3


def test_moe_hotness_evolves_and_decays():
    cfg = reduced_config(get_config("deepseek-v2-lite-16b"))
    key = jax.random.PRNGKey(5)
    params = T.init_params(cfg, key)
    hot = T.init_hotness_state(cfg)
    batch = _batch(cfg, key)
    train_fn = jax.jit(lambda p, b, h: T.forward_train(p, b, cfg, h))
    _, out = train_fn(params, batch, hot)
    h1 = out["new_hotness"]
    assert float(jnp.sum(h1)) > 0
    _, out2 = train_fn(params, batch, h1)
    h2 = out2["new_hotness"]
    # inter-epoch decay: h2 = alpha*h1 + counts, counts equal for same batch
    alpha = cfg.moe.fish_alpha
    np.testing.assert_allclose(np.asarray(h2), alpha * np.asarray(h1)
                               + np.asarray(h1), rtol=1e-4, atol=1e-4)
