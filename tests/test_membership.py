"""Churn behaviour for every grouping scheme (ISSUE 2).

Contract (DESIGN.md §5): no scheme raises on membership change; after an
event both engines route only to live workers (SG/FG/PKG stay *exact*
batched-vs-reference across events); scale-out grows per-worker arrays in
place and the new worker receives traffic; FG keeps consistent-hash key
affinity on single-host removal; ``ServingEngine.fail_replica`` requeues
every orphaned request for every routing scheme.
"""

import numpy as np
import pytest

from repro.core import MembershipEvent, simulate_edge
from repro.data.synthetic import zipf_time_evolving
from repro.serving.engine import Request, ServingEngine
from repro.topology import build_grouper

from repro.analysis.contracts import EXACT_SCHEMES, SCHEMES


def _sim_batched(g, keys, **kw):
    return simulate_edge(g, keys, mode="batched", **kw).metrics


def _sim_reference(g, keys, **kw):
    return simulate_edge(g, keys, mode="reference", **kw).metrics


@pytest.fixture(scope="module")
def keys():
    return zipf_time_evolving(8_000, num_keys=800, z=1.3, seed=1)


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("batched", [True, False], ids=["batch", "scalar"])
def test_routes_only_to_live_workers(scheme, batched, keys):
    g = build_grouper(scheme, 8)
    head, tail = keys[:2_000], keys[2_000:4_000]
    if batched:
        g.assign_batch(head, 0.0, 5e-5)
    else:
        for i, k in enumerate(head[:400]):
            g.assign(k, i * 5e-5)
    before_dead = int(g.assigned_counts[5])
    g.on_membership_change([0, 1, 2, 3, 4, 6, 7])  # worker 5 leaves
    if batched:
        out = g.assign_batch(tail, 0.5, 5e-5)
    else:
        out = np.array([g.assign(k, 0.5 + i * 5e-5)
                        for i, k in enumerate(tail[:400])])
    assert 5 not in set(out.tolist())
    assert int(g.assigned_counts[5]) == before_dead
    assert set(out.tolist()) <= {0, 1, 2, 3, 4, 6, 7}


@pytest.mark.parametrize("scheme", EXACT_SCHEMES)
def test_exact_schemes_agree_across_membership_events(scheme, keys):
    """Batched and reference engines stay bit-identical through churn."""
    ev = [
        MembershipEvent(at=2_500, workers=tuple(w for w in range(8) if w != 3)),
        MembershipEvent(at=5_500, workers=tuple(range(9))),  # 3 back + 8 new
    ]
    m_ref = _sim_reference(build_grouper(scheme, 8), keys,
                                      arrival_rate=2e4, events=ev)
    m_bat = _sim_batched(build_grouper(scheme, 8), keys,
                            arrival_rate=2e4, events=ev)
    for field, v_ref in m_ref.row().items():
        assert m_bat.row()[field] == pytest.approx(v_ref, rel=1e-9), field


@pytest.mark.parametrize("scheme", SCHEMES)
def test_simulator_membership_event_no_scheme_raises(scheme, keys):
    ev = [MembershipEvent(at=4_000, workers=tuple(w for w in range(8)
                                                  if w != 3))]
    for sim in (_sim_batched, _sim_reference):
        g = build_grouper(scheme, 8)
        m = sim(g, keys, arrival_rate=2e4, events=ev)
        assert m.execution_time > 0


@pytest.mark.parametrize("scheme", SCHEMES)
def test_scale_out_grows_arrays_and_uses_new_workers(scheme, keys):
    g = build_grouper(scheme, 4)
    g.assign_batch(keys[:2_000], 0.0, 5e-5)
    g.on_membership_change(range(6))  # workers 4, 5 join
    assert g.assigned_counts.shape[0] == 6
    assert g.num_workers == 6
    if scheme == "fish":
        assert g.estimator.capacities.shape[0] == 6
        assert g.estimator.backlog.shape[0] == 6
    g.assign_batch(keys[2_000:], 0.5, 5e-5)
    assert int(g.assigned_counts[4] + g.assigned_counts[5]) > 0


@pytest.mark.parametrize("scheme", ["dc", "wc"])
def test_dc_wc_theta_tracks_worker_growth(scheme):
    g = build_grouper(scheme, 8)
    assert g.theta == pytest.approx(0.25 / 8)
    g.on_membership_change(range(16))
    assert g.theta == pytest.approx(0.25 / 16)


def test_fg_consistent_hash_affinity_on_removal():
    w = 8
    g = build_grouper("fg", w)
    sample = [int(k) for k in range(2_000)]
    before = {k: g.probe_route(k) for k in sample}
    removed = 5
    g.on_membership_change([x for x in range(w) if x != removed])
    moved = 0
    for k, b in before.items():
        a = g.probe_route(k)
        assert a != removed
        if b == removed:
            moved += 1
        else:
            # ring monotonicity: keys on surviving workers never move
            assert a == b, k
    # only the removed worker's arc moves: ~1/W of keys, bounded well below 2/W
    assert moved / len(sample) < 2.0 / w
    assert moved > 0


@pytest.mark.parametrize("scheme", SCHEMES)
def test_fail_replica_requeues_all_orphans(scheme):
    rng = np.random.default_rng(4)
    eng = ServingEngine(num_replicas=4, slots_per_replica=2, grouping=scheme)
    n = 50
    for i in range(n):
        eng.submit(Request(i, int(rng.integers(0, 40)), arrival=float(i),
                           target_tokens=int(rng.integers(3, 8))))
    for _ in range(4):
        eng.tick()
    eng.fail_replica(2)
    eng.run(until_done=n, max_ticks=20_000)
    assert len(eng.done) == n
    assert len({r.request_id for r in eng.done}) == n  # no dupes, no loss
    assert len(eng.slots[2]) == 0 and not eng.queues[2]
