"""Partitioning-rule tests: every arch's param tree gets valid, divisible
PartitionSpecs on both production meshes (no device state needed)."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models import transformer as T
from repro.models.sharding import ShardingRules, param_specs

MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axis_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, str):
        return MESH_SIZES[entry]
    return int(np.prod([MESH_SIZES[a] for a in entry]))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    """Every sharded dim must divide its mesh-axis product — this is the
    property the dry-run's in_shardings enforce at lower time."""
    cfg = get_config(arch)
    dp = ("pod", "data") if multi_pod else ("data",)
    rules = ShardingRules(dp=dp, tp="model", tp_size=16,
                          zero=cfg.zero_sharding)
    params = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, rules)

    def check(path, leaf, spec):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        assert len(spec) <= leaf.ndim, (name, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_size(entry)
            assert dim % size == 0, \
                f"{arch}: {name} dim {dim} % mesh {entry}({size})"

    jax.tree_util.tree_map_with_path(
        lambda p, l: check(p, l, None) if False else None, params)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        check(path, leaf, spec)


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "recurrentgemma-9b"])
def test_big_params_fully_sharded(arch):
    """Large weight tensors must shard over >1 axis so per-device bytes fit
    16 GB HBM (the 1T-param feasibility requirement)."""
    cfg = get_config(arch)
    rules = ShardingRules(dp=("pod", "data"), tp="model", tp_size=16,
                          zero=True)
    params = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, rules)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    total_dev_bytes = 0
    for (path, leaf), spec in zip(flat_p, flat_s):
        ways = int(np.prod([_axis_size(e) for e in tuple(spec)])) or 1
        total_dev_bytes += leaf.size * leaf.dtype.itemsize / ways
    assert total_dev_bytes < 9e9, \
        f"{arch}: {total_dev_bytes/2**30:.1f} GiB params/device"


def test_moe_expert_weights_use_ep_plus_zero():
    cfg = get_config("kimi-k2-1t-a32b")
    rules = ShardingRules(dp=("pod", "data"), tp="model", tp_size=16,
                          zero=True)
    params = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, rules)
    wg = specs["stack"]["moe"]["w_gate"]  # (L, E, D, F)
    assert tuple(wg) [1] == "model"            # EP over tp
    assert tuple(wg)[3] == ("pod", "data")     # ZeRO over dp
    sh = specs["stack"]["moe"]["shared"]["w_gate"]  # (L, D, Fs)
    assert tuple(sh)[1] == ("pod", "data") and tuple(sh)[2] == "model"


def test_heads_vs_seq_attention_policy():
    r = ShardingRules(dp=("data",), tp="model", tp_size=16, zero=False)
    assert r.heads_shardable(64) and r.heads_shardable(16)
    assert not r.heads_shardable(24)  # starcoder2
    assert not r.heads_shardable(8)   # gemma2
    assert not r.heads_shardable(20)  # whisper
