"""Keyed operator-state subsystem (ISSUE 4): store backends, window
semantics, split-key merge, and migration exactness under churn.

The load-bearing contract: merged per-window results are a pure function
of the input key stream — identical across store backends, grouping
schemes, engines, churn patterns and migration policies, and equal to the
routing-free :func:`repro.state.direct_aggregate` oracle.  Migration is a
*cost* (bytes moved / tuples replayed), never a correctness event.
"""

import numpy as np
import pytest

from repro.core import MembershipEvent
from repro.data.synthetic import zipf_time_evolving
from repro.scenarios import (Scenario, WorkloadSpec, ChurnOp,
                             default_scenarios, run_dspe_scenario,
                             run_serving_scenario)
from repro.state import (ENTRY_BYTES, ArrayStateStore, DictStateStore,
                         KeyedStateManager, WindowOp, direct_aggregate,
                         merge_partials, topk_cut, tuple_values)
from repro.topology import (Edge, FieldConfig, ScopedEvent,
                            ServingTopologyEngine, SimulatorEngine, Source,
                            Stage, Topology, config_for)

SCHEMES = ("sg", "fg", "pkg", "dc", "wc", "fish")


@pytest.fixture(scope="module")
def keys():
    return zipf_time_evolving(6_000, num_keys=600, z=1.4, seed=0)


def _topo(scheme, op, workers=8, merge_workers=4):
    return Topology(
        name="state",
        stages=(Stage("count", parallelism=workers, operator=op),
                Stage("merge", parallelism=merge_workers)),
        edges=(Edge("source", "count", config_for(scheme)),
               Edge("count", "merge", FieldConfig())),
    )


_CHURN = [
    # worker 3 fails, then returns alongside a brand-new worker 8
    ScopedEvent("count", MembershipEvent(
        at=2_500, workers=tuple(w for w in range(8) if w != 3))),
    ScopedEvent("count", MembershipEvent(at=4_500, workers=tuple(range(9)))),
]


# ---------------------------------------------------------------------------
# store backends
# ---------------------------------------------------------------------------


def test_store_backends_equivalent_under_update_take_merge():
    rng = np.random.default_rng(0)
    a, d = ArrayStateStore(4), DictStateStore()
    for _ in range(40):
        ks = rng.integers(0, 400, rng.integers(1, 150))
        vs = rng.integers(1, 9, ks.shape[0])
        a.update_batch(ks, vs)
        d.update_batch(ks, vs)
        if rng.random() < 0.5 and a.num_entries > 4:
            all_k, _, _ = a.items()
            pick = all_k[rng.choice(all_k.shape[0],
                                    min(7, all_k.shape[0]), replace=False)]
            va, ca = a.take(pick)
            vd, cd = d.take(pick)
            np.testing.assert_array_equal(va, vd)
            np.testing.assert_array_equal(ca, cd)
            # round-trip: merging the extracted entries back is lossless
            a.merge_entries(pick, va, ca)
            d.merge_entries(pick, vd, cd)
    for xa, xd in zip(a.items(), d.items()):
        np.testing.assert_array_equal(xa, xd)
    assert a.num_entries == d.num_entries
    assert a.size_bytes() == d.size_bytes() == a.num_entries * ENTRY_BYTES


def test_array_store_grows_and_reuses_tombstones():
    st = ArrayStateStore(4)
    ks = np.arange(500, dtype=np.int64)
    st.update_batch(ks, np.ones(500, dtype=np.int64))
    assert st.num_entries == 500  # forced several resizes from cap 4
    vals, cnts = st.take(ks[:250])
    assert st.num_entries == 250
    np.testing.assert_array_equal(vals, np.ones(250, dtype=np.int64))
    st.update_batch(ks[:250], np.full(250, 5, dtype=np.int64))  # reinsert
    out_k, out_v, _ = st.items()
    np.testing.assert_array_equal(out_k, ks)
    assert out_v[:250].tolist() == [5] * 250
    with pytest.raises(KeyError):
        st.take(np.array([10_000]))


def test_window_op_validation():
    with pytest.raises(ValueError):
        WindowOp(agg="median")
    with pytest.raises(ValueError):
        WindowOp(size=0)
    with pytest.raises(ValueError):
        WindowOp(size=10, slide=3)  # size must be a multiple of slide
    with pytest.raises(ValueError):
        WindowOp(backend="redis")
    with pytest.raises(ValueError):
        WindowOp(migration="teleport")
    with pytest.raises(ValueError):
        WindowOp(agg="topk", k=0)
    assert WindowOp(size=10, slide=5).stride == 5
    assert WindowOp(size=10).stride == 10


# ---------------------------------------------------------------------------
# window semantics + merge
# ---------------------------------------------------------------------------


def test_tumbling_and_sliding_oracle_shapes():
    keys = np.array([1, 1, 2, 1, 3, 3, 2, 1], dtype=np.int64)
    tumb = direct_aggregate(keys, WindowOp(agg="count", size=4))
    assert tumb == {0: {1: 3, 2: 1}, 4: {1: 1, 2: 1, 3: 2}}
    slide = direct_aggregate(keys, WindowOp(agg="count", size=4, slide=2))
    assert slide[2] == {1: 1, 2: 1, 3: 2}  # tuples 2..5 = [2, 1, 3, 3]
    assert set(slide) == {0, 2, 4, 6}
    top = direct_aggregate(keys, WindowOp(agg="topk", size=8, k=2))
    assert top == {0: [[1, 4], [2, 2]]}  # count ties break to smaller key


def test_topk_tie_break_deterministic():
    ks = np.array([5, 2, 9], dtype=np.int64)
    cs = np.array([3, 3, 7], dtype=np.int64)
    assert topk_cut(ks, cs, 2) == [[9, 7], [2, 3]]


def test_sum_values_deterministic_per_key():
    op = WindowOp(agg="sum", size=8)
    k = np.array([7, 7, 11], dtype=np.int64)
    v1, v2 = tuple_values(op, k), tuple_values(op, k)
    np.testing.assert_array_equal(v1, v2)
    assert v1[0] == v1[1] and (v1 >= 1).all()


@pytest.mark.parametrize("backend", ["dict", "array"])
@pytest.mark.parametrize("agg", ["count", "sum", "topk"])
def test_backends_and_aggs_match_oracle_through_engine(keys, backend, agg):
    op = WindowOp(agg=agg, size=1_500, backend=backend, k=5)
    rep = SimulatorEngine().run(_topo("pkg", op),
                                Source(keys, arrival_rate=2e4))
    assert rep.state["count"]["merged"] == direct_aggregate(keys, op)


def test_merge_stage_consumes_one_tuple_per_state_entry(keys):
    op = WindowOp(agg="count", size=2_000)
    rep = SimulatorEngine().run(_topo("pkg", op),
                                Source(keys, arrival_rate=2e4))
    er = rep.edge("count")
    assert er.partial_entries == rep.edge("merge").n_tuples > 0
    assert er.state_bytes > 0
    assert er.state_bytes == er.state_entries * ENTRY_BYTES
    # split keys: PKG may hold a hot key on 2 workers, so the merge input
    # exceeds the per-window distinct-key count
    st = rep.state["count"]
    distinct = sum(len(w) for w in st["merged"].values())
    assert er.partial_entries >= distinct
    # merge stage sees partials after the window closes: e2e covers them
    assert rep.e2e_latency_p99 > 0


# ---------------------------------------------------------------------------
# migration exactness: churn never changes merged results (tentpole gate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
def test_dspe_churn_exactness_all_schemes(keys, scheme):
    op = WindowOp(agg="count", size=2_000)
    oracle = direct_aggregate(keys, op)
    src = Source(keys, arrival_rate=2e4)
    base = SimulatorEngine().run(_topo(scheme, op), src)
    churn = SimulatorEngine().run(_topo(scheme, op), src, _CHURN)
    assert base.state["count"]["merged"] == oracle
    assert churn.state["count"]["merged"] == oracle
    assert churn.migration_bytes > 0  # failure moved real state
    assert base.migration_bytes == 0


@pytest.mark.parametrize("scheme", ("sg", "fg", "fish"))
def test_reference_engine_churn_exactness(keys, scheme):
    op = WindowOp(agg="sum", size=2_000)
    oracle = direct_aggregate(keys, op)
    rep = SimulatorEngine(mode="reference").run(
        _topo(scheme, op), Source(keys, arrival_rate=2e4), _CHURN)
    assert rep.state["count"]["merged"] == oracle
    assert rep.migration_bytes > 0


@pytest.mark.parametrize("scheme", SCHEMES)
def test_serving_engine_churn_exactness_all_schemes(keys, scheme):
    op = WindowOp(agg="count", size=48)
    eng = ServingTopologyEngine(max_requests=96)
    src = Source(keys, arrival_rate=2e4)
    sub = keys[np.linspace(0, keys.shape[0] - 1, 96).astype(np.int64)]
    oracle = direct_aggregate(sub, op)
    events = [ScopedEvent("count", MembershipEvent(
        at=40, workers=(0, 1, 2, 3, 4))),
        ScopedEvent("count", MembershipEvent(
            at=70, workers=(0, 1, 2, 3, 4, 6)))]
    base = eng.run(_topo(scheme, op, workers=6), src)
    churn = eng.run(_topo(scheme, op, workers=6), src, events)
    assert base.state["count"]["merged"] == oracle
    assert churn.state["count"]["merged"] == oracle
    assert churn.state["count"]["migration_events"] == 2


def test_boundary_aligned_event_migrates_nothing():
    """A window that completed exactly at the event index is lazily open
    but *done* — its state must flush, never migrate (cost would be
    overcounted otherwise)."""
    from repro.state import KeyedStateManager

    class _G:
        active_workers = [0, 1]

        def probe_route(self, k):
            return int(k) % 2

    class _G2(_G):
        active_workers = [0]

        def probe_route(self, k):
            return 0

    mgr = KeyedStateManager(WindowOp(agg="count", size=100))
    ks = np.arange(100, dtype=np.int64)
    mgr.feed(ks, ks % 2)
    mgr.on_event("pre_membership", _G())   # event lands at idx == 100
    mgr.on_event("post_membership", _G2())
    mgr.finalize()
    rep = mgr.report("s")
    assert rep.migration_bytes == 0 and rep.tuples_replayed == 0
    assert rep.merged == direct_aggregate(ks, WindowOp(agg="count", size=100))


def test_rebuild_policy_replays_instead_of_moving_bytes(keys):
    op = WindowOp(agg="count", size=2_000, migration="rebuild")
    rep = SimulatorEngine().run(_topo("fg", op),
                                Source(keys, arrival_rate=2e4), _CHURN)
    st = rep.state["count"]
    assert st["merged"] == direct_aggregate(keys, op)
    assert st["tuples_replayed"] > 0
    assert st["migration_bytes"] == 0


def test_sliding_windows_exact_under_churn(keys):
    op = WindowOp(agg="count", size=2_000, slide=500)
    rep = SimulatorEngine().run(_topo("fish", op),
                                Source(keys, arrival_rate=2e4), _CHURN)
    assert rep.state["count"]["merged"] == direct_aggregate(keys, op)
    assert rep.state["count"]["windows"] == len(range(0, 6_000, 500))


def test_operator_stage_rejects_transform():
    from repro.topology import hashed_fanout

    with pytest.raises(ValueError, match="mutually exclusive"):
        Stage("s", 2, transform=hashed_fanout(2, 10),
              operator=WindowOp(size=10))
    with pytest.raises(TypeError, match="WindowOp"):
        Stage("s", 2, operator="count")


# ---------------------------------------------------------------------------
# scenario runners report state-migration cost + exactness
# ---------------------------------------------------------------------------


def test_dspe_scenario_reports_state_migration():
    suite = default_scenarios(num_tuples=3_000, num_keys=300, workers=6)
    # window straddles every suite churn point (at 900/1200/1500/1800):
    # a boundary-aligned event would rightly migrate nothing
    op = WindowOp(agg="count", size=1_000)
    for sc in suite:
        for scheme in ("fg", "fish"):
            out = run_dspe_scenario(sc, scheme, window=op)
            st = out["state"]
            assert st["exact"], (sc.name, scheme)
            if sc.churn:
                assert st["migration_bytes"] > 0, (sc.name, scheme)
            else:
                assert st["migration_bytes"] == 0, (sc.name, scheme)


def test_dspe_scenario_without_window_has_no_state_row():
    sc = default_scenarios(num_tuples=1_500, num_keys=200, workers=4)[0]
    out = run_dspe_scenario(sc, "pkg")
    assert "state" not in out


def test_serving_scenario_reports_state_migration():
    sc = next(s for s in default_scenarios(3_000, 300, 6)
              if s.name == "failure_elastic")
    out = run_serving_scenario(sc, "sg", num_requests=60,
                               window=WindowOp(agg="count", size=60))
    st = out["state"]
    assert out["completed"] == 60
    assert st["exact"]
    # SG replicates sessions on every live replica, so the failed replica
    # is guaranteed to hold state when the heartbeat monitor fires
    assert st["migration_bytes"] > 0
    assert st["migration_events"] >= 1


def test_serving_scenario_scale_out_state_exact():
    sc = Scenario(
        "scale_out_state", workers=4,
        workload=WorkloadSpec("piecewise", 2_000, 200, z=1.2, phases=4),
        churn=(ChurnOp(0.5, "add", 4),),
    )
    out = run_serving_scenario(sc, "fish", num_requests=48,
                               window=WindowOp(agg="sum", size=48))
    assert out["state"]["exact"]
    assert out["state"]["migration_events"] == 1


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------


def test_reports_roundtrip_json(keys):
    import json

    op = WindowOp(agg="topk", size=3_000, k=4)
    rep = SimulatorEngine().run(_topo("wc", op),
                                Source(keys, arrival_rate=2e4), _CHURN)
    blob = json.dumps(rep.to_dict())
    assert "state_bytes" in blob and "migration_bytes" in blob
    er = rep.edge("count")
    assert er.migration_bytes == rep.migration_bytes > 0
    assert rep.state["count"]["per_worker_bytes"]
    assert rep.state["count"]["state_keys"] == len(np.unique(keys))
