"""Checkpointing, data pipeline, runtime (fault/elastic/straggler), serving."""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpointing import checkpoint as ckpt
from repro.core.fish import FishParams
from repro.data.pipeline import StreamingPipeline
from repro.data.synthetic import token_stream
from repro.runtime.elastic import ElasticPool
from repro.runtime.fault import HeartbeatMonitor, RestartPolicy
from repro.runtime.stragglers import StragglerMitigator
from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_latest_step_ignores_uncommitted(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crashed save: directory without COMMITTED
    os.makedirs(tmp_path / "step_000000009")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_keep_policy_removes_old(tmp_path):
    tree = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, _tree())
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.ones(4)},
           "step": jnp.int32(0)}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_batches_and_balance():
    pipe = StreamingPipeline(num_hosts=4, seq_len=32, batch_per_host=2,
                             grouping="fish",
                             fish_params=FishParams(epoch=200, k_max=64))
    stream = token_stream(600, num_keys=100, doc_len=40, vocab_size=1000,
                          z=1.4, seed=0)
    pipe.ingest_stream(stream)
    batch = pipe.next_global_batch()
    assert batch is not None
    assert batch["tokens"].shape == (8, 32)
    assert batch["labels"].shape == (8, 32)
    # next-token alignment
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])
    # memory bounded: far fewer replicas than shuffle would create
    assert pipe.memory_overhead() <= 4 * 100


def test_pipeline_straggler_feedback_shifts_load():
    caps = np.array([1.0, 1.0, 1.0, 8.0])  # host 3 is 8x slower
    pipe = StreamingPipeline(num_hosts=4, seq_len=16, batch_per_host=1,
                             grouping="fish", host_capacities=caps)
    stream = token_stream(2000, num_keys=500, doc_len=8, vocab_size=100,
                          z=1.1, seed=1)
    pipe.ingest_stream(stream)
    routed = pipe._docs_routed
    assert routed[3] < routed[:3].mean() * 0.8, routed


def test_pipeline_elastic_rescale():
    pipe = StreamingPipeline(num_hosts=4, seq_len=16, batch_per_host=1)
    stream = list(token_stream(300, num_keys=50, doc_len=8, vocab_size=100,
                               seed=2))
    pipe.ingest_stream(iter(stream[:150]))
    routed_before = pipe._docs_routed.copy()
    pipe.rescale([0, 1, 2])  # host 3 died
    pipe.ingest_stream(iter(stream[150:]))
    routed_after = pipe._docs_routed.copy()
    # no new docs reached the dead host
    assert routed_after[3] == routed_before[3]
    assert routed_after.sum() == 300


def test_rescale_redistributes_stranded_backlog():
    """A removed host's non-empty buffer must move to a survivor: leaving
    it in ``_buffers`` kept the dead host in ``_active_hosts`` and made
    ``ready()``/``next_global_batch()`` wait on a queue nothing drains."""
    pipe = StreamingPipeline(num_hosts=4, seq_len=4, batch_per_host=1,
                             grouping="fg")
    stream = list(token_stream(200, num_keys=40, doc_len=6, vocab_size=100,
                               seed=3))
    pipe.ingest_stream(iter(stream))
    total_before = sum(len(b) for b in pipe._buffers.values())
    backlog3 = len(pipe._buffers[3])
    assert backlog3 > 0  # the bug needs a non-empty dead buffer

    pipe.rescale([0, 1, 2])
    assert 3 not in pipe._buffers
    assert pipe._active_hosts() == [0, 1, 2]
    # tokens conserved — the dead host's run landed on a survivor
    assert sum(len(b) for b in pipe._buffers.values()) == total_before
    # batch assembly no longer waits on the dead host
    batch = pipe.next_global_batch()
    assert batch is not None and batch["tokens"].shape == (3, 4)


def test_work_stealing_preserves_token_order():
    """Stolen tokens must be a contiguous run from the donor's *head*;
    ``pop()`` from the tail handed the recipient a reversed slice of the
    donor's newest tokens."""
    from collections import deque

    pipe = StreamingPipeline(num_hosts=2, seq_len=4, batch_per_host=1,
                             grouping="sg")
    # donor host 0 holds 0..59 in ingestion order; host 1 is starved
    pipe._buffers[0] = deque(range(60))
    pipe._buffers[1] = deque()
    need = pipe.seq_len * pipe.batch_per_host + pipe.batch_per_host  # = 5
    batch = pipe.next_global_batch(steal=True)
    assert batch is not None
    # host 0 kept its head run, host 1 received the contiguous stolen run
    np.testing.assert_array_equal(batch["tokens"][0], [5, 6, 7, 8])
    np.testing.assert_array_equal(batch["labels"][0], [6, 7, 8, 9])
    np.testing.assert_array_equal(batch["tokens"][1], [0, 1, 2, 3])
    np.testing.assert_array_equal(batch["labels"][1], [1, 2, 3, 4])
    # donor's remaining buffer is still in order
    assert list(pipe._buffers[0]) == list(range(10, 60))


# ---------------------------------------------------------------------------
# runtime: fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_detects_death_and_rejoin():
    mon = HeartbeatMonitor(range(4), timeout=5.0)
    for t in range(4):
        for h in range(4):
            if h != 2:
                mon.heartbeat(h, float(t))
    dead = mon.check(7.0)
    assert dead == [2]
    assert mon.alive() == [0, 1, 3]
    mon.heartbeat(2, 8.0)
    assert mon.alive() == [0, 1, 2, 3]


def test_restart_policy_elastic_vs_restart():
    events = {"rescale": 0, "restart": 0}
    pol = RestartPolicy(
        total_hosts=8, max_lost_frac=0.25,
        on_rescale=lambda alive: events.__setitem__("rescale",
                                                    events["rescale"] + 1),
        on_restart=lambda: events.__setitem__("restart",
                                              events["restart"] + 1) or 0,
    )
    mon = HeartbeatMonitor(range(8), timeout=5.0)
    for h in range(8):
        mon.heartbeat(h, 0.0)
    # one host silent -> elastic continue
    for h in range(7):
        mon.heartbeat(h, 4.0)
    mon.check(8.0)
    assert pol.handle(mon, 8.0) == "rescaled"
    # hosts 4-7 silent -> 4/8 lost -> checkpoint restart
    for h in range(4):
        mon.heartbeat(h, 9.0)
    mon.check(12.0)
    assert pol.handle(mon, 12.0) == "restarted"
    assert events == {"rescale": 1, "restart": 1}


def test_elastic_pool_remap_fraction():
    pool = ElasticPool(range(8), virtual_nodes=64)
    keys = [f"k{i}" for i in range(4000)]
    moved = pool.remove_host(3, sample_keys=keys)
    assert moved / len(keys) < 0.3  # ~1/8 expected


def test_straggler_mitigator_shares():
    sm = StragglerMitigator(num_hosts=4, interval=1.0)
    sm.record_step_time(0, 1.0)
    sm.record_step_time(1, 1.0)
    sm.record_step_time(2, 1.0)
    sm.record_step_time(3, 4.0)  # straggler
    shares = sm.shares()
    assert shares.sum() == pytest.approx(1.0)
    assert shares[3] < shares[:3].min()
    assert sm.slowest() in range(4)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def _mk_requests(n, rng, hot_frac=0.8, sessions=50):
    reqs = []
    for i in range(n):
        # time-evolving sessions: hot set flips halfway
        if rng.random() < hot_frac:
            base = 0 if i < n // 2 else sessions
            sess = f"hot{base + rng.integers(0, 3)}"
        else:
            sess = f"cold{rng.integers(0, sessions)}"
        reqs.append(Request(i, sess, arrival=float(i) * 0.1,
                            target_tokens=int(rng.integers(4, 12))))
    return reqs


def test_engine_completes_all_requests():
    rng = np.random.default_rng(0)
    eng = ServingEngine(num_replicas=4, slots_per_replica=4,
                        grouping="fish")
    reqs = _mk_requests(80, rng)
    for r in reqs:
        eng.submit(r)
    eng.run(until_done=80)
    assert len(eng.done) == 80
    m = eng.metrics()
    assert m.throughput_tokens > 0
    assert m.session_replicas_norm < 4.0  # bounded replication


def test_engine_fish_beats_fg_latency_under_skew():
    rng = np.random.default_rng(1)
    reqs = _mk_requests(150, rng)
    lat = {}
    for scheme in ("fg", "fish"):
        eng = ServingEngine(num_replicas=4, slots_per_replica=4,
                            grouping=scheme)
        for r in reqs:
            r2 = Request(r.request_id, r.session, r.arrival, r.target_tokens)
            eng.submit(r2)
        eng.run(until_done=150)
        lat[scheme] = eng.metrics().latency_p99
    assert lat["fish"] <= lat["fg"]


def test_engine_replica_failure_reroutes():
    rng = np.random.default_rng(2)
    eng = ServingEngine(num_replicas=3, slots_per_replica=4, grouping="fish")
    for r in _mk_requests(60, rng):
        eng.submit(r)
    for _ in range(5):
        eng.tick()
    moved = eng.fail_replica(1)
    assert moved > 0
    eng.run(until_done=60)
    assert len(eng.done) == 60
    # nothing ran on the dead replica after failure
    assert len(eng.slots[1]) == 0


def test_engine_scale_out():
    rng = np.random.default_rng(3)
    eng = ServingEngine(num_replicas=2, slots_per_replica=2, grouping="fish")
    for r in _mk_requests(40, rng):
        eng.submit(r)
    eng.add_replica(speed=2.0, slots=4)
    eng.run(until_done=40)
    assert len(eng.done) == 40
