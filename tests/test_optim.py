"""AdamW / factored-AdamW optimizer tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.adamw import (AdamWConfig, adamw_update, cosine_schedule,
                               global_norm, init_opt_state, opt_state_specs)


def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0]),
            "m": {"scale": jnp.asarray([2.0, 2.0])}}


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0)
    params = _quad_params()
    state = init_opt_state(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["m"]["scale"] - 1.0) ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 0.1 * l0


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0,
                      weight_decay=0.0)
    params = {"w": jnp.ones(4)}
    state = init_opt_state(params, cfg)
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(g, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_warmup_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(jnp.int32(1), cfg)) < 0.2
    assert float(cosine_schedule(jnp.int32(10), cfg)) == pytest.approx(1.0, rel=0.05)
    assert float(cosine_schedule(jnp.int32(100), cfg)) < 0.2


def test_no_decay_on_norm_params():
    cfg = AdamWConfig(lr=0.1, weight_decay=10.0, warmup_steps=0)
    params = {"mlp": {"w_gate": jnp.ones((4, 4))},
              "ln": {"scale": jnp.ones(4)}}
    state = init_opt_state(params, cfg)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(zero_g, state, params, cfg)
    # decayed: w_gate shrinks; not decayed: scale unchanged
    assert float(jnp.abs(new_p["ln"]["scale"] - 1.0).max()) < 1e-6
    assert float(new_p["mlp"]["w_gate"].max()) < 1.0


def test_factored_v_shapes_and_descent():
    cfg = AdamWConfig(lr=0.05, warmup_steps=0, factored_v=True,
                      weight_decay=0.0)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(8, 6)).astype(np.float32))}
    state = init_opt_state(params, cfg)
    assert state.v["w"]["r"].shape == (8,)
    assert state.v["w"]["c"].shape == (6,)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 0.2 * l0


def test_opt_state_specs_factored():
    from jax.sharding import PartitionSpec as P

    cfg = AdamWConfig(factored_v=True)
    params = {"w": jnp.zeros((8, 6)), "b": jnp.zeros((6,))}
    pspecs = {"w": P("model", "data"), "b": P(None)}
    m_specs, v_specs = opt_state_specs(params, pspecs, cfg)
    assert m_specs["w"] == P("model", "data")
    assert v_specs["w"]["r"] == P("model")
    assert v_specs["w"]["c"] == P("data")
    assert v_specs["b"] == P(None)


def test_state_dtype_bf16():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4))}
    state = init_opt_state(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
