"""Consistent hashing (paper §5): monotonicity, balance, virtual nodes."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import ConsistentHashRing


def test_lookup_deterministic():
    ring = ConsistentHashRing(range(8))
    assert ring.lookup("abc") == ring.lookup("abc")


def test_lookup_n_distinct_workers():
    ring = ConsistentHashRing(range(8))
    cands = ring.lookup_n("key", 5)
    assert len(cands) == len(set(cands)) == 5


def test_lookup_n_caps_at_worker_count():
    ring = ConsistentHashRing(range(3))
    assert len(ring.lookup_n("key", 10)) == 3


@given(st.integers(3, 20), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_removal_only_remaps_removed_workers_keys(n_workers, seed):
    """Monotonicity (Fig. 8b): removing w only moves keys owned by w."""
    ring = ConsistentHashRing(range(n_workers), virtual_nodes=16)
    keys = [f"k{seed}_{i}" for i in range(200)]
    before = {k: ring.lookup(k) for k in keys}
    victim = seed % n_workers
    ring.remove_worker(victim)
    for k in keys:
        after = ring.lookup(k)
        if before[k] != victim:
            assert after == before[k], "non-victim key remapped"
        else:
            assert after != victim


@given(st.integers(3, 20), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_addition_only_steals_keys_for_new_worker(n_workers, seed):
    """Monotonicity (Fig. 8c): adding w only moves keys onto w."""
    ring = ConsistentHashRing(range(n_workers), virtual_nodes=16)
    keys = [f"a{seed}_{i}" for i in range(200)]
    before = {k: ring.lookup(k) for k in keys}
    new = n_workers + 1
    ring.add_worker(new)
    for k in keys:
        after = ring.lookup(k)
        assert after == before[k] or after == new


def test_virtual_nodes_improve_balance():
    """Fig. 8(d): more virtual nodes -> more even key distribution."""
    keys = [f"key{i}" for i in range(20_000)]

    def imbalance(vn):
        ring = ConsistentHashRing(range(8), virtual_nodes=vn)
        counts = {}
        for k in keys:
            w = ring.lookup(k)
            counts[w] = counts.get(w, 0) + 1
        loads = np.array([counts.get(w, 0) for w in range(8)], float)
        return loads.max() / loads.mean()

    assert imbalance(128) < imbalance(1)


def test_expected_remap_fraction_small():
    """Removing 1 of n workers should remap ~1/n of keys (paper §5)."""
    n = 16
    ring = ConsistentHashRing(range(n), virtual_nodes=64)
    keys = [f"key{i}" for i in range(20_000)]
    before = {k: ring.lookup(k) for k in keys}
    ring.remove_worker(0)
    moved = sum(1 for k in keys if ring.lookup(k) != before[k])
    frac = moved / len(keys)
    assert frac < 2.5 / n, f"remapped {frac:.3f}, expected ~{1/n:.3f}"
