"""Telemetry spine (ISSUE 9): metrics/tracer/timeline units, the strict
disabled fast path, report bit-identity, trace-schema validity, the FISH
hot-set timeline against an exact Alg. 1 oracle, engine-clock/epoch
monotonicity, the streaming trace writer's crash path, and the CLI.
"""

import json

import numpy as np
import pytest

from repro.data.synthetic import zipf_time_evolving
from repro.obs import telemetry as telmod
from repro.obs.export import TraceWriter, validate_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.telemetry import Telemetry
from repro.obs.timeline import NULL_TIMELINE, TIMELINE_COLUMNS
from repro.obs.trace import NULL_SPAN, NULL_TRACER
from repro.core import MembershipEvent
from repro.topology import (Edge, ScopedEvent, SimulatorEngine, Source,
                            Stage, Topology, config_for)

RATE = 20_000.0


def _topo(scheme="fish", workers=8, name="obs"):
    return Topology(name=name,
                    stages=(Stage("w", parallelism=workers),),
                    edges=(Edge("source", "w", config_for(scheme)),))


def _run(keys, scheme="fish", mode="batched", telemetry=None, batch=2_000,
         events=()):
    session = SimulatorEngine(mode=mode).open(
        _topo(scheme), arrival_rate=RATE, telemetry=telemetry)
    if events:
        session.advance(list(events))
    for b in Source(keys, arrival_rate=RATE).iter_batches(batch_size=batch):
        session.feed(b)
    return session.close()


# ---------------------------------------------------------------------------
# instruments + registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.add(2)
    c.add(3)
    assert c.value == 5
    c.set(1)
    assert c.value == 1
    g = reg.gauge("g")
    g.set(4.0)
    g.peak(2.0)
    assert g.value == 4.0  # peak never lowers
    g.peak(9.0)
    assert g.value == 9.0
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.record(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 1.0 and s["max"] == 4.0


def test_registry_snapshot_aggregates_by_name():
    reg = MetricsRegistry()
    reg.counter("n").add(2)
    reg.counter("n").add(3)  # second cell, same name: snapshot sums
    snap = reg.snapshot()
    assert snap["n"]["value"] == 5
    # adopt: an externally-minted cell joins this registry's snapshot
    other = MetricsRegistry()
    cell = other.counter("ext")
    cell.add(7)
    reg.adopt(cell)
    assert reg.snapshot()["ext"]["value"] == 7


def test_tracer_spans_and_instants():
    tel = Telemetry(enabled=True)
    with tel.tracer.span("outer", cat="t", k=1) as sp:
        sp.set(extra=2)
        tel.tracer.instant("ping", cat="t", n=3)
    assert len(tel.tracer.spans) == 1
    sp = tel.tracer.spans[0]
    assert sp.name == "outer" and sp.t1 >= sp.t0
    assert sp.args["k"] == 1 and sp.args["extra"] == 2
    (t, name, cat, args), = tel.tracer.instants
    assert name == "ping" and cat == "t" and args["n"] == 3


# ---------------------------------------------------------------------------
# the strict disabled fast path
# ---------------------------------------------------------------------------


def test_disabled_bundle_is_noop_singletons():
    tel = Telemetry(enabled=False)
    assert tel.tracer is NULL_TRACER
    assert tel.tracer.span("x", cat="y", a=1) is NULL_SPAN
    with tel.tracer.span("x") as sp:
        sp.set(a=1)
    tel.tracer.instant("x", cat="y")
    tel.timeline.point("s", 1.0)
    # nothing was recorded anywhere
    assert tel.tracer.spans == [] and tel.tracer.instants == []
    assert tel.timeline.series == {} and NULL_TIMELINE.series == {}
    assert tel.timeline_dict() is None
    # a disabled process default hands out private per-session bundles;
    # an enabled one is shared so the whole run lands on one trace
    assert tel.for_session() is not tel
    on = Telemetry(enabled=True)
    assert on.for_session() is on


def test_disabled_session_collects_nothing():
    keys = zipf_time_evolving(4_000, num_keys=400, z=1.2, seed=0)
    tel = Telemetry(enabled=False)
    _run(keys, telemetry=tel)
    assert tel.tracer.spans == [] and tel.timeline.series == {}
    # metrics are ALWAYS real — feed/event-granular, never per-tuple
    assert tel.metrics.snapshot()["session.feeds"]["value"] == 2


# ---------------------------------------------------------------------------
# report bit-identity + zero extra device work (overhead guard, tier 1)
# ---------------------------------------------------------------------------


def test_reports_bit_identical_when_disabled():
    keys = zipf_time_evolving(6_000, num_keys=600, z=1.3, seed=1)
    base = _run(keys).to_dict()
    enabled = _run(keys, telemetry=Telemetry(enabled=True)).to_dict()
    assert "timeline" not in base
    tl = enabled.pop("timeline")
    assert tl["series"] and tl["metrics"]
    assert enabled == base  # everything but the timeline is untouched


@pytest.mark.parametrize("scheme", ("sg", "fish"))
def test_fused_dispatches_unchanged_by_telemetry(scheme):
    keys = zipf_time_evolving(4_096, num_keys=500, z=1.3, seed=2)
    off = _run(keys, scheme=scheme, mode="fused", batch=1_024)
    on = _run(keys, scheme=scheme, mode="fused", batch=1_024,
              telemetry=Telemetry(enabled=True))
    e_off, e_on = off.edge("w"), on.edge("w")
    # instrumentation observes, never reshapes: same launches, same stream
    assert e_on.dispatches == e_off.dispatches
    assert e_on.row() == e_off.row()


# ---------------------------------------------------------------------------
# FISH hot-set timeline vs the exact Alg. 1 oracle (ZF hot-key flip)
# ---------------------------------------------------------------------------

_N, _NK, _W = 12_000, 800, 8
_EPOCH, _ALPHA = 1000, 0.2  # FishParams defaults


def _oracle_hotsets(keys):
    """Per-epoch hot sets from unbounded exact Alg. 1 counts.  With
    ``num_keys <= k_max`` SpaceSaving never evicts, so the tracker must
    match this oracle exactly — not approximately."""
    theta = 0.25 / _W
    counts, tin, hotsets = {}, 0, []
    for k in keys.tolist():
        if tin == _EPOCH:
            for kk in counts:
                counts[kk] *= _ALPHA
            tin = 0
            total = sum(counts.values())
            hotsets.append(
                {kk for kk, c in counts.items() if c / total > theta})
        counts[k] = counts.get(k, 0.0) + 1.0
        tin += 1
    return hotsets


def _flip_run():
    keys = zipf_time_evolving(_N, num_keys=_NK, z=1.4, flip_head=_NK // 3,
                              seed=0)
    tel = Telemetry(enabled=True)
    _run(keys, scheme="fish", telemetry=tel)
    return keys, tel


def test_fish_hot_set_timeline_matches_exact_oracle():
    keys, tel = _flip_run()
    hotsets = _oracle_hotsets(keys)
    size = tel.timeline.series["fish.hot_set_size"]
    churn = tel.timeline.series["fish.hot_set_churn"]
    assert len(size) == len(hotsets)
    for _wall, _clock, _feed, epoch, value in size:
        assert int(value) == len(hotsets[int(epoch) - 1])
    prev, oracle_churn = set(), []
    for h in hotsets:
        oracle_churn.append(len(h ^ prev))
        prev = h
    for _wall, _clock, _feed, epoch, value in churn:
        assert int(value) == oracle_churn[int(epoch) - 1]


def test_hot_key_flip_visible_within_one_epoch():
    keys, tel = _flip_run()
    churn = {int(p[3]): p[4] for p in
             tel.timeline.series["fish.hot_set_churn"]}
    flip_epoch = int(0.8 * _N) // _EPOCH  # the flip lands inside this epoch
    # the churn spike shows up in the first two epoch reports after the
    # flip and dominates every steady-state epoch before it
    steady = max(v for e, v in churn.items() if 2 <= e <= flip_epoch)
    spike = max(churn[flip_epoch + 1], churn[flip_epoch + 2])
    assert spike > steady


def test_engine_clock_and_epoch_monotone_under_events_and_multifeed():
    keys = zipf_time_evolving(_N, num_keys=_NK, z=1.3, seed=3)
    tel = Telemetry(enabled=True)
    events = [ScopedEvent("w", MembershipEvent(at=_N // 2,
                                               workers=tuple(range(6))))]
    _run(keys, scheme="fish", telemetry=tel, batch=1_500, events=events)
    for name, pts in tel.timeline.series.items():
        clocks = [p[TIMELINE_COLUMNS.index("engine_clock")] for p in pts]
        assert clocks == sorted(clocks), name
        epochs = [p[TIMELINE_COLUMNS.index("epoch_idx")] for p in pts]
        assert epochs == sorted(epochs), name
        walls = [p[0] for p in pts]
        assert walls == sorted(walls), name
    # the membership event surfaced both as a counter and a trace instant
    assert tel.metrics.snapshot()["session.membership_events"]["value"] == 1
    assert any(name == "event.membership"
               for _t, name, _c, _a in tel.tracer.instants)


def test_timeline_downsample_keeps_first_and_last():
    tel = Telemetry(enabled=True)
    for i in range(2_000):
        tel.timeline.point("s", float(i), engine_clock=float(i))
    out = tel.timeline.export(max_points=100)
    s = out["series"]["s"]
    assert s["n_points"] == 2_000 and s["n_kept"] <= 101
    pts = s["points"]
    assert pts[0][-1] == 0.0 and pts[-1][-1] == 1999.0
    assert out["columns"] == list(TIMELINE_COLUMNS)


# ---------------------------------------------------------------------------
# chrome trace export + streaming writer + CLI
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_valid():
    keys = zipf_time_evolving(6_000, num_keys=600, z=1.3, seed=4)
    tel = Telemetry(enabled=True, label="schema")
    _run(keys, telemetry=tel)
    trace = tel.chrome_trace()
    assert validate_chrome_trace(trace) == []
    phases = {ev["ph"] for ev in trace["traceEvents"]}
    assert {"M", "X", "C"} <= phases
    # negative control: the validator actually rejects garbage
    bad = {"traceEvents": [{"name": "x", "ph": "Z", "pid": 1}]}
    assert validate_chrome_trace(bad)


def test_trace_writer_abort_seals_valid_json(tmp_path):
    path = tmp_path / "run.trace.json"
    w = TraceWriter(str(path))
    w.write_event({"name": "a", "ph": "i", "ts": 0.0, "pid": 1, "s": "p"})
    w.abort("died mid-run")
    obj = json.loads(path.read_text())
    assert validate_chrome_trace(obj) == []
    assert obj["otherData"]["aborted"] is True
    assert obj["otherData"]["abort_reason"] == "died mid-run"
    assert w.abort() is None  # idempotent
    # the context-manager form seals on exception too
    path2 = tmp_path / "boom.trace.json"
    with pytest.raises(RuntimeError):
        with TraceWriter(str(path2)) as w2:
            w2.write_event({"name": "b", "ph": "i", "ts": 0.0, "pid": 1})
            raise RuntimeError("boom")
    obj2 = json.loads(path2.read_text())
    assert validate_chrome_trace(obj2) == []
    assert obj2["otherData"]["aborted"] is True


def test_reporter_failure_flushes_partial_trace(tmp_path):
    from benchmarks.common import Reporter

    tel = telmod.enable(label="failing-bench")
    try:
        tel.tracer.instant("before.crash", cat="run")
        rep = Reporter()
        w = TraceWriter(str(tmp_path / "failing.trace.json"))
        rep.attach_trace(w)
        rep.add_failure("failing-bench", RuntimeError("synthetic"))
    finally:
        telmod.disable()
    obj = json.loads((tmp_path / "failing.trace.json").read_text())
    assert validate_chrome_trace(obj) == []
    assert obj["otherData"]["aborted"] is True
    # the events collected before the crash were flushed, not truncated
    assert any(ev.get("name") == "before.crash"
               for ev in obj["traceEvents"])
    assert not (tmp_path / "failing.trace.json.tmp").exists()


def test_cli_summarize_diff_validate(tmp_path, capsys):
    from repro.obs.cli import main as obs_main

    keys = zipf_time_evolving(4_000, num_keys=400, z=1.2, seed=5)
    tel_a = Telemetry(enabled=True, label="a")
    _run(keys, scheme="fish", telemetry=tel_a)
    tel_b = Telemetry(enabled=True, label="b")
    _run(keys, scheme="pkg", telemetry=tel_b)
    pa, pb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    tel_a.save(pa)
    tel_b.save(pb)
    assert obs_main(["validate", pa]) == 0
    capsys.readouterr()  # drop the validate "ok" line
    assert obs_main(["summarize", pa, "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["label"] == "a" and summary["spans"]["session.feed"]
    assert obs_main(["diff", pa, pb, "--json"]) == 0
    diff = json.loads(capsys.readouterr().out)
    assert diff["a"] == "a" and diff["b"] == "b"
    assert "session.feeds" in diff["metrics"]
    # an invalid file exits nonzero
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [{"ph": "Q", "pid": 1}]}')
    assert obs_main(["validate", str(bad)]) == 1


# ---------------------------------------------------------------------------
# unified counters: legacy attributes are registry-backed
# ---------------------------------------------------------------------------


def test_serving_engine_counters_are_registry_backed():
    from repro.serving.engine import Request, ServingEngine

    reg = MetricsRegistry()
    eng = ServingEngine(2, slots_per_replica=1, max_queue_per_replica=1,
                        metrics=reg)
    for i in range(8):
        eng.submit(Request(i, i % 2, arrival=0.0, target_tokens=2))
    assert eng.shed > 0
    assert reg.snapshot()["serving.shed"]["value"] == eng.shed
    assert (reg.snapshot()["serving.queue_depth_peak"]["value"]
            == eng.queue_depth_peak)
    eng.shed = 0  # legacy write-compat goes through the cell
    assert reg.snapshot()["serving.shed"]["value"] == 0


def test_feed_fused_trace_count_is_registry_backed():
    from repro.kernels import feed_fused
    from repro.obs.metrics import GLOBAL_METRICS

    base = feed_fused.TRACE_COUNT
    feed_fused.TRACE_COUNT += 2  # the module-class property forwards writes
    assert feed_fused.TRACE_COUNT == base + 2
    assert (GLOBAL_METRICS.snapshot()["fused.trace_count"]["value"]
            == base + 2)
    feed_fused.TRACE_COUNT = base


def test_load_report_timeline_gated_on_telemetry():
    from repro.scenarios import OpenLoopScenario, run_open_loop_scenario
    from repro.load import IngressQueue, OpenLoopDriver

    ol = OpenLoopScenario("obs_smoke", workers=4, rate=1_000.0, horizon=1.0,
                          num_keys=128, queue_capacity=128, policy="shed",
                          backpressure=0.25)
    tel = telmod.enable(label="open-loop")
    try:
        session = SimulatorEngine(mode="batched").open(
            _topo("fish", workers=4, name="ol"), arrival_rate=ol.rate)
        driver = OpenLoopDriver(
            session, IngressQueue(ol.queue_capacity, policy="shed"),
            backpressure=0.05)
        rep = driver.run(ol.arrivals(), 0.0, ol.horizon, drain=True)
    finally:
        telmod.disable()
    d = rep.to_dict()
    assert "load.queue_depth" in d["timeline"]["series"]
    assert "load.backpressure_engaged" in d["timeline"]["metrics"]
    # disabled: the very same run shape omits the timeline key entirely
    out = run_open_loop_scenario(ol, "fish", engine="batched")
    assert out["identity_ok"]
